"""Gradient compression for the slow (cross-pod / DCN) axis.

int8 quantization with per-leaf scales and *error feedback* [Seide et al.,
1-bit SGD; Karimireddy et al. EF-SGD]: the quantization residual is carried
into the next step so compression error doesn't bias convergence.  Applied
only to the pod-axis all-reduce in multi-pod training — ICI-local reduces
stay full precision.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any   # pytree like grads


def init_ef_state(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(
        lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads_like))


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, ef: EFState):
    """Returns (quantized pytree of (q, scale), new EFState)."""
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, ef.residual)
    q_tree = jax.tree.map(quantize_int8, corrected)
    deq = jax.tree.map(lambda qs: dequantize_int8(*qs), q_tree,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_resid = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return q_tree, EFState(residual=new_resid)


def decompress(q_tree):
    return jax.tree.map(lambda qs: dequantize_int8(*qs), q_tree,
                        is_leaf=lambda t: isinstance(t, tuple))
