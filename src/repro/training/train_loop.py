"""Trainer: jitted step + checkpoint/restart + straggler accounting.

Runs on any mesh (the single-CPU host mesh for tests/demos; the production
mesh in the dry-run).  Fault tolerance drill: kill the process at any step,
rerun the same command — the trainer resumes from the latest atomic
checkpoint and the deterministic pipeline replays the exact batch stream.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import SyntheticPipeline
from repro.distributed.sharding import ShardingPolicy, use_policy
from repro.launch.steps import build_train_step
from repro.models.transformer import Model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.straggler import StragglerMonitor


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "checkpoints"
    log_every: int = 10
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, model: Model, shape: ShapeSpec,
                 policy: Optional[ShardingPolicy], tcfg: TrainConfig,
                 pipeline: Optional[SyntheticPipeline] = None):
        self.model = model
        self.shape = shape
        self.policy = policy
        self.tcfg = tcfg
        self.pipeline = pipeline or SyntheticPipeline(model.cfg, shape)
        self.monitor = StragglerMonitor()
        self.history: list = []

        if policy is not None:
            step, in_sh, out_sh, _ = build_train_step(
                model, policy, shape, tcfg.opt)
            self._p_shard, self._o_shard = in_sh[0], in_sh[1]
            self._step = jax.jit(step, in_shardings=in_sh,
                                 out_shardings=out_sh,
                                 donate_argnums=(0, 1))
        else:
            from repro.training.optimizer import adamw_update

            def step(params, opt_state, batch):
                loss, grads = jax.value_and_grad(model.train_loss)(
                    params, batch)
                params, opt_state, metrics = adamw_update(
                    tcfg.opt, params, grads, opt_state)
                return params, opt_state, loss, metrics

            self._p_shard = self._o_shard = None
            self._step = jax.jit(step, donate_argnums=(0, 1))

    # -- state ----------------------------------------------------------------
    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.key(seed))
        opt = adamw_init(params)
        return params, opt

    def try_restore(self, params, opt):
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return params, opt, 0
        params = ckpt.restore_checkpoint(self.tcfg.ckpt_dir, params,
                                         shardings=self._p_shard)
        opt = ckpt.restore_checkpoint(
            pathlib.Path(self.tcfg.ckpt_dir) / "opt", opt,
            shardings=self._o_shard)
        return params, opt, last

    # -- loop -------------------------------------------------------------------
    def run(self, seed: int = 0,
            on_step: Optional[Callable[[int, float], None]] = None):
        params, opt = self.init_state(seed)
        params, opt, start = self.try_restore(params, opt)
        ctx = use_policy(self.policy) if self.policy else _nullctx()
        with ctx:
            for step_i in range(start, self.tcfg.total_steps):
                batch = {k: jax.numpy.asarray(v)
                         for k, v in self.pipeline.batch_at(step_i).items()}
                t0 = time.perf_counter()
                params, opt, loss, metrics = self._step(params, opt, batch)
                loss = float(loss)
                dt = time.perf_counter() - t0
                self.monitor.record(0, dt)
                self.history.append(
                    dict(step=step_i, loss=loss, sec=dt,
                         grad_norm=float(metrics["grad_norm"])))
                if on_step:
                    on_step(step_i, loss)
                if (step_i + 1) % self.tcfg.log_every == 0:
                    print(f"[train] step={step_i + 1} loss={loss:.4f} "
                          f"({dt:.2f}s/step)")
                if (step_i + 1) % self.tcfg.ckpt_every == 0 or \
                        step_i + 1 == self.tcfg.total_steps:
                    ckpt.save_checkpoint(self.tcfg.ckpt_dir, step_i + 1,
                                         params)
                    ckpt.save_checkpoint(
                        pathlib.Path(self.tcfg.ckpt_dir) / "opt",
                        step_i + 1, opt)
        return params, opt


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
