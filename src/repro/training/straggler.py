"""Straggler detection & mitigation hooks.

On a real fleet, per-host step durations feed this monitor; here the same
logic is driven by wall-clock step times (and unit-tested with synthetic
traces).  Mitigations exposed to the trainer:

  * flagging (exclude/replace a persistently slow host at the next elastic
    restart),
  * bounded-staleness accumulation: if the slow host exceeds the deadline,
    the step proceeds with the gradients that arrived (scaled), bounded to
    ``max_stale`` consecutive skips — the standard backup-worker recipe
    adapted to synchronous data parallelism.

ALA tie-in: the step-time EWMA doubles as an online throughput sample that
can be fed back into the benchmark database.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    window: int = 32
    threshold: float = 1.8      # x median => straggler
    max_stale: int = 4          # max consecutive proceed-without


class StragglerMonitor:
    def __init__(self, cfg: Optional[StragglerConfig] = None):
        self.cfg = cfg or StragglerConfig()
        self.durations: Dict[int, Deque[float]] = collections.defaultdict(
            lambda: collections.deque(maxlen=self.cfg.window))
        self.stale: Dict[int, int] = collections.defaultdict(int)

    def record(self, host: int, duration_s: float) -> None:
        self.durations[host].append(duration_s)

    def median_duration(self) -> float:
        allv = [v for q in self.durations.values() for v in q]
        return float(np.median(allv)) if allv else 0.0

    def stragglers(self) -> List[int]:
        med = self.median_duration()
        if med <= 0:
            return []
        out = []
        for host, q in self.durations.items():
            if len(q) >= 4 and float(np.median(q)) > self.cfg.threshold * med:
                out.append(host)
        return sorted(out)

    def should_proceed_without(self, host: int) -> bool:
        """Bounded staleness: proceed if the host hasn't been skipped more
        than max_stale consecutive steps."""
        if self.stale[host] >= self.cfg.max_stale:
            return False
        self.stale[host] += 1
        return True

    def mark_arrived(self, host: int) -> None:
        self.stale[host] = 0
