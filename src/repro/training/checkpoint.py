"""Fault-tolerant sharded checkpointing.

Format: one directory per step containing per-leaf ``.npy`` files plus a
JSON manifest (pytree structure, shapes, dtypes, step).  Writes go to a
``.tmp`` staging dir that is atomically renamed on completion — a crashed
save can never corrupt the latest checkpoint.  Restore is mesh-agnostic:
leaves load host-side and are ``device_put`` against whatever shardings
the *new* mesh prescribes, which is what makes elastic restarts (save on
mesh A, resume on mesh B) work.
"""
from __future__ import annotations

import json
import pathlib
import shutil
from typing import Any, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir, step: int, tree: Any,
                    keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    meta = {"step": step, "treedef": str(treedef), "n_leaves": len(leaves),
            "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind not in "fiub" or orig_dtype == "bfloat16":
            # non-native numpy dtype (bf16, fp8, ...): persist as f32
            arr = arr.astype(np.float32)
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        meta["leaves"].append({"shape": list(arr.shape),
                               "dtype": orig_dtype})
    (tmp / MANIFEST).write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                     # atomic commit
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: pathlib.Path, keep: int) -> None:
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = sorted(ckpt_dir.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir, like: Any, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the (possibly different) current mesh."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoints in {ckpt_dir}"
    d = ckpt_dir / f"step_{step:08d}"
    meta = json.loads((d / MANIFEST).read_text())
    leaves_like, treedef = _flatten(like)
    assert meta["n_leaves"] == len(leaves_like), (
        f"checkpoint has {meta['n_leaves']} leaves, model expects "
        f"{len(leaves_like)}")
    shard_leaves = (None if shardings is None
                    else _flatten(shardings)[0])
    out = []
    for i, ref in enumerate(leaves_like):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        assert tuple(arr.shape) == tuple(ref.shape), (
            f"leaf {i}: ckpt {arr.shape} vs model {ref.shape}")
        arr = jax.numpy.asarray(arr, dtype=ref.dtype)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
