"""Hand-rolled AdamW + schedules (optax is not available offline).

Optimizer state mirrors the param pytree; under ZeRO-1 the (m, v) trees are
additionally sharded over the ``data`` axis (see sharding.tree_shardings
with ``for_opt_state=True``) so per-device optimizer memory scales 1/DP.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(lambda a, b: a + b, sq))


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState,
                 constrain_update=None):
    """Returns (new_params, new_state, metrics).

    ``constrain_update``: optional fn pinning the update tree to the
    ZeRO (data-sharded) layout so the cross-data all-gather happens ONCE
    on the fused delta instead of separately on m-hat and v-hat (perf
    iteration #4 — halves the ZeRO update gather bytes)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(which):
        def f(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m2 = cfg.b1 * m + (1 - cfg.b1) * g
            v2 = cfg.b2 * v + (1 - cfg.b2) * g * g
            if which == "m":
                return m2
            if which == "v":
                return v2
            mhat = m2 / b1c
            vhat = v2 / b2c
            return mhat / (jnp.sqrt(vhat) + cfg.eps) \
                + cfg.weight_decay * p.astype(jnp.float32)
        return f

    # three passes over the tree; XLA CSEs the shared m2/v2 computation
    delta = jax.tree.map(upd("d"), params, grads, state.m, state.v)
    if constrain_update is not None:
        delta = constrain_update(delta)
    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) - lr * d).astype(p.dtype),
        params, delta)
    new_m = jax.tree.map(upd("m"), params, grads, state.m, state.v)
    new_v = jax.tree.map(upd("v"), params, grads, state.m, state.v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr}
