"""Runtime tracers for the contracts static analysis can't see.

``jit-in-loop`` catches the *syntactic* recompile hazard; whether the
pow2 shape-bucketing contract actually holds at runtime (PR 5: growing
online data reuses XLA compiles after warmup) is only observable by
counting compilations.  :func:`assert_max_compiles` is that gate — a
context manager counting XLA compiles via ``jax.monitoring`` events,
used by the online/fleet smoke benchmarks to assert that post-warmup
epochs stay inside a fixed compile budget (the count is recorded in
the BENCH artifact).

Counting mechanics: a single process-global listener (registered
lazily, never unregistered — ``jax.monitoring`` only offers clear-all,
which would nuke other listeners) accumulates two monotone counters,
and each context manager diffs them around its block:

  * ``/jax/core/compile/backend_compile_duration`` — one event per
    actual XLA backend compile.
  * ``/jax/core/compile/jaxpr_to_mlir_module_duration`` — one event
    per lowering.  This is the fallback count: a persistent
    compilation cache can swallow the backend compile, but every new
    (program, shape) still traces and lowers, which is exactly the
    shape-bucketing violation the gate exists to catch.

``CompileReport.count`` is the max of the two — either event firing
means a shape bucket the warmup didn't cover.

:func:`nan_guard` is the second runtime tracer: fit/predict outputs
must never carry NaN (Alg 7/8 would silently propagate it into
confidence scores); +/-inf stays allowed by default because the
degenerate-log sentinel (d_min=inf, confidence=0.0) is a documented
output.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import warnings
from typing import Iterator, Optional

import numpy as np

__all__ = [
    "CompileReport", "CompileBudgetExceeded", "assert_max_compiles",
    "count_compiles", "nan_guard",
]

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_LOWERING_EVENT = "/jax/core/compile/jaxpr_to_mlir_module_duration"


class CompileBudgetExceeded(AssertionError):
    """Raised when a block compiles more XLA programs than budgeted."""


class _CompileCounter:
    __slots__ = ("n_compiles", "n_lowerings", "available")

    def __init__(self) -> None:
        self.n_compiles = 0
        self.n_lowerings = 0
        self.available = False


_COUNTER: Optional[_CompileCounter] = None


def _get_counter() -> _CompileCounter:
    global _COUNTER
    if _COUNTER is None:
        counter = _CompileCounter()
        try:
            from jax import monitoring

            def _on_duration(key: str, duration: float, **kw) -> None:
                if key == _COMPILE_EVENT:
                    counter.n_compiles += 1
                elif key == _LOWERING_EVENT:
                    counter.n_lowerings += 1

            monitoring.register_event_duration_secs_listener(_on_duration)
            counter.available = True
        except Exception:            # jax absent, or the API moved
            counter.available = False
        _COUNTER = counter
    return _COUNTER


@dataclasses.dataclass
class CompileReport:
    """What compiled inside an ``assert_max_compiles`` block."""
    limit: Optional[int] = None
    label: str = ""
    n_compiles: int = 0            # backend compiles (cache misses)
    n_lowerings: int = 0           # jaxpr->MLIR lowerings
    available: bool = True         # jax.monitoring delivered events

    @property
    def count(self) -> int:
        """Effective compile count for the gate: max of backend
        compiles and lowerings (see module docstring)."""
        return max(self.n_compiles, self.n_lowerings)


@contextlib.contextmanager
def assert_max_compiles(n: Optional[int],
                        label: str = "") -> Iterator[CompileReport]:
    """Gate a block to at most ``n`` XLA compilations.

    Yields a :class:`CompileReport` that fills in on exit; raises
    :class:`CompileBudgetExceeded` when the block compiled (or
    re-lowered) more than ``n`` programs.  ``n=None`` counts without
    asserting.  When ``jax.monitoring`` is unavailable the gate
    degrades to a counted no-op with ``report.available = False`` and
    a warning — a missing monitoring API must not turn a perf gate
    into a hard import failure on exotic jax builds.
    """
    counter = _get_counter()
    report = CompileReport(limit=n, label=label,
                           available=counter.available)
    c0, l0 = counter.n_compiles, counter.n_lowerings
    try:
        yield report
    finally:
        report.n_compiles = counter.n_compiles - c0
        report.n_lowerings = counter.n_lowerings - l0
    if not counter.available:
        warnings.warn("assert_max_compiles: jax.monitoring unavailable; "
                      "compile gate not enforced", RuntimeWarning,
                      stacklevel=2)
        return
    if n is not None and report.count > n:
        where = f" [{label}]" if label else ""
        raise CompileBudgetExceeded(
            f"compile budget exceeded{where}: {report.count} > {n} "
            f"(backend_compiles={report.n_compiles}, "
            f"lowerings={report.n_lowerings}) — a shape bucket the "
            f"warmup didn't cover, or jit built inside the hot path")


def count_compiles(label: str = ""):
    """``assert_max_compiles(None)``: count without asserting."""
    return assert_max_compiles(None, label=label)


def _first_bad_leaf(obj, path: str, allow_inf: bool):
    """Depth-first search for a NaN (or inf) leaf; returns its path."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            bad = _first_bad_leaf(v, f"{path}[{k!r}]", allow_inf)
            if bad:
                return bad
        return None
    if isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            bad = _first_bad_leaf(v, f"{path}[{i}]", allow_inf)
            if bad:
                return bad
        return None
    try:
        arr = np.asarray(obj)
    except Exception:
        return None
    if arr.dtype.kind not in "fc":
        return None
    if np.isnan(arr).any():
        return f"{path}: NaN"
    if not allow_inf and np.isinf(arr).any():
        return f"{path}: inf"
    return None


def nan_guard(fn=None, *, label: Optional[str] = None,
              allow_inf: bool = True):
    """Wrap a fit/predict callable so non-finite outputs raise loudly.

    ``FloatingPointError`` names the function and the offending output
    leaf.  ``allow_inf=True`` by default: the Alg 8 degenerate-log
    sentinel legitimately returns (d_min=inf, confidence=0.0); NaN is
    never legitimate.  Usable bare (``@nan_guard``), with options
    (``@nan_guard(allow_inf=False)``), or inline
    (``nan_guard(eng.predict, label="online.predict")(rows)``).
    """
    def deco(f):
        name = label or getattr(f, "__qualname__", repr(f))

        @functools.wraps(f)
        def wrapped(*args, **kwargs):
            out = f(*args, **kwargs)
            bad = _first_bad_leaf(out, "output", allow_inf)
            if bad:
                raise FloatingPointError(
                    f"nan_guard[{name}]: non-finite fit output at "
                    f"{bad}")
            return out

        return wrapped

    return deco(fn) if fn is not None else deco
