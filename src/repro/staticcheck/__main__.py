"""CLI: ``python -m repro.staticcheck [--format=github] [paths...]``.

Exit codes: 0 = no findings, 1 = findings, 2 = bad invocation.  The
``github`` format emits workflow-command annotations that render
inline on the PR diff; CI runs this before the test tiers so contract
violations fail fast with a file:line pointer.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from repro.staticcheck import ALL_RULES, RULES_BY_NAME, check_paths


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="repro-check: contract-aware static analysis")
    parser.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                        help="files or directories to check "
                             "(default: src benchmarks)")
    parser.add_argument("--format", choices=("text", "github"),
                        default="text", dest="fmt",
                        help="finding output style")
    parser.add_argument("--rule", action="append", default=None,
                        metavar="NAME",
                        help="run only the named rule(s)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        width = max(len(r.name) for r in ALL_RULES)
        for r in ALL_RULES:
            print(f"{r.name:<{width}}  {r.description}")
        return 0

    rules = None
    if args.rule:
        unknown = [n for n in args.rule if n not in RULES_BY_NAME]
        if unknown:
            print(f"unknown rule(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2
        rules = [RULES_BY_NAME[n] for n in args.rule]

    missing = [p for p in args.paths if not pathlib.Path(p).exists()]
    if missing:
        print(f"no such path(s): {', '.join(missing)}", file=sys.stderr)
        return 2

    result = check_paths(args.paths, rules=rules)
    for f in result.findings:
        print(f.format(style=args.fmt))
    n = len(result.findings)
    print(f"repro-check: {n} finding{'s' if n != 1 else ''} in "
          f"{result.n_files} files", file=sys.stderr)
    return 1 if result.findings else 0


if __name__ == "__main__":
    sys.exit(main())
