"""Rule registry — one module per contract, one instance per rule.

Adding a rule: create ``rules/<name>.py`` with a ``Rule`` subclass and
a module-level ``RULE`` instance, import it here, append to
``ALL_RULES``, document it in docs/static_analysis.md, and add
positive/negative/suppressed fixtures in tests/test_staticcheck.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.staticcheck.engine import Rule
from repro.staticcheck.rules.banned_solve import RULE as BANNED_SOLVE
from repro.staticcheck.rules.bench_provenance import RULE as BENCH_PROVENANCE
from repro.staticcheck.rules.float64_edges import RULE as FLOAT64_EDGES
from repro.staticcheck.rules.jit_in_loop import RULE as JIT_IN_LOOP
from repro.staticcheck.rules.mutable_default_config import \
    RULE as MUTABLE_DEFAULT_CONFIG
from repro.staticcheck.rules.no_shim_import import RULE as NO_SHIM_IMPORT
from repro.staticcheck.rules.unseeded_rng import RULE as UNSEEDED_RNG
from repro.staticcheck.rules.wallclock_in_sim import RULE as WALLCLOCK_IN_SIM

ALL_RULES: Tuple[Rule, ...] = (
    BANNED_SOLVE,
    NO_SHIM_IMPORT,
    UNSEEDED_RNG,
    WALLCLOCK_IN_SIM,
    BENCH_PROVENANCE,
    FLOAT64_EDGES,
    JIT_IN_LOOP,
    MUTABLE_DEFAULT_CONFIG,
)

RULES_BY_NAME: Dict[str, Rule] = {r.name: r for r in ALL_RULES}

__all__ = ["ALL_RULES", "RULES_BY_NAME"]
