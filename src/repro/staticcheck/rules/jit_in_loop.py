"""jit-in-loop: ``jax.jit`` constructed inside a ``for``/``while`` body.

Contract (PR 5): XLA compiles are paid once per (program, shape
bucket) — the pow2 shape-bucketing idiom exists so growing online data
reuses compiles.  A ``jax.jit(...)`` (or ``functools.partial(jax.jit,
...)``) evaluated *syntactically inside a loop body* builds a fresh
jitted callable every iteration; each carries its own trace cache, so
every iteration recompiles and ``assert_max_compiles`` gates blow up.
The repo idiom is a ``_make_*`` factory or module-level closure that
jits once (``gbt._make_forest_apply``, ``fleet._JaxTraj``).  A
function *defined* inside the loop shields its own jit calls — they
run per call, not per iteration — so only the directly-in-loop case
fires.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.staticcheck.engine import Finding, Rule, dotted_name, parent_map

_JIT = {"jax.jit", "jit"}
_PARTIAL = {"functools.partial", "partial"}


def _is_jit_call(node: ast.Call) -> bool:
    chain = dotted_name(node.func)
    if chain in _JIT:
        return True
    if chain in _PARTIAL and node.args:
        return dotted_name(node.args[0]) in _JIT
    return False


class JitInLoop(Rule):
    name = "jit-in-loop"
    description = ("jax.jit / partial(jax.jit, ...) evaluated inside a "
                   "for/while body (per-iteration recompile)")
    contract = ("compile-once jit placement: XLA compiles are paid per "
                "shape bucket, never per loop iteration")

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        parents: Dict[ast.AST, ast.AST] = parent_map(tree)
        # jit occurrences: call sites, plus bare `@jax.jit` decorators
        # (Attribute, not Call) — those execute in the enclosing scope
        # when the def statement runs, so a decorated def in a loop
        # body recompiles per iteration just like a call would
        occurrences: List[ast.AST] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and _is_jit_call(node):
                occurrences.append(node)
            elif isinstance(node, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)):
                occurrences.extend(
                    deco for deco in node.decorator_list
                    if not isinstance(deco, ast.Call)
                    and dotted_name(deco) in _JIT)
        for node in occurrences:
            child: ast.AST = node
            cur = parents.get(node)
            while cur is not None:
                if isinstance(cur, (ast.FunctionDef,
                                    ast.AsyncFunctionDef, ast.Lambda)):
                    # a decorator executes in the enclosing scope, so a
                    # decorated def inside a loop still jits per
                    # iteration; anything else inside the def is
                    # shielded by the function boundary
                    if child not in getattr(cur, "decorator_list", []):
                        break
                elif isinstance(cur, (ast.For, ast.While)):
                    out.append(self.finding(
                        relpath, node,
                        "jax.jit evaluated inside a loop body builds a "
                        "fresh callable (and trace cache) every "
                        "iteration; hoist it to a _make_* factory or "
                        "module level"))
                    break
                child = cur
                cur = parents.get(cur)
        return out


RULE = JitInLoop()
