"""no-shim-import: in-repo imports of the deprecated ``perfmodel.tpu``.

Contract (PR 8): ``repro.perfmodel.tpu`` survives only as a
DeprecationWarning shim for out-of-tree callers; everything under
``src/`` imports ``repro.perfmodel.hardware`` directly.  This promotes
the old grep-based test in ``tests/test_hardware_transfer.py`` into the
rule engine — same guarantee, one mechanism — and additionally catches
``importlib.import_module("repro.perfmodel.tpu")`` spellings grep could
only see as strings.
"""
from __future__ import annotations

import ast
from typing import List

from repro.staticcheck.engine import Finding, Rule, dotted_name

_SHIM = "repro.perfmodel.tpu"
_SHIM_FILE = "src/repro/perfmodel/tpu.py"
_MSG = ("import repro.perfmodel.hardware instead; the tpu module is a "
        "deprecated out-of-tree shim")


class NoShimImport(Rule):
    name = "no-shim-import"
    description = ("import of the deprecated repro.perfmodel.tpu shim "
                   "inside src/")
    contract = ("single hardware-descriptor module: all in-repo code "
                "prices against repro.perfmodel.hardware")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("src/") and relpath != _SHIM_FILE

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == _SHIM or \
                            alias.name.startswith(_SHIM + "."):
                        out.append(self.finding(relpath, node, _MSG))
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if mod == _SHIM or mod.startswith(_SHIM + "."):
                    out.append(self.finding(relpath, node, _MSG))
                elif mod == "repro.perfmodel" and \
                        any(a.name == "tpu" for a in node.names):
                    out.append(self.finding(relpath, node, _MSG))
            elif isinstance(node, ast.Call):
                chain = dotted_name(node.func)
                if chain in ("importlib.import_module",
                             "import_module", "__import__") and \
                        node.args and \
                        isinstance(node.args[0], ast.Constant) and \
                        isinstance(node.args[0].value, str) and \
                        node.args[0].value.startswith(_SHIM):
                    out.append(self.finding(relpath, node, _MSG))
        return out


RULE = NoShimImport()
