"""mutable-default-config: mutable defaults on dataclass fields.

Contract (PRs 4-9): config dataclasses (``SimConfig``, ``SAConfig``,
``OnlineConfig``, ``ObsConfig``, the frozen ``HardwareProfile`` /
``ModelConfig`` descriptors) are shared freely across benchmark arms
and fleet replicas — two arms mutating one shared default list/dict/
array is exactly the cross-arm contamination the differential parity
harness cannot detect.  The dataclass machinery rejects bare
``list``/``dict``/``set`` *instances* at class-creation time, but a
``field(default=[...])``, an ``np.zeros(...)`` default, or a
constructor call (``dict()``, ``collections.deque()``) slips through
and is shared by every instance.  Use ``field(default_factory=...)``
or an immutable tuple.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.staticcheck.engine import Finding, Rule, dotted_name

_DATACLASS_DECOS = {"dataclass", "dataclasses.dataclass"}
_FIELD_FNS = {"field", "dataclasses.field"}
_MUTABLE_CTORS = {"list", "dict", "set", "bytearray"}
_MUTABLE_ATTRS = {"zeros", "ones", "empty", "full", "array", "deque",
                  "defaultdict", "OrderedDict", "Counter"}


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for deco in cls.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        if dotted_name(target) in _DATACLASS_DECOS:
            return True
    return False


def _mutable_default(node: ast.AST) -> Optional[str]:
    """A description of the mutable value, or None if it is safe."""
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp)):
        return "a mutable literal"
    if isinstance(node, ast.Call):
        chain = dotted_name(node.func)
        if chain in _MUTABLE_CTORS:
            return f"a {chain}() instance"
        if chain and chain.split(".")[-1] in _MUTABLE_ATTRS:
            return f"a {chain}(...) instance"
    return None


class MutableDefaultConfig(Rule):
    name = "mutable-default-config"
    description = ("mutable default value on a dataclass field "
                   "(shared across every instance)")
    contract = ("config isolation: dataclass instances shared across "
                "benchmark arms / replicas never alias mutable state")

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        for cls in ast.walk(tree):
            if not (isinstance(cls, ast.ClassDef) and _is_dataclass(cls)):
                continue
            for stmt in cls.body:
                if isinstance(stmt, ast.AnnAssign):
                    default = stmt.value
                elif isinstance(stmt, ast.Assign):
                    default = stmt.value
                else:
                    continue
                if default is None:
                    continue
                if isinstance(default, ast.Call) and \
                        dotted_name(default.func) in _FIELD_FNS:
                    for kw in default.keywords:
                        if kw.arg == "default":
                            why = _mutable_default(kw.value)
                            if why:
                                out.append(self.finding(
                                    relpath, stmt,
                                    f"field(default=...) holds {why}, "
                                    f"shared by every {cls.name}; use "
                                    f"default_factory"))
                    continue
                why = _mutable_default(default)
                if why:
                    out.append(self.finding(
                        relpath, stmt,
                        f"dataclass field default is {why}, shared by "
                        f"every {cls.name} instance; use "
                        f"field(default_factory=...) or a tuple"))
        return out


RULE = MutableDefaultConfig()
