"""float64-edges: bin-edge construction missing the float32 cast.

Contract (PRs 3/9): ``SubsetBank`` (``core/uncertainty.py``) and
``StreamHist`` (``obs/metrics.py``) share one fixed-bin contract —
edges are **float32**, bin assignment compares float32 values against
float32 edges via ``searchsorted(side="right")``.  An edge array left
in float64 buckets boundary values differently from the jitted bank
kernel (which casts), so serial/batched parity and shard-merge
equality silently drift by one bin.  The rule scopes to the contract
modules and fires on any ``*edges*``-named function (or ``inner_edges``
assignment) that builds arrays without a float32 cast in sight.  The
per-pair *serial reference* edges in ``_feature_bins`` are
intentionally float64 (they are recomputed per query, never shared
with the kernel) and sit outside the naming convention.
"""
from __future__ import annotations

import ast
from typing import List

from repro.staticcheck.engine import Finding, Rule, dotted_name

_CONTRACT_FILES = (
    "src/repro/core/uncertainty.py",
    "src/repro/obs/metrics.py",
    "src/repro/obs/tracing.py",
)
_BUILDERS = ("linspace", "geomspace", "logspace", "arange",
             "concatenate", "asarray", "array")


def _mentions_float32(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "float32":
            return True
        if isinstance(sub, ast.Name) and sub.id == "float32":
            return True
        if isinstance(sub, ast.Constant) and sub.value == "float32":
            return True
    return False


def _builds_array(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = dotted_name(sub.func)
            if chain and chain.split(".")[-1] in _BUILDERS:
                return True
    return False


class Float64Edges(Rule):
    name = "float64-edges"
    description = ("bin-edge construction without a float32 cast in the "
                   "SubsetBank/StreamHist contract modules")
    contract = ("float32 fixed-bin edges: serial, jitted, and "
                "shard-merged histograms bucket boundary values "
                "identically")

    def applies(self, relpath: str) -> bool:
        return relpath in _CONTRACT_FILES

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.FunctionDef) and "edges" in node.name:
                body = ast.Module(body=node.body, type_ignores=[])
                if _builds_array(body) and not _mentions_float32(body):
                    out.append(self.finding(
                        relpath, node,
                        f"{node.name} builds bin edges without a "
                        f"float32 cast; the SubsetBank/StreamHist "
                        f"contract compares float32 values against "
                        f"float32 edges"))
            elif isinstance(node, (ast.Assign, ast.AnnAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                named = any("inner_edges" in (dotted_name(t) or "")
                            for t in targets)
                value = node.value
                if named and value is not None and _builds_array(value) \
                        and not _mentions_float32(value):
                    out.append(self.finding(
                        relpath, node,
                        "inner_edges assigned without a float32 cast; "
                        "edge arrays must be float32 to match the "
                        "bank kernel's bucketize"))
        return out


RULE = Float64Edges()
