"""wallclock-in-sim: wall-clock reads inside simulation code.

Contract (PRs 4/7): simulated time advances only through the event
heap / bucket clock.  ``time.time``, ``time.monotonic``, and
``datetime.now`` inside ``serving/``, ``core/``, or ``perfmodel/``
leak host wall-clock into simulation state, silently breaking replay
determinism and the heap-vs-fleet differential parity suite.
``time.perf_counter`` stays allowed — the fit pipeline uses it for
*reported timings* (``ALA.timings``), never for sim state — and
``bench``/provenance code (``benchmarks/``, ``launch/``, ``obs``
export) is out of scope: stamping artifacts with real wall-clock is
the point there.
"""
from __future__ import annotations

import ast
from typing import List

from repro.staticcheck.engine import Finding, Rule, dotted_name

_SCOPES = ("src/repro/serving/", "src/repro/core/", "src/repro/perfmodel/")
_BANNED = {
    "time.time", "time.monotonic", "time.monotonic_ns", "time.time_ns",
    "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}


class WallclockInSim(Rule):
    name = "wallclock-in-sim"
    description = ("time.time/time.monotonic/datetime.now inside "
                   "serving/, core/, or perfmodel/")
    contract = ("sim-clock purity: simulation state advances only via "
                "the event clock, so identical seeds replay "
                "identically on any host")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(_SCOPES)

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            # the bare-name spelling: `from time import time` makes the
            # later call site indistinguishable from any `time()`, so
            # flag the import itself
            if isinstance(node, ast.ImportFrom) and node.module == "time":
                for alias in node.names:
                    if alias.name in ("time", "monotonic", "time_ns",
                                      "monotonic_ns"):
                        out.append(self.finding(
                            relpath, node,
                            f"`from time import {alias.name}` hides a "
                            f"wall-clock read from the sim-clock "
                            f"contract; import the module and keep "
                            f"wall-clock out of simulation code"))
                continue
            if not isinstance(node, ast.Call):
                continue
            chain = dotted_name(node.func)
            if chain in _BANNED or (chain or "").endswith("datetime.now"):
                out.append(self.finding(
                    relpath, node,
                    f"{chain} reads host wall-clock inside simulation "
                    f"code; use the sim clock (time.perf_counter is "
                    f"allowed for reported fit timings only)"))
        return out


RULE = WallclockInSim()
