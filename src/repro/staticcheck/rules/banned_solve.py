"""banned-solve: dense ``linalg.solve`` outside ``core/fit.py``.

Contract (PR 5): every 3x3 LM solve routes through the closed-form,
batch-invariant ``repro.core.fit._solve3``.  ``jnp.linalg.solve`` (and
the numpy/scipy spellings) use pivoted LAPACK paths whose results
depend on batch composition and backend — which breaks the online
engine's bit-for-bit "untouched groups reuse their params" refit parity
(``update_exponential_database``) and the delta-refit regression tests.
``core/fit.py`` itself is exempt: it owns the one documented
``np.linalg.solve`` fallback inside the scalar reference path.
"""
from __future__ import annotations

import ast
from typing import List

from repro.staticcheck.engine import Finding, Rule, dotted_name

_EXEMPT = "src/repro/core/fit.py"


class BannedSolve(Rule):
    name = "banned-solve"
    description = ("dense linalg.solve outside core/fit.py (use the "
                   "batch-invariant fit._solve3)")
    contract = ("batch-invariant LM solves: untouched (ii,oo) groups "
                "reuse params bit-for-bit across online refits")

    def applies(self, relpath: str) -> bool:
        return relpath != _EXEMPT

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted_name(node.func)
            if chain and chain.endswith(".linalg.solve"):
                out.append(self.finding(
                    relpath, node,
                    f"{chain} is not batch-invariant; route through "
                    f"repro.core.fit._solve3 (only core/fit.py may "
                    f"call linalg.solve)"))
        return out


RULE = BannedSolve()
