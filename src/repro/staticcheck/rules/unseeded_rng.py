"""unseeded-rng: global-state or seedless RNG draws inside ``src/``.

Contract (PRs 4-7): every stochastic stream in the library is an
explicitly seeded ``np.random.default_rng(seed)`` (or a derived
``jax.random`` key) — trace generation, fault plans, SA chains, and
telemetry corruption are all *replayable by construction*, and the
differential heap-vs-fleet parity suite plus the fault-plan
``fingerprint()`` determinism gates depend on it.  Three spellings
break that: legacy ``np.random.<dist>`` global-state calls (shared
mutable stream), stdlib ``random.*`` module functions (same), and
``default_rng()`` with no seed argument (fresh OS entropy per call).
Benchmarks/tests may do what they like; the rule scopes to ``src/``.
"""
from __future__ import annotations

import ast
from typing import List

from repro.staticcheck.engine import Finding, Rule, dotted_name

# np.random members that construct explicit generators/seeds rather
# than drawing from the legacy global stream
_NP_CONSTRUCTORS = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64", "RandomState",
}
# stdlib random module functions (module-level = hidden global state)
_STDLIB_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "seed", "betavariate", "expovariate",
    "normalvariate", "lognormvariate", "triangular", "vonmisesvariate",
    "paretovariate", "weibullvariate", "getrandbits", "randbytes",
}


def _seedless(node: ast.Call) -> bool:
    """No positional seed and no seed= keyword, or an explicit None."""
    if node.args:
        first = node.args[0]
        return isinstance(first, ast.Constant) and first.value is None
    for kw in node.keywords:
        if kw.arg == "seed":
            return isinstance(kw.value, ast.Constant) and \
                kw.value.value is None
    return True


class UnseededRng(Rule):
    name = "unseeded-rng"
    description = ("global-state np.random/<stdlib random> draw or "
                   "seedless default_rng() in src/")
    contract = ("seed-determinism: traces, fault plans, SA chains, and "
                "corruption streams replay bit-identically from their "
                "recorded seeds")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("src/")

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            # bare-name imports make later call sites untraceable:
            # flag `from random import choice` / `from numpy.random
            # import normal` at the import
            if isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    for alias in node.names:
                        if alias.name in _STDLIB_FNS:
                            out.append(self.finding(
                                relpath, node,
                                f"`from random import {alias.name}` "
                                f"pulls in hidden global RNG state; "
                                f"use a seeded default_rng"))
                elif node.module == "numpy.random":
                    for alias in node.names:
                        if alias.name not in _NP_CONSTRUCTORS:
                            out.append(self.finding(
                                relpath, node,
                                f"`from numpy.random import "
                                f"{alias.name}` draws from the global "
                                f"stream; use a seeded default_rng"))
                continue
            if not isinstance(node, ast.Call):
                continue
            chain = dotted_name(node.func)
            if chain is None:
                continue
            if chain.startswith(("np.random.", "numpy.random.")):
                member = chain.rsplit(".", 1)[1]
                if member in ("default_rng", "RandomState"):
                    if _seedless(node):
                        out.append(self.finding(
                            relpath, node,
                            f"{chain}() with no seed draws fresh OS "
                            f"entropy; pass an explicit seed"))
                elif member not in _NP_CONSTRUCTORS:
                    out.append(self.finding(
                        relpath, node,
                        f"{chain} uses numpy's global RNG stream; draw "
                        f"from an explicitly seeded "
                        f"np.random.default_rng(seed)"))
            elif chain.startswith("random.") and chain.count(".") == 1:
                member = chain.split(".", 1)[1]
                if member in _STDLIB_FNS:
                    out.append(self.finding(
                        relpath, node,
                        f"stdlib {chain} uses hidden global state; use "
                        f"a seeded np.random.default_rng(seed)"))
            elif chain == "default_rng" and _seedless(node):
                out.append(self.finding(
                    relpath, node,
                    "default_rng() with no seed draws fresh OS entropy; "
                    "pass an explicit seed"))
        return out


RULE = UnseededRng()
