"""bench-provenance: BENCH artifacts serialized outside ``_write_bench``.

Contract (PR 9): every ``results/BENCH_*.json`` carries a provenance
stamp (git SHA, jax/numpy versions, backend/device, UTC wall-clock,
seed) so any number in a committed artifact answers "which code, which
machine, which run".  ``benchmarks/run.py:_write_bench`` is the single
write path that stamps it; a raw ``json.dump``/``json.dumps`` aimed at
a ``BENCH_*`` file ships an unstamped artifact that the calibration
audit and perf reports can't trace back.
"""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.staticcheck.engine import (Finding, Rule, dotted_name,
                                      enclosing_function, parent_map)

_HELPER = "_write_bench"


def _stmt_mentions_bench(stmt: ast.AST) -> bool:
    for node in ast.walk(stmt):
        if isinstance(node, ast.Constant) and \
                isinstance(node.value, str) and "BENCH_" in node.value:
            return True
    return False


class BenchProvenance(Rule):
    name = "bench-provenance"
    description = ("json.dump of a BENCH_* artifact outside the "
                   "provenance-stamping _write_bench helper")
    contract = ("artifact provenance: every results/BENCH_*.json is "
                "stamped with git SHA, versions, device, and seed")

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        out: List[Finding] = []
        parents: Dict[ast.AST, ast.AST] = parent_map(tree)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            chain = dotted_name(node.func)
            if chain not in ("json.dump", "json.dumps"):
                continue
            if enclosing_function(node, parents) == _HELPER:
                continue
            # climb to the enclosing statement: the filename usually
            # sits beside the dump (write_text / open target / f-string)
            stmt = node
            while stmt in parents and not isinstance(stmt, ast.stmt):
                stmt = parents[stmt]
            if _stmt_mentions_bench(stmt):
                out.append(self.finding(
                    relpath, node,
                    f"{chain} writes a BENCH_* artifact without a "
                    f"provenance stamp; route it through "
                    f"benchmarks/run.py:{_HELPER}"))
        return out


RULE = BenchProvenance()
