"""repro-check: contract-aware static analysis for the ALA stack.

``python -m repro.staticcheck [--format=github] [paths]`` runs the
AST rule engine (engine.py) with the repo's contract rules (rules/)
over every ``*.py`` under the given paths (default: ``src``
``benchmarks``) and exits non-zero on any finding.  The sibling
``tracers`` module holds the *runtime* side of the same contracts:
``assert_max_compiles`` (XLA recompile gates for the pow2
shape-bucketing contract) and ``nan_guard``.

See docs/static_analysis.md for the rule catalog and suppression
syntax (``# repro-check: disable=<rule>``).
"""
from repro.staticcheck.engine import (CheckResult, Finding, Rule,
                                      check_paths, check_source)
from repro.staticcheck.rules import ALL_RULES, RULES_BY_NAME

__all__ = [
    "Finding", "Rule", "CheckResult", "check_source", "check_paths",
    "ALL_RULES", "RULES_BY_NAME",
]
