"""AST rule engine for ``repro-check`` — the repo's contract checker.

The ALA stack's correctness rests on a handful of *implicit* contracts
(batch-invariant solves, single-seeded RNG streams, the float32
fixed-bin edge contract, sim-clock-only simulation code, provenance-
stamped BENCH artifacts, compile-stable jit placement, immutable config
defaults).  Each contract is one :class:`Rule`; the engine parses every
file once, hands the tree to each applicable rule, and filters the
findings through inline suppressions.

Suppression syntax (same line as the finding)::

    delta = np.linalg.solve(A, b)  # repro-check: disable=banned-solve

Multiple rules separate with commas.  A disable comment that suppresses
nothing is itself a finding (``unused-suppression``) — stale waivers
rot into silent contract holes otherwise, so the engine refuses to
carry them.

Rules subclass :class:`Rule` and register in
``repro.staticcheck.rules.ALL_RULES``; the engine never imports the
rules package (rules import the engine), so adding a rule touches only
``rules/``.  See docs/static_analysis.md for the catalog and the
how-to-add-a-rule walkthrough.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "Finding", "Rule", "CheckResult", "check_source", "check_paths",
    "dotted_name", "parent_map", "enclosing_function", "repo_relpath",
]

_SUPPRESS_RE = re.compile(r"#\s*repro-check:\s*disable=([A-Za-z0-9_,\-]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One contract violation at ``path:line:col``."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self, style: str = "text") -> str:
        if style == "github":
            # GitHub Actions workflow-command annotation: renders as an
            # inline error on the PR diff and fails the step via exit
            # code (the CLI handles the exit code)
            return (f"::error file={self.path},line={self.line},"
                    f"col={self.col},title=repro-check[{self.rule}]::"
                    f"{self.message}")
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


class Rule:
    """One machine-checked contract.

    Subclasses set ``name`` (the CLI/suppression identifier),
    ``description`` (one line for ``--list-rules``), and ``contract``
    (the invariant protected — surfaces in docs), then implement
    :meth:`check`.  Override :meth:`applies` to scope the rule to a
    subtree of the repo; ``relpath`` is always posix-style relative to
    the repo root (``src/repro/serving/fleet.py``).
    """

    name: str = ""
    description: str = ""
    contract: str = ""

    def applies(self, relpath: str) -> bool:
        return True

    def check(self, tree: ast.AST, text: str,
              relpath: str) -> List[Finding]:
        raise NotImplementedError

    # ------------------------------------------------------- helpers
    def finding(self, relpath: str, node: ast.AST,
                message: str) -> Finding:
        return Finding(path=relpath, line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0) + 1,
                       rule=self.name, message=message)


# ------------------------------------------------------------------ AST utils
def dotted_name(node: ast.AST) -> Optional[str]:
    """``Attribute``/``Name`` chain as a dotted string, else None.

    ``jnp.linalg.solve`` -> "jnp.linalg.solve"; anything rooted in a
    call/subscript (``foo().bar``) yields None — rules match syntactic
    spelling, not resolved objects.
    """
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    """Child -> parent for every node (the stdlib ast has no uplinks)."""
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def enclosing_function(node: ast.AST,
                       parents: Dict[ast.AST, ast.AST]) -> Optional[str]:
    """Name of the nearest enclosing def, or None at module level."""
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur.name
        cur = parents.get(cur)
    return None


# ------------------------------------------------------------- suppressions
def _parse_suppressions(text: str) -> Dict[int, List[str]]:
    """line -> rule names disabled on that line (source order kept).

    Tokenized, not regexed over raw lines: a disable spelled inside a
    string literal (docs, fixtures) is content, not a waiver.
    """
    out: Dict[int, List[str]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if m:
                out[tok.start[0]] = [n.strip()
                                     for n in m.group(1).split(",")
                                     if n.strip()]
    except tokenize.TokenizeError:
        pass
    return out


def _default_rules() -> Sequence[Rule]:
    from repro.staticcheck.rules import ALL_RULES
    return ALL_RULES


def _default_rules_by_name() -> Dict[str, Rule]:
    from repro.staticcheck.rules import RULES_BY_NAME
    return RULES_BY_NAME


def check_source(text: str, relpath: str,
                 rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run every applicable rule over one file's source.

    Returns the post-suppression findings, including synthesized
    ``unused-suppression`` findings for disable comments that shielded
    nothing, and a ``parse-error`` finding when the file does not parse
    (a file the checker cannot see is a file the contracts do not
    cover).
    """
    if rules is None:
        rules = _default_rules()
    # three tiers of rule-name knowledge for suppression auditing:
    # registry-known names from an unselected rule (CLI --rule subset)
    # pass silently, selected-but-inapplicable or fired-nothing names
    # are stale waivers, and unregistered names are typos
    try:
        registry = set(_default_rules_by_name())
    except Exception:
        registry = set()
    selected = {r.name for r in rules}
    known = registry | selected
    applicable_names = {r.name for r in rules if r.applies(relpath)}
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding(path=relpath, line=e.lineno or 1,
                        col=(e.offset or 0) + 1, rule="parse-error",
                        message=f"file does not parse: {e.msg}")]

    raw: List[Finding] = []
    for rule in rules:
        if rule.applies(relpath):
            raw.extend(rule.check(tree, text, relpath))

    suppress = _parse_suppressions(text)
    kept: List[Finding] = []
    used: Set[Tuple[int, str]] = set()
    for f in raw:
        names = suppress.get(f.line, [])
        if f.rule in names:
            used.add((f.line, f.rule))
        else:
            kept.append(f)
    for line, names in suppress.items():
        for nm in names:
            if (line, nm) in used:
                continue
            if nm not in known:
                kept.append(Finding(
                    path=relpath, line=line, col=1,
                    rule="unused-suppression",
                    message=f"disable names unknown rule '{nm}'"))
            elif nm in selected and nm not in applicable_names:
                kept.append(Finding(
                    path=relpath, line=line, col=1,
                    rule="unused-suppression",
                    message=f"disable={nm} is moot: the rule does not "
                            f"apply to {relpath}; remove the waiver"))
            elif nm in applicable_names:
                kept.append(Finding(
                    path=relpath, line=line, col=1,
                    rule="unused-suppression",
                    message=f"disable={nm} suppresses nothing on this "
                            f"line; remove the stale waiver"))
            # registry-known but unselected (--rule subset): tolerated
    kept.sort()
    return kept


# ------------------------------------------------------------------ walking
def repo_relpath(path: pathlib.Path,
                 root: Optional[pathlib.Path] = None) -> str:
    """Posix path relative to the repo root, for rule scoping.

    The root is detected by walking up from the file to the first
    ancestor holding ``src/repro`` (or a ``.git``); files outside any
    repo fall back to their given spelling — scoped rules then simply
    don't apply, which is the safe direction for a checker.
    """
    path = pathlib.Path(path)
    resolved = path.resolve()
    if root is None:
        for anc in resolved.parents:
            if (anc / "src" / "repro").is_dir() or (anc / ".git").exists():
                root = anc
                break
    if root is not None:
        try:
            return resolved.relative_to(pathlib.Path(root).resolve()) \
                           .as_posix()
        except ValueError:
            pass
    return path.as_posix()


@dataclasses.dataclass
class CheckResult:
    findings: List[Finding]
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.findings


def _iter_py_files(paths: Iterable[pathlib.Path]) -> List[pathlib.Path]:
    files: List[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            files.extend(sorted(f for f in p.rglob("*.py")
                                if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            files.append(p)
    return files


def check_paths(paths: Sequence, rules: Optional[Sequence[Rule]] = None,
                root: Optional[pathlib.Path] = None) -> CheckResult:
    """Check every ``*.py`` under ``paths`` (files or directories)."""
    findings: List[Finding] = []
    files = _iter_py_files(paths)
    for f in files:
        findings.extend(check_source(f.read_text(),
                                     repo_relpath(f, root=root),
                                     rules=rules))
    findings.sort()
    return CheckResult(findings=findings, n_files=len(files))
