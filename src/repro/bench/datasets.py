"""Benchmark-dataset generation (the paper's §III-A experimental setup).

Three datasets mirror the paper's:
  * ``inhouse``   — the paper's ~4,800-point grid over (ii, oo, bb) for one
                    served model (LLaMA-3.1-8B; here on TPU v5e TP=4),
                    5-10 repetitions per combination.
  * ``suite``     — LLM-inference-bench-style: many model families x
                    serving frameworks, bb 1-64, ii/oo 128-2048 (the RQ3
                    "ANL dataset" analog, here over the 10 assigned archs).
  * ``mismatch``  — a model run on a *different* accelerator profile
                    (RQ4's Qwen2-7B-on-Intel-PVC case).

Data comes from the analytical TPU roofline simulator; the real wall-clock
path (timing the actual JAX engine on CPU at tiny scale) is in
repro.bench.harness.
"""
from __future__ import annotations

import itertools
import pathlib
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.configs import ARCHS, get_config
from repro.core.dataset import Dataset
from repro.perfmodel.simulator import ServingSetup, sample_throughput
from repro.perfmodel.hardware import (LEGACY_GPU, PROFILES, TPU_V5E,
                                      feature_row)

DATA_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "data"

INHOUSE_II = (128, 256, 512, 1024, 2048, 4096, 8192, 16384)
INHOUSE_OO = (128, 256, 512, 1024, 2048, 4096)
INHOUSE_BB = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)

SUITE_II = (128, 512, 1024, 2048)
SUITE_OO = (128, 512, 1024, 2048)
SUITE_BB = (1, 2, 4, 8, 16, 32, 64)
FRAMEWORKS = {"vllm-jax": 1.0, "tgi-jax": 0.85, "trt-jax": 1.1}


def _tp_degree(cfg) -> int:
    n = cfg.param_count()
    if n > 1e11:
        return 16
    if n > 1e10:
        return 8
    return 4


def _simulate(model_name: str, hw, grid, reps: int, rng,
              framework: str = "vllm-jax", chips: Optional[int] = None,
              noise_sigma: float = 0.05) -> List[Dict]:
    cfg = get_config(model_name)
    setup = ServingSetup(cfg=cfg, hw=hw, chips=chips or _tp_degree(cfg),
                         framework_eff=FRAMEWORKS[framework])
    # hardware identity (acc) *and* descriptor features: rows from
    # different accelerators key apart in the registry yet stay
    # regressable across hardware via the hw_* columns
    hw_cols = feature_row(hw)
    rows = []
    for ii, oo, bb in grid:
        for t in sample_throughput(setup, ii, oo, bb, reps, rng,
                                   noise_sigma=noise_sigma):
            rows.append(dict(model=model_name, acc=hw.name,
                             acc_count=setup.chips, back=framework,
                             prec="bf16", mode="serve",
                             ii=ii, oo=oo, bb=bb, thpt=float(t),
                             **hw_cols))
    return rows


def make_inhouse_dataset(seed: int = 0, reps: int = 10) -> Dataset:
    rng = np.random.default_rng(seed)
    grid = list(itertools.product(INHOUSE_II, INHOUSE_OO, INHOUSE_BB))
    rows = _simulate("llama3.1-8b", TPU_V5E, grid, reps, rng)
    return Dataset.from_rows(rows)


def make_suite_dataset(seed: int = 1, reps: int = 3,
                       models: Optional[Iterable[str]] = None,
                       frameworks: Optional[Iterable[str]] = None) -> Dataset:
    rng = np.random.default_rng(seed)
    models = list(models or ARCHS)
    frameworks = list(frameworks or FRAMEWORKS)
    grid = list(itertools.product(SUITE_II, SUITE_OO, SUITE_BB))
    rows: List[Dict] = []
    for m in models:
        for fw in frameworks:
            rows.extend(_simulate(m, TPU_V5E, grid, reps, rng, framework=fw))
    return Dataset.from_rows(rows)


def make_mismatch_dataset(seed: int = 2, reps: int = 3,
                          model: str = "qwen3-0.6b") -> Dataset:
    """RQ4: same workload grid, different accelerator profile."""
    rng = np.random.default_rng(seed)
    grid = list(itertools.product(SUITE_II, SUITE_OO, SUITE_BB))
    rows = _simulate(model, LEGACY_GPU, grid, reps, rng, chips=4,
                     noise_sigma=0.08)
    return Dataset.from_rows(rows)


def load_or_make(name: str, **kw) -> Dataset:
    path = DATA_DIR / name
    if path.with_suffix(".npz").exists():
        return Dataset.load(path)
    ds = {"inhouse": make_inhouse_dataset,
          "suite": make_suite_dataset,
          "mismatch": make_mismatch_dataset}[name](**kw)
    ds.save(path)
    return ds


def train_test_split(ds: Dataset, test_frac: float = 0.3, seed: int = 0):
    rng = np.random.default_rng(seed)
    mask = rng.random(len(ds)) < test_frac
    return ds[~mask], ds[mask]
