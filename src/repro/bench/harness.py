"""Real wall-clock benchmarking of the JAX serving engine.

The paper's "custom inference benchmarking framework": sweep (ii, oo, bb),
run each combination ``reps`` times, record tokens/sec.  On this CPU
container it runs tiny smoke-size models (the numbers are real measured
throughput of the actual engine); on TPU the same harness benchmarks the
full configs.  Output rows feed the same ALA pipeline as simulator data —
the framework is agnostic to where thpt came from.
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterable, List, Optional, Sequence

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.dataset import Dataset
from repro.inference.engine import ServingEngine
from repro.models.transformer import Model

CPU_GRID_II = (16, 32, 64)
CPU_GRID_OO = (8, 16)
CPU_GRID_BB = (1, 2, 4, 8, 16)


def measure_arch(arch: str, grid_ii: Optional[Sequence[int]] = None,
                 grid_oo: Optional[Sequence[int]] = None,
                 grid_bb: Optional[Sequence[int]] = None,
                 reps: int = 2, seed: int = 0) -> Dataset:
    """Sweep the engine over a grid; ``None`` grids fall back to the CPU
    smoke defaults, so CLI overrides (``benchmarks/run.py --grid-ii ...``)
    and TPU-scale sweeps share this one code path."""
    grid_ii = CPU_GRID_II if grid_ii is None else tuple(grid_ii)
    grid_oo = CPU_GRID_OO if grid_oo is None else tuple(grid_oo)
    grid_bb = CPU_GRID_BB if grid_bb is None else tuple(grid_bb)
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(seed))
    engine = ServingEngine(model, params)
    rows: List[Dict] = []
    for ii, oo, bb in itertools.product(grid_ii, grid_oo, grid_bb):
        for r in engine.measure_throughput(ii, oo, bb, reps=reps,
                                           seed=seed):
            rows.append(dict(model=arch, acc="cpu-host", acc_count=1,
                             back="repro-jax", prec="fp32", mode="serve",
                             ii=r["ii"], oo=r["oo"], bb=r["bb"],
                             thpt=r["thpt"]))
    return Dataset.from_rows(rows)
