"""Deterministic, resumable synthetic LM data pipeline.

Batches are a pure function of (seed, step): restart at step k reproduces
exactly the batch stream a non-failing run would have seen — the data-side
half of fault tolerance.  The generator synthesizes power-law token
streams with local n-gram structure so the training loss actually
decreases (useful for the end-to-end driver), while remaining fully
offline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.models.config import ModelConfig
from repro.configs.shapes import ShapeSpec


@dataclasses.dataclass
class PipelineConfig:
    seed: int = 0
    zipf_a: float = 1.2          # vocabulary power law
    ngram_order: int = 3
    ngram_strength: float = 0.7  # prob. of following the n-gram process


class SyntheticPipeline:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 pcfg: Optional[PipelineConfig] = None):
        self.cfg = cfg
        self.shape = shape
        self.pcfg = pcfg or PipelineConfig()
        # deterministic n-gram transition hash parameters
        root = np.random.default_rng(self.pcfg.seed)
        self._mix = root.integers(1, 2**31 - 1, size=self.pcfg.ngram_order)
        self._bias = int(root.integers(0, 2**31 - 1))

    def _next_token(self, ctx: np.ndarray, rnd: np.ndarray) -> np.ndarray:
        """Hash-based deterministic 'n-gram LM' over the vocab."""
        v = self.cfg.vocab_size
        h = (ctx @ self._mix + self._bias) % (2**31 - 1)
        ngram_tok = (h % max(v // 8, 2)).astype(np.int32)
        follow = rnd < self.pcfg.ngram_strength
        return np.where(follow, ngram_tok, -1)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.pcfg.seed, step]))
        b = self.shape.global_batch
        s = self.shape.seq_len
        v = self.cfg.vocab_size
        order = self.pcfg.ngram_order
        # base zipf stream (clipped to vocab)
        base = rng.zipf(self.pcfg.zipf_a, size=(b, s + 1)).astype(np.int64)
        toks = (base % v).astype(np.int32)
        # overwrite with n-gram process where 'follow' fires
        rnd = rng.random((b, s + 1))
        for t in range(order, s + 1):
            nxt = self._next_token(toks[:, t - order:t], rnd[:, t])
            toks[:, t] = np.where(nxt >= 0, nxt, toks[:, t])
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.frontend == "vision":
            p = self.cfg.n_patches
            text = s - p
            batch = {"tokens": toks[:, :text], "labels": toks[:, 1:text + 1],
                     "patches": rng.standard_normal(
                         (b, p, self.cfg.d_model)).astype(np.float32)}
        if self.cfg.frontend == "audio":
            batch["frames"] = rng.standard_normal(
                (b, self.cfg.encoder_seq, self.cfg.d_model)
            ).astype(np.float32)
        return batch
