"""Step builders shared by the dry-run, trainer and server.

Each builder returns (step_fn, in_shardings, out_shardings, arg_structs)
ready for ``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import ShardingPolicy, tree_shardings
from repro.distributed.staterules import decode_cache_shardings
from repro.models.io import input_specs
from repro.models.transformer import Model
from repro.training.optimizer import (
    AdamWConfig, AdamWState, adamw_init, adamw_update)


def batch_shardings(policy: ShardingPolicy, specs):
    """Tokens/labels shard over data on dim0; frontend embeds likewise."""
    out = {}
    for name, s in specs.items():
        spec = policy.resolve("act_btd", s.shape)
        out[name] = NamedSharding(policy.mesh, spec)
    return out


def build_train_step(model: Model, policy: ShardingPolicy, shape: ShapeSpec,
                     opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig()
    cfg = model.cfg

    params_s = jax.eval_shape(
        functools.partial(model.init), jax.random.key(0))
    opt_s = jax.eval_shape(adamw_init, params_s)
    in_specs = input_specs(cfg, shape)

    p_shard = tree_shardings(params_s, policy)
    mv_shard = tree_shardings(params_s, policy, for_opt_state=True)

    def constrain_update(delta):
        # keep the fused Adam delta in the ZeRO layout -> one gather
        return jax.tree.map(jax.lax.with_sharding_constraint, delta,
                            mv_shard)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        params, opt_state, metrics = adamw_update(
            opt_cfg, params, grads, opt_state,
            constrain_update=constrain_update)
        return params, opt_state, loss, metrics
    o_shard = AdamWState(step=NamedSharding(policy.mesh, P()),
                         m=mv_shard, v=mv_shard)
    b_shard = batch_shardings(policy, in_specs)
    metric_shard = {"grad_norm": NamedSharding(policy.mesh, P()),
                    "lr": NamedSharding(policy.mesh, P())}
    in_shardings = (p_shard, o_shard, b_shard)
    out_shardings = (p_shard, o_shard, NamedSharding(policy.mesh, P()),
                     metric_shard)
    args = (params_s, opt_s, in_specs)
    return train_step, in_shardings, out_shardings, args


def build_prefill_step(model: Model, policy: ShardingPolicy,
                       shape: ShapeSpec):
    cfg = model.cfg

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len=shape.seq_len)

    params_s = jax.eval_shape(model.init, jax.random.key(0))
    in_specs = input_specs(cfg, shape)
    p_shard = tree_shardings(params_s, policy)
    b_shard = batch_shardings(policy, in_specs)

    cache_s = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    c_shard = decode_cache_shardings(policy, cache_s)
    logits_shard = NamedSharding(
        policy.mesh, policy.resolve_logits(
            (shape.global_batch, 1, cfg.padded_vocab)))
    in_shardings = (p_shard, b_shard)
    out_shardings = (logits_shard, c_shard)
    args = (params_s, in_specs)
    return prefill_step, in_shardings, out_shardings, args


def build_serve_step(model: Model, policy: ShardingPolicy,
                     shape: ShapeSpec):
    """One-token decode against a seq_len-deep cache (the shape's
    ``decode_*`` semantics: one new token, KV cache of seq_len)."""
    cfg = model.cfg

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    params_s = jax.eval_shape(model.init, jax.random.key(0))
    cache_s = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                 filled=shape.seq_len - 1))
    tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)

    p_shard = tree_shardings(params_s, policy)
    c_shard = decode_cache_shardings(policy, cache_s)
    t_shard = NamedSharding(policy.mesh,
                            policy.resolve("act_btd", tok_s.shape))
    logits_shard = NamedSharding(
        policy.mesh, policy.resolve_logits(
            (shape.global_batch, 1, cfg.padded_vocab)))
    in_shardings = (p_shard, c_shard, t_shard)
    out_shardings = (logits_shard, c_shard)
    args = (params_s, cache_s, tok_s)
    return serve_step, in_shardings, out_shardings, args


def build_step(model: Model, policy: ShardingPolicy, shape: ShapeSpec):
    if shape.kind == "train":
        return build_train_step(model, policy, shape)
    if shape.kind == "prefill":
        return build_prefill_step(model, policy, shape)
    if shape.kind == "decode":
        return build_serve_step(model, policy, shape)
    raise ValueError(shape.kind)
