"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 256 chips as (data=16, model=16).
Multi-pod: 2 pods x 256 chips as (pod=2, data=16, model=16); the ``pod``
axis carries only data-parallel gradient all-reduce (DCN-friendly).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real host devices (tests / CPU demos)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axes_of(mesh) -> tuple:
    return ("model",)
