import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the jit'd
step for the production mesh must partition, compile, and report its
memory/cost analysis.  Results accumulate in ``results/dryrun/*.json`` so
the sweep is resumable (one process per cell via --arch/--shape flags, or
an in-process sweep with --all).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k [--multi-pod] [--collectives]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax

from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, cell_applicable, get_shape
from repro.distributed.sharding import ShardingPolicy, use_policy
from repro.launch.mesh import data_axes_of, make_production_mesh
from repro.launch.steps import build_step
from repro.models.transformer import Model

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Match only lines whose RHS *op* is a collective: `%x = <shape> <op>(...)`.
# Fusions that merely consume a collective's result must not count.
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of all array shapes in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind result-shape bytes for every collective in the HLO.

    Async ``-start`` ops return a (operand, dest) tuple; only the dest
    buffer counts.  Ops inside while bodies are counted once (see roofline
    extrapolation in repro.analysis.roofline for trip-count scaling).
    """
    stats: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done(" in line:
            continue
        result, kind, is_start = m.group(1), m.group(2), m.group(3)
        if is_start and result.startswith("("):
            # tuple result: count only the destination (last) shape
            shapes = _SHAPE_RE.findall(result)
            if shapes:
                dt, dims = shapes[-1]
                result = f"{dt}[{dims}]"
        nbytes = _shape_bytes(result)
        e = stats.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += nbytes
    return stats


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             collectives: bool = True, unroll_periods: int = 0,
             save: bool = True, policy_mode: str = "auto") -> dict:
    """Lower+compile one cell; returns the result record.

    ``policy_mode``: "auto" applies the hillclimbed sharding policy
    (TP-only serving weights when they fit, context-parallel serving for
    non-divisible head counts, shard_map EP MoE); "baseline" pins the
    paper-faithful pre-hillclimb policy for §Perf A/B records."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "unroll_periods": unroll_periods, "policy": policy_mode}
    if not ok:
        rec.update(status="skipped", reason=reason)
        _save(rec, save)
        return rec

    t0 = time.time()
    try:
        import jax.numpy as jnp
        mesh = make_production_mesh(multi_pod=multi_pod)
        serving = shape.kind != "train"
        tp = mesh.shape["model"]
        hbm_budget = 11e9
        # hillclimb #1: TP-only weights whenever they fit per-chip HBM —
        # 2D (data x model) weight sharding costs a full weight all-gather
        # per step and is reserved for models too big for TP alone.
        serving_2d = cfg.param_count() * 2 / tp > hbm_budget
        # hillclimb #2: context-parallel serving for archs whose head
        # count doesn't divide the TP width (replicate block weights over
        # model, shard the sequence end-to-end) — only when the replicated
        # weights actually fit alongside activations.
        cp = (serving and not cfg.attention_free
              and cfg.n_heads % tp != 0
              and cfg.param_count() * 2 <= 0.6 * hbm_budget)
        if policy_mode == "baseline":
            policy = ShardingPolicy(mesh, data_axes=data_axes_of(mesh),
                                    serving=serving, serving_2d=True,
                                    cp_replicate_weights=False,
                                    ep_moe=False)
        else:
            policy = ShardingPolicy(mesh, data_axes=data_axes_of(mesh),
                                    serving=serving, serving_2d=serving_2d,
                                    cp_replicate_weights=cp)
        if serving:
            # inference holds bf16 weights, sharded across the full slice
            cfg = cfg.scaled(param_dtype=jnp.bfloat16)
        if unroll_periods:
            overrides = {"n_layers": len(cfg.period) * unroll_periods}
            if cfg.is_encdec:
                overrides["n_encoder_layers"] = unroll_periods
            cfg = cfg.scaled(**overrides)
            model = Model(cfg, unroll=True)
        else:
            model = Model(cfg, remat=(shape.kind == "train"))
        step, in_sh, out_sh, args = build_step(model, policy, shape)
        donate = {"train": (0, 1), "decode": (1,), "prefill": ()}[shape.kind]
        with use_policy(policy):
            jitted = jax.jit(step, in_shardings=in_sh,
                             out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        from repro.distributed.compat import cost_analysis_dict

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            flops=float(cost.get("flops", -1.0)),
            bytes_accessed=float(cost.get("bytes accessed", -1.0)),
            memory={
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes",
                          "output_size_in_bytes",
                          "temp_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)},
        )
        if collectives:
            rec["collectives"] = collective_stats(compiled.as_text())
        print(f"[dryrun] OK {arch} {shape_name} mesh={rec['mesh']} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"flops={rec['flops']:.3g}")
        if shape.kind != "skipped":
            print("  memory:", rec["memory"])
    except Exception as e:  # noqa: BLE001 — record the failure
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[dryrun] FAIL {arch} {shape_name}: {rec['error']}")
    _save(rec, save)
    return rec


def _save(rec: dict, save: bool):
    if not save:
        return
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = "u%d" % rec["unroll_periods"] if rec.get("unroll_periods") else ""
    if rec.get("policy") == "baseline":
        tag += "__pbase"
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    (RESULTS / name.replace("/", "_")).write_text(json.dumps(rec, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=[s.name for s in SHAPES])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--unroll-periods", type=int, default=0,
                    help="compile an unrolled depth-N variant (roofline)")
    ap.add_argument("--no-collectives", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="compile u1+u2 unrolled variants for every "
                         "applicable single-pod cell")
    ap.add_argument("--policy", choices=("auto", "baseline"),
                    default="auto")
    args = ap.parse_args()

    if args.roofline:
        n_fail = 0
        for arch in ARCHS:
            for shape in SHAPES:
                for u in (1, 2):
                    rec = run_cell(arch, shape.name, multi_pod=False,
                                   collectives=True, unroll_periods=u,
                                   policy_mode=args.policy)
                    n_fail += rec["status"] == "error"
        print(f"[dryrun] roofline sweep done fail={n_fail}")
        raise SystemExit(1 if n_fail else 0)

    if args.all:
        n_ok = n_skip = n_fail = 0
        for multi_pod in (False, True):
            for arch in ARCHS:
                for shape in SHAPES:
                    rec = run_cell(arch, shape.name, multi_pod=multi_pod,
                                   collectives=not args.no_collectives,
                                   policy_mode=args.policy)
                    n_ok += rec["status"] == "ok"
                    n_skip += rec["status"] == "skipped"
                    n_fail += rec["status"] == "error"
        print(f"[dryrun] sweep done ok={n_ok} skip={n_skip} fail={n_fail}")
        raise SystemExit(1 if n_fail else 0)

    assert args.arch and args.shape, "--arch/--shape or --all"
    rec = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   collectives=not args.no_collectives,
                   unroll_periods=args.unroll_periods,
                   policy_mode=args.policy)
    raise SystemExit(0 if rec["status"] in ("ok", "skipped") else 1)


if __name__ == "__main__":
    main()
