"""Discrete-event continuous-batching serving simulator.

Each replica is a continuous-batching engine over the roofline step-time
primitives of ``repro.perfmodel.simulator``: an iteration is either a
*prefill step* (admits up to ``max_prefill_requests`` waiting requests,
costed by ``prefill_step_time`` over their heterogeneous prompt lengths)
or a *decode step* (every running sequence emits one token, costed by
``decode_step_time_group`` over their current contexts).  Prefill is
prioritized — the vLLM-style default.  KV memory is accounted per
``HardwareProfile``: a request reserves ``ii + oo`` tokens of KV at
admission (no mid-flight eviction), bounded by ``kv_capacity_tokens``.

The fleet layer routes arrivals to the least-loaded active replica and
fires a control event every ``control_interval_s``; a policy object
(see ``repro.serving.autoscaler``) observes the last window and sets the
replica count and the per-replica admission batch cap.  New replicas
come up after ``provision_delay_s``; scale-down drains (stops routing,
finishes in-flight work).  Every event pops through one seeded,
counter-tiebroken heap, so a run is exactly reproducible.

Fault injection (``SimConfig.faults`` = a ``serving.faults``
``FaultInjector``): replica crash/restart windows enter the same event
heap.  A crash loses the replica's KV state — in-flight sequences are
requeued to surviving replicas under a bounded retry budget
(``max_retries``) with deadline-based shedding (``shed_after_s``);
restarts pay ``restart_warmup_s`` through the provisioning path before
serving again.  Straggler windows multiply that replica's step times.
Every admitted request ends as exactly one of completed / shed
(``SimResult.check_conservation`` enforces it), and shed requests count
as SLO misses in *both* ``slo_attainment`` and ``ttft_percentile``.

Metrics: per-request TTFT / TPOT / E2E (+ retry/shed accounting), fleet
goodput, TTFT-SLO attainment (shed and unfinished requests count as
misses), replica-seconds (cost), availability under faults, and the raw
step log consumed by ``repro.serving.adapter``.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import (RingLog, percentile_with_inf,  # noqa: F401
                               tenant_rollup)
from repro.perfmodel.simulator import (ServingSetup, decode_step_time_group,
                                       kv_capacity_tokens, prefill_step_time)
from repro.serving.faults import FaultEvent
from repro.serving.traces import Trace, TraceRequest

# percentile_with_inf moved to repro.obs.metrics; re-exported here
# (and imported downstream as before) for API stability.

_ARRIVAL, _STEP_DONE, _CONTROL, _PROVISION, _CRASH, _RESTORE = range(6)


@dataclasses.dataclass
class SimConfig:
    setup: ServingSetup
    batch_cap: int = 64
    max_prefill_requests: int = 8
    n_replicas: int = 1
    max_replicas: int = 8
    # heterogeneous fleets: per-slot hardware.  Replica rid takes
    # ``replica_setups[rid % len(replica_setups)]`` (cycling keeps
    # autoscaler-created replicas deterministic); None -> every replica
    # runs ``setup``.  A policy Action naming a hardware profile
    # overrides the slot default for replicas it creates.
    replica_setups: Optional[Tuple[ServingSetup, ...]] = None
    control_interval_s: float = 2.0
    provision_delay_s: float = 1.0
    drain_s: float = 120.0            # grace period past the horizon
    kv_capacity_override: Optional[float] = None  # tokens; None -> profile
    # epochal streaming: start the clock (and the first control tick)
    # at an offset so a Trace.slice with absolute arrival times replays
    # as one epoch of a longer run instead of idling from t = 0
    t_start: float = 0.0
    # fault injection (see repro.serving.faults)
    faults: Optional[object] = None   # FaultInjector; None -> fault-free
    max_retries: int = 2              # crash requeues per request
    shed_after_s: Optional[float] = None  # age limit at requeue; None -> off
    # vectorized fleet engine (see repro.serving.fleet): admissions are
    # quantized to bucket boundaries — the documented parity tolerance
    bucket_s: float = 0.25
    traj_backend: str = "numpy"       # "numpy" | "jax" decode-run math

    # observability hook (repro.obs.tracing.ObsConfig); None -> no span
    # capture, unbounded telemetry buffers (typed loosely like `faults`
    # to keep this module import-light)
    obs: Optional[object] = None

    def setup_for(self, rid: int, hardware: Optional[str] = None
                  ) -> ServingSetup:
        """Resolve the ServingSetup for replica ``rid``.

        ``hardware`` (a ``repro.perfmodel.hardware`` profile name, e.g.
        from ``Action.hardware``) overrides the slot default's
        accelerator while keeping the model/parallelism unchanged."""
        base = (self.replica_setups[rid % len(self.replica_setups)]
                if self.replica_setups else self.setup)
        if hardware is not None and hardware != base.hw.name:
            from repro.perfmodel.hardware import profile
            base = dataclasses.replace(base, hw=profile(hardware))
        return base

    def kv_cap_for(self, setup: ServingSetup) -> float:
        # kv_capacity_override is uniform across hardware — it models a
        # software cap (e.g. a scheduler limit), not HBM size
        return (self.kv_capacity_override
                if self.kv_capacity_override is not None
                else kv_capacity_tokens(setup))

    def slot_setups(self) -> Tuple[ServingSetup, ...]:
        return tuple(self.replica_setups) if self.replica_setups \
            else (self.setup,)


@dataclasses.dataclass
class RequestRecord:
    rid: int
    ii: int
    oo: int
    arrival_s: float
    tenant: str = ""
    replica: int = -1
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    retries: int = 0                  # crash-driven requeues
    shed: bool = False                # dropped: never completed
    shed_s: Optional[float] = None
    shed_reason: str = ""             # oversized|retry_budget|deadline|unserved

    @property
    def completed(self) -> bool:
        return self.done_s is not None

    @property
    def ttft_s(self) -> float:
        return (self.first_token_s - self.arrival_s
                if self.first_token_s is not None else float("inf"))

    @property
    def e2e_s(self) -> float:
        return (self.done_s - self.arrival_s if self.done_s is not None
                else float("inf"))

    @property
    def tpot_s(self) -> float:
        if self.done_s is None or self.first_token_s is None:
            return float("inf")
        return (self.done_s - self.first_token_s) / max(self.oo - 1, 1)


@dataclasses.dataclass
class StepRecord:
    t_end: float
    replica: int
    kind: str                          # "prefill" | "decode"
    bb: int
    duration_s: float
    tokens_out: int


class _Seq:
    __slots__ = ("rec", "generated")

    def __init__(self, rec: RequestRecord):
        self.rec = rec
        self.generated = 0

    @property
    def context(self) -> int:
        return self.rec.ii + self.generated


class Replica:
    def __init__(self, rid: int, setup: ServingSetup, batch_cap: int,
                 max_prefill: int, kv_capacity: float):
        self.rid = rid
        self.setup = setup
        self.batch_cap = batch_cap
        self.max_prefill = max_prefill
        self.kv_capacity = kv_capacity
        self.waiting: Deque[_Seq] = collections.deque()
        self.running: List[_Seq] = []
        self.prefilling: List[_Seq] = []
        self.kv_reserved = 0.0
        self.busy = False
        self.draining = False
        self.active = True            # provisioned and routable
        self.provisioning = False     # _PROVISION event in flight
        self.failed = False           # crashed: down until its restore
        self.restore_to_active = True  # what the restore should bring back
        self.incarnation = 0          # bumps on crash; stale steps ignored

    @property
    def load(self) -> int:
        return len(self.waiting) + len(self.running) + len(self.prefilling)

    def _kv_need(self, s: _Seq) -> float:
        return float(s.rec.ii + s.rec.oo)

    def fail(self) -> Tuple[List["_Seq"], List["_Seq"]]:
        """Crash: lose all KV state.  Returns (in-flight, queued) — the
        in-flight sequences lost computed KV (a retry), the queued ones
        merely need rerouting.  Any step completion already in the heap
        belongs to the old incarnation and is ignored when it pops."""
        self.restore_to_active = (self.active or self.provisioning) \
            and not self.draining
        inflight = list(self.prefilling) + list(self.running)
        queued = list(self.waiting)
        self.prefilling, self.running = [], []
        self.waiting.clear()
        self.kv_reserved = 0.0
        self.busy = False
        self.active = False
        self.provisioning = False
        self.draining = False
        self.failed = True
        self.incarnation += 1
        return inflight, queued

    def begin_step(self) -> Optional[Tuple[float, str]]:
        """Pick the next iteration; returns (duration, kind) or None."""
        admit: List[_Seq] = []
        while (self.waiting and len(admit) < self.max_prefill
               and len(self.running) + len(admit) < self.batch_cap
               and self.kv_reserved + self._kv_need(self.waiting[0])
               <= self.kv_capacity):
            s = self.waiting.popleft()
            self.kv_reserved += self._kv_need(s)
            admit.append(s)
        if admit:
            self.prefilling = admit
            dur = prefill_step_time(self.setup,
                                    [s.rec.ii for s in admit])
            return dur, "prefill"
        if self.running:
            dur = decode_step_time_group(self.setup,
                                         [s.context for s in self.running])
            return dur, "decode"
        return None

    def finish_step(self, kind: str, t_end: float) -> List[RequestRecord]:
        """Apply a completed iteration; returns finished request records."""
        done: List[RequestRecord] = []
        if kind == "prefill":
            for s in self.prefilling:
                s.generated = 1
                s.rec.first_token_s = t_end
                if s.generated >= s.rec.oo:
                    s.rec.done_s = t_end
                    self.kv_reserved -= self._kv_need(s)
                    done.append(s.rec)
                else:
                    self.running.append(s)
            self.prefilling = []
        else:
            still: List[_Seq] = []
            for s in self.running:
                s.generated += 1
                if s.generated >= s.rec.oo:
                    s.rec.done_s = t_end
                    self.kv_reserved -= self._kv_need(s)
                    done.append(s.rec)
                else:
                    still.append(s)
            self.running = still
        return done


@dataclasses.dataclass
class Observation:
    """What a control policy sees at each control tick."""
    now: float
    window_s: float
    n_arrivals: int
    mean_ii: float                    # over window arrivals (0 if none)
    mean_oo: float
    arrival_rate: float               # req/s over the window
    queue_len: int                    # fleet-wide waiting requests
    n_running: int
    n_active_replicas: int
    batch_cap: int
    decode_tokens: int                # emitted in window, fleet-wide
    busy_s: float                     # summed step time in window
    measured_tok_s: float             # decode_tokens / busy_s (0 if idle)
    n_failed_replicas: int = 0        # crashed replicas currently down


@dataclasses.dataclass
class Action:
    n_replicas: int
    batch_cap: int
    # hardware profile name for replicas this action *creates* (scale-up
    # beyond warm/decommissioned capacity).  None -> the slot default
    # from SimConfig.setup_for.  Existing replicas never migrate.
    hardware: Optional[str] = None


@dataclasses.dataclass
class SimResult:
    records: List[RequestRecord]
    steps: List[StepRecord]
    sim_end_s: float
    n_events: int
    replica_seconds: float
    controls: List[Tuple[float, Action]]
    t_start: float = 0.0              # epochal replay offset (absolute)
    availability: float = 1.0         # healthy / (healthy + crashed) rs
    fault_log: List[FaultEvent] = dataclasses.field(default_factory=list)
    # rid -> hardware profile name; heterogeneous fleets use this to
    # attribute steps/requests to the hardware that served them
    replica_hw: Dict[int, str] = dataclasses.field(default_factory=dict)
    # observability (cfg.obs): span table, ring-buffer drop accounting,
    # and lossless step aggregates that survive any sample dropping
    spans: Optional[object] = None    # repro.obs.tracing.SpanTable
    steps_dropped: int = 0            # step records evicted by the ring cap
    faults_dropped: int = 0           # fault events evicted by the ring cap
    step_totals: Optional[Dict[str, float]] = None  # n/busy_s/tokens_out

    @property
    def hardware_names(self) -> Tuple[str, ...]:
        return tuple(sorted(set(self.replica_hw.values())))

    @property
    def completed(self) -> List[RequestRecord]:
        return [r for r in self.records if r.completed]

    @property
    def shed(self) -> List[RequestRecord]:
        return [r for r in self.records if r.shed]

    @property
    def n_retries(self) -> int:
        return sum(r.retries for r in self.records)

    def accounting(self) -> Dict[str, int]:
        return {"admitted": len(self.records),
                "completed": len(self.completed),
                "shed": len(self.shed)}

    def check_conservation(self) -> None:
        """Every admitted request must end as exactly one of completed /
        shed — none lost, none double-counted.  Raises on violation;
        the fault_engine smoke run turns this into a CI gate."""
        acc = self.accounting()
        both = sum(1 for r in self.records if r.completed and r.shed)
        if both or acc["completed"] + acc["shed"] != acc["admitted"]:
            raise RuntimeError(
                f"request conservation violated: {acc}, "
                f"completed&shed overlap={both}")

    def slo_attainment(self, ttft_slo_s: float) -> float:
        """Fraction of admitted requests whose first token arrived in
        time.  Shed and never-completed requests are explicit misses —
        the same convention ``ttft_percentile`` uses, so the two metrics
        always agree about failed requests."""
        if not self.records:
            return 1.0
        ok = sum(1 for r in self.records
                 if not r.shed and r.first_token_s is not None
                 and r.ttft_s <= ttft_slo_s)
        return ok / len(self.records)

    @property
    def goodput_tok_s(self) -> float:
        toks = sum(r.oo for r in self.completed)
        # elapsed span, not absolute clock: an epochal replay starting
        # at t_start must not count the pre-epoch offset as serving time
        return toks / max(self.sim_end_s - self.t_start, 1e-9)

    def ttft_percentile(self, q: float, on_missing: str = "inf") -> float:
        """TTFT percentile over admitted requests.

        Shed / never-first-token requests contribute ``inf`` by default
        — consistent with ``slo_attainment`` counting them as misses.
        ``on_missing="drop"`` restores the completed-only view (useful
        for plotting finite tails), but the default never lets a run
        that shed half its traffic report a rosy p95."""
        if on_missing not in ("inf", "drop"):
            raise ValueError(f"on_missing {on_missing!r}: 'inf' or 'drop'")
        vals = self._ttft_values()
        if on_missing == "drop":
            vals = vals[np.isfinite(vals)]
        return percentile_with_inf(vals, q)

    # -- fleet-level meta-metrics -------------------------------------------
    def _ttft_values(self) -> np.ndarray:
        """Per-admitted-request TTFT with inf for shed / no-first-token —
        the miss convention shared by slo_attainment and percentiles."""
        return np.array([float("inf") if (r.shed or r.first_token_s is None)
                         else r.ttft_s for r in self.records], np.float64)

    def _tenant_arrays(self):
        """(tenant, oo, completed, shed, retries) columns feeding the
        shared rollup; the fleet result overrides this with its raw
        arrays instead of materializing records."""
        recs = self.records
        return (np.array([r.tenant for r in recs], object),
                np.array([r.oo for r in recs], np.int64),
                np.array([r.completed for r in recs], bool),
                np.array([r.shed for r in recs], bool),
                np.array([r.retries for r in recs], np.int64))

    def per_tenant(self, slo_map: Optional[Dict[str, float]] = None
                   ) -> Dict[str, Dict[str, float]]:
        """Per-tenant request accounting, TTFT tail and SLO attainment.

        ``slo_map`` maps tenant name -> TTFT SLO seconds (e.g.
        ``FleetTraceConfig.slo_map``); tenants absent from the map get
        ``attainment = nan``.  Shed requests count as misses and as inf
        TTFT, exactly like the fleet-wide metrics.  ``goodput_share`` is
        the tenant's fraction of completed output tokens.  One shared
        rollup (``repro.obs.metrics.tenant_rollup``) serves both
        engines."""
        tenant, oo, completed, shed, retries = self._tenant_arrays()
        return tenant_rollup(tenant, self._ttft_values(), oo, completed,
                             shed, retries, slo_map)

    def meta_metrics(self, slo_map: Optional[Dict[str, float]] = None
                     ) -> Dict[str, object]:
        """Fleet-level scorecard (after "Meta-Metrics and Best Practices
        for System-Level Inference Performance Benchmarking"): request
        accounting, goodput, availability, shed/retry rates, per-tenant
        breakdown, Jain fairness across per-tenant attainment, and the
        fleet attainment where each request is scored against its own
        tenant's SLO tier."""
        pt = self.per_tenant(slo_map)
        acc = self.accounting()
        n = max(acc["admitted"], 1)
        att = [v["attainment"] for v in pt.values()
               if np.isfinite(v["attainment"])]
        if att and sum(a * a for a in att) > 0:
            jain = (sum(att) ** 2) / (len(att) * sum(a * a for a in att))
        else:
            jain = 1.0
        if slo_map:
            fleet_att = sum(v["attainment"] * v["n_requests"]
                            for v in pt.values()
                            if np.isfinite(v["attainment"])) / n
        else:
            fleet_att = float("nan")
        return {
            "n_requests": acc["admitted"],
            "n_completed": acc["completed"],
            "n_shed": acc["shed"],
            "shed_rate": acc["shed"] / n,
            "retry_rate": self.n_retries / n,
            "goodput_tok_s": self.goodput_tok_s,
            "availability": self.availability,
            "replica_seconds": self.replica_seconds,
            "fleet_attainment": fleet_att,
            "jain_fairness": float(jain),
            "per_tenant": pt,
        }


class FleetSimulator:
    def __init__(self, trace: Trace, cfg: SimConfig, policy=None):
        self.trace = trace
        self.cfg = cfg
        self.policy = policy
        # admission bound: a request that cannot fit the *largest* slot's
        # KV can never be served anywhere; per-replica fit is re-checked
        # at dispatch (heterogeneous fleets have smaller replicas too)
        self.kv_cap = max(cfg.kv_cap_for(s) for s in cfg.slot_setups())

    def _new_replica(self, rid: int, active: bool = True,
                     hardware: Optional[str] = None) -> Replica:
        setup = self.cfg.setup_for(rid, hardware)
        r = Replica(rid, setup, self.cfg.batch_cap,
                    self.cfg.max_prefill_requests,
                    self.cfg.kv_cap_for(setup))
        r.active = active
        return r

    def run(self) -> SimResult:
        cfg = self.cfg
        replicas = [self._new_replica(i)
                    for i in range(max(cfg.n_replicas, 1))]
        records: Dict[int, RequestRecord] = {}
        obs_cfg = cfg.obs if (cfg.obs is not None
                              and getattr(cfg.obs, "enabled", True)) \
            else None
        step_cap = getattr(obs_cfg, "max_steps", None)
        fault_cap = getattr(obs_cfg, "max_fault_events", None)
        steps: List[StepRecord] = RingLog(step_cap) if step_cap else []
        controls: List[Tuple[float, Action]] = []
        fault_log: List[FaultEvent] = RingLog(fault_cap) if fault_cap \
            else []
        # lossless step aggregates (survive ring-cap drops)
        tot_steps, tot_busy, tot_tokens = 0, 0.0, 0
        heap: List[Tuple[float, int, int, object]] = []
        tick = 0

        steps_in_flight = 0

        def push(t: float, kind: int, payload=None):
            nonlocal tick, steps_in_flight
            heapq.heappush(heap, (t, kind, tick, payload))
            tick += 1
            if kind == _STEP_DONE:
                steps_in_flight += 1

        for req in self.trace.requests:
            push(req.arrival_s, _ARRIVAL, req)
        n_pending = len(self.trace.requests)
        if self.policy is not None and cfg.control_interval_s > 0:
            push(cfg.t_start + cfg.control_interval_s, _CONTROL, None)

        inj = cfg.faults
        warmup_s = float(inj.cfg.restart_warmup_s) if inj is not None \
            else 0.0
        if inj is not None:
            # crash windows enter the same heap as everything else; a
            # window straddling t_start starts the replica down.  Ids
            # beyond the live fleet are ignored at pop time (the plan
            # covers max_replicas, the fleet may be smaller).
            for w in inj.crash_windows():
                if w.replica >= cfg.max_replicas or w.t_up <= cfg.t_start:
                    continue
                push(max(w.t_down, cfg.t_start), _CRASH, w.replica)
                push(w.t_up, _RESTORE, w.replica)

        # per-window accumulators for Observation
        win = dict(arrivals=0, ii=0, oo=0, tokens=0, busy=0.0,
                   last=cfg.t_start)
        n_events = 0
        now, replica_seconds, last_t = cfg.t_start, 0.0, cfg.t_start
        failed_seconds = 0.0
        deadline = self.trace.horizon_s + cfg.drain_s

        def maybe_start(r: Replica):
            if r.busy:
                return
            got = r.begin_step()
            if got is not None:
                dur, kind = got
                if inj is not None:
                    dur *= inj.slow_factor(r.rid, now)
                r.busy = True
                push(now + dur, _STEP_DONE, (r, kind, dur, r.incarnation))

        def shed(rec: RequestRecord, t: float, reason: str):
            nonlocal n_pending
            rec.shed = True
            rec.shed_s = t
            rec.shed_reason = reason
            n_pending -= 1

        def dispatch(rec: RequestRecord):
            # crashed replicas take no new work; fall back progressively.
            # Heterogeneous fleets: a candidate must have enough KV for
            # the whole sequence — if no live replica fits it (e.g. the
            # only large-memory replica crashed), shed as oversized.
            need = float(rec.ii + rec.oo)
            cands = None
            for pool in (
                    [r for r in replicas
                     if r.active and not r.draining and not r.failed],
                    [r for r in replicas if r.active and not r.failed],
                    [r for r in replicas if not r.failed],
                    replicas):
                fit = [r for r in pool if need <= r.kv_capacity]
                if fit:
                    cands = fit
                    break
            if cands is None:
                shed(rec, now, "oversized")
                return
            tgt = min(cands, key=lambda r: (r.load, r.rid))
            rec.replica = tgt.rid
            tgt.waiting.append(_Seq(rec))
            maybe_start(tgt)

        def route(req: TraceRequest):
            rec = RequestRecord(rid=req.rid, ii=req.ii, oo=req.oo,
                                arrival_s=req.arrival_s, tenant=req.tenant)
            records[req.rid] = rec
            if req.ii + req.oo > self.kv_cap:
                # can never fit any replica's KV: shed at admission
                # (SLO miss) instead of head-of-line blocking
                shed(rec, now, "oversized")
                return
            dispatch(rec)

        def requeue_or_shed(s: _Seq, t: float):
            """A crash displaced this sequence: retry on a healthy
            replica within budget + deadline, else shed."""
            rec = s.rec
            if rec.retries > cfg.max_retries:
                shed(rec, t, "retry_budget")
                return
            if cfg.shed_after_s is not None \
                    and t - rec.arrival_s > cfg.shed_after_s:
                shed(rec, t, "deadline")
                return
            # KV (and any generated tokens) died with the replica: the
            # retry restarts generation, so TTFT restarts too (no
            # streaming resume across replicas)
            rec.first_token_s = None
            dispatch(rec)

        def apply_action(act: Action):
            act = Action(n_replicas=int(np.clip(act.n_replicas, 1,
                                                cfg.max_replicas)),
                         batch_cap=max(int(act.batch_cap), 1),
                         hardware=act.hardware)
            n_active = sum(1 for r in replicas
                           if r.active and not r.draining)
            if act.n_replicas > n_active:
                need = act.n_replicas - n_active
                # un-drain warm replicas first, then re-provision
                # decommissioned ones, then create fresh
                for r in replicas:
                    if need and r.active and r.draining:
                        r.draining = False
                        need -= 1
                for r in replicas:
                    if need and not r.active and not r.provisioning \
                            and not r.failed:
                        r.draining = False
                        r.provisioning = True
                        push(now + cfg.provision_delay_s, _PROVISION, r)
                        need -= 1
                for _ in range(need):
                    nr = self._new_replica(len(replicas), active=False,
                                           hardware=act.hardware)
                    nr.provisioning = True
                    replicas.append(nr)
                    push(now + cfg.provision_delay_s, _PROVISION, nr)
            elif act.n_replicas < n_active:
                # drain the highest-index active replicas
                for r in sorted(replicas, key=lambda r: -r.rid):
                    if n_active <= act.n_replicas:
                        break
                    if r.active and not r.draining:
                        r.draining = True
                        if not r.busy and r.load == 0:
                            r.active = False      # nothing to drain
                        n_active -= 1
            for r in replicas:    # after scale-up, so new replicas get it
                r.batch_cap = act.batch_cap
            return act

        while heap:
            t, kind, _, payload = heapq.heappop(heap)
            if t > deadline:
                break
            n_active = sum(1 for r in replicas if r.active)
            n_failed = sum(1 for r in replicas if r.failed)
            replica_seconds += n_active * (t - last_t)
            failed_seconds += n_failed * (t - last_t)
            last_t = now = t
            n_events += 1
            if kind == _ARRIVAL:
                req = payload
                win["arrivals"] += 1
                win["ii"] += req.ii
                win["oo"] += req.oo
                route(req)
            elif kind == _STEP_DONE:
                steps_in_flight -= 1
                r, skind, dur, inc = payload
                if inc != r.incarnation:
                    continue          # step of a crashed incarnation
                r.busy = False
                n_pre = len(r.prefilling)
                finished = r.finish_step(skind, t)
                n_pending -= len(finished)
                # every participant of the step emitted exactly one token
                toks = (len(r.running) + len(finished)
                        if skind == "decode" else n_pre)
                steps.append(StepRecord(t_end=t, replica=r.rid, kind=skind,
                                        bb=toks, duration_s=dur,
                                        tokens_out=toks))
                tot_steps += 1
                tot_busy += dur
                tot_tokens += toks
                win["tokens"] += toks
                win["busy"] += dur
                maybe_start(r)
                if r.draining and not r.busy and r.load == 0:
                    r.active = False              # drained dry: decommission
            elif kind == _CRASH:
                if payload >= len(replicas):
                    continue          # plan covers more replicas than live
                r = replicas[payload]
                if r.failed:
                    continue          # overlapping windows: already down
                inflight, queued = r.fail()
                fault_log.append(FaultEvent(t=t, kind="crash",
                                            replica=r.rid,
                                            n_displaced=len(inflight)
                                            + len(queued)))
                for s in inflight:
                    s.rec.retries += 1            # computed KV was lost
                    requeue_or_shed(s, t)
                for s in queued:
                    requeue_or_shed(s, t)         # rerouted, not a retry
            elif kind == _RESTORE:
                if payload >= len(replicas):
                    continue
                r = replicas[payload]
                if not r.failed:
                    continue
                r.failed = False
                fault_log.append(FaultEvent(t=t, kind="restore",
                                            replica=r.rid))
                if r.restore_to_active:
                    # restart pays a warm-up through the provisioning path
                    if warmup_s > 0:
                        r.provisioning = True
                        push(t + warmup_s, _PROVISION, r)
                    else:
                        r.active = True
                        maybe_start(r)
            elif kind == _PROVISION:
                if payload.failed:
                    # crashed while provisioning/warming: stay down — the
                    # restore (or the autoscaler) re-arms it later
                    payload.provisioning = False
                    continue
                payload.provisioning = False
                if not payload.draining:   # drained meanwhile: stay down
                    payload.active = True
                    maybe_start(payload)
            elif kind == _CONTROL:
                w = max(t - win["last"], 1e-9)
                n_arr = win["arrivals"]
                obs = Observation(
                    now=t, window_s=w, n_arrivals=n_arr,
                    mean_ii=win["ii"] / n_arr if n_arr else 0.0,
                    mean_oo=win["oo"] / n_arr if n_arr else 0.0,
                    arrival_rate=n_arr / w,
                    queue_len=sum(len(r.waiting) for r in replicas),
                    n_running=sum(len(r.running) + len(r.prefilling)
                                  for r in replicas),
                    n_active_replicas=sum(1 for r in replicas
                                          if r.active and not r.draining),
                    batch_cap=replicas[0].batch_cap,
                    decode_tokens=win["tokens"], busy_s=win["busy"],
                    measured_tok_s=(win["tokens"] / win["busy"]
                                    if win["busy"] > 0 else 0.0),
                    n_failed_replicas=sum(1 for r in replicas if r.failed))
                act = apply_action(self.policy.control(obs))
                controls.append((t, act))
                win = dict(arrivals=0, ii=0, oo=0, tokens=0, busy=0.0,
                           last=t)
                if t + cfg.control_interval_s < self.trace.horizon_s:
                    push(t + cfg.control_interval_s, _CONTROL, None)
            if n_pending <= 0 and steps_in_flight == 0:
                break

        ordered = [records[r.rid] for r in self.trace.requests]
        # whatever is still in flight when the horizon + drain expires
        # was never served: shed it explicitly so admitted == completed
        # + shed holds unconditionally (request conservation)
        for rec in ordered:
            if not rec.completed and not rec.shed:
                shed(rec, now, "unserved")
        denom = replica_seconds + failed_seconds
        res = SimResult(records=ordered, steps=steps, sim_end_s=now,
                        n_events=n_events, replica_seconds=replica_seconds,
                        controls=controls, t_start=cfg.t_start,
                        availability=(replica_seconds / denom
                                      if denom > 0 else 1.0),
                        fault_log=fault_log,
                        replica_hw={r.rid: r.setup.hw.name
                                    for r in replicas})
        res.steps_dropped = getattr(steps, "n_dropped", 0)
        res.faults_dropped = getattr(fault_log, "n_dropped", 0)
        res.step_totals = {"n": tot_steps, "busy_s": tot_busy,
                           "tokens_out": tot_tokens}
        if obs_cfg is not None:
            from repro.obs.tracing import record_spans
            res.spans = record_spans(res, obs_cfg)
        return res


def simulate(trace: Trace, cfg: SimConfig, policy=None,
             engine: str = "heap") -> SimResult:
    """Run a trace through one of the two engines.

    ``engine="heap"`` is the event-heap reference above; ``"fleet"`` is
    the vectorized time-bucketed engine (``repro.serving.fleet``) — same
    semantics, admissions quantized to ``cfg.bucket_s`` boundaries, and
    orders of magnitude faster on large traces."""
    if engine == "heap":
        return FleetSimulator(trace, cfg, policy).run()
    if engine == "fleet":
        from repro.serving.fleet import VectorFleetSimulator
        return VectorFleetSimulator(trace, cfg, policy).run()
    raise KeyError(f"unknown engine {engine!r}; known: heap, fleet")
