"""Control policies for the serving fleet: static baseline + ALA-in-the-loop.

``ALAAutoscaler`` generalizes ``inference.scheduler.plan_batch_size`` to
the dynamic setting.  At every control tick it:

  1. observes the window's arrival rate and mean request shape;
  2. asks ALA for per-replica throughput at each candidate batch cap and
     for the (predicted error, confidence) of that workload region
     (Alg 5 + Alg 8);
  3. derates low-confidence predictions through the shared
    ``derate_confidence`` safety factor — the PR-3 degenerate sentinel
    (``confidence == 0.0``) never divides by zero, it *falls back to the
    measured rate* from the last window instead (and to the maximally
    derated prediction when the fleet was idle);
  4. sizes the fleet: ``replicas = ceil(demand / (util_target * supply))``
    where demand is the window's output-token arrival rate, plus a queue
    drain term so backlogs clear within roughly one control interval.

``StaticPolicy`` is the static-bb baseline the benchmark compares
against: fixed replica count, fixed admission cap, no feedback.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.ala import ALA
from repro.inference.scheduler import derate_confidence
from repro.serving.simulator import Action, Observation


@dataclasses.dataclass
class StaticPolicy:
    """No-op controller: whatever it was told at construction, forever."""
    n_replicas: int = 1
    batch_cap: int = 64

    def control(self, obs: Observation) -> Action:
        return Action(n_replicas=self.n_replicas, batch_cap=self.batch_cap)


@dataclasses.dataclass
class ALAAutoscaler:
    ala: ALA
    candidate_bb: Tuple[int, ...] = (8, 16, 32, 64, 128)
    confidence_floor: float = 0.7
    min_derate: float = 0.25
    util_target: float = 0.75         # provision 1/util_target headroom
    min_replicas: int = 1
    max_replicas: int = 8
    # diagnostics: (confidence, derate, used_fallback) per control tick
    log: list = dataclasses.field(default_factory=list)

    def _predict_per_replica(self, ii: float, oo: float
                             ) -> Tuple[int, float, float]:
        """(best bb, predicted tok/s at it, confidence of the region)."""
        bbs = np.asarray(self.candidate_bb, np.float64)
        thpt = self.ala.predict(np.full(len(bbs), ii),
                                np.full(len(bbs), oo), bbs)
        conf = 1.0
        if self.ala.error_model is not None and self.ala.sa_log is not None:
            q = (np.full(len(bbs), ii), np.full(len(bbs), oo), bbs,
                 np.full(len(bbs), np.nan))
            _, conf = self.ala.estimate(q)
        i = int(np.argmax(thpt))
        return int(bbs[i]), float(thpt[i]), float(conf)

    def control(self, obs: Observation) -> Action:
        if obs.n_arrivals == 0:
            # idle window: hold the fleet, nothing to infer demand from
            return Action(n_replicas=obs.n_active_replicas,
                          batch_cap=obs.batch_cap)
        bb, pred, conf = self._predict_per_replica(obs.mean_ii, obs.mean_oo)
        derate = derate_confidence(conf, self.confidence_floor,
                                   self.min_derate)
        fallback = conf <= 0.0 and obs.measured_tok_s > 0.0
        if fallback:
            # degenerate sentinel: trust what the fleet actually served
            supply = obs.measured_tok_s
        else:
            supply = pred * derate
        self.log.append((float(conf), float(derate), bool(fallback)))
        # demand: fresh output tokens/s plus draining the standing queue
        demand = obs.arrival_rate * obs.mean_oo
        backlog = (obs.queue_len * obs.mean_oo) / max(obs.window_s, 1e-9)
        need = (demand + backlog) / max(self.util_target * supply, 1e-9)
        n = int(np.clip(int(np.ceil(need)), self.min_replicas,
                        self.max_replicas))
        return Action(n_replicas=n, batch_cap=bb)
