"""Control policies for the serving fleet: static baseline + ALA-in-the-loop.

``ALAAutoscaler`` generalizes ``inference.scheduler.plan_batch_size`` to
the dynamic setting.  At every control tick it:

  1. observes the window's arrival rate and mean request shape;
  2. asks ALA for per-replica throughput at each candidate batch cap and
     for the (predicted error, confidence) of that workload region
     (Alg 5 + Alg 8);
  3. derates low-confidence predictions through the shared
    ``derate_confidence`` safety factor — the PR-3 degenerate sentinel
    (``confidence == 0.0``) never divides by zero, it *falls back to the
    measured rate* from the last window instead (and to the maximally
    derated prediction when the fleet was idle);
  4. sizes the fleet: ``replicas = ceil(demand / (util_target * supply))``
    where demand is the window's output-token arrival rate, plus a queue
    drain term so backlogs clear within roughly one control interval.

Streaming mode: attach a ``repro.core.online.OnlineALA`` (``online`` +
``combo``) and the autoscaler (a) rebinds to the engine's freshest fit
for its combination at every tick — a mid-run refit takes effect on the
next control decision — and (b) accumulates tick-level drift evidence
(median APE of measured vs predicted throughput at the current batch
cap, and Alg 8 confidence) over a rolling window; when the evidence
crosses the thresholds it calls ``online.request_refit`` so the next
epoch ingest recalibrates even under the ``refit="drift"`` policy.
Recalibration requests are logged in ``recalibrations``.

Graceful degradation under faults: predictions are sanity-checked (a
non-finite or non-positive supply falls back to the measured rate, or
holds the fleet when there is nothing measured); sustained
degenerate/low-confidence ticks trigger an exponential backoff during
which the controller stops trusting the model entirely and sizes from
measured throughput only; and scale-*down* requires
``scale_down_patience`` consecutive ticks of evidence, so a
crash-restart flap (capacity dips, the controller scales up, the
replica restores, capacity jumps) does not thrash the replica count.
The controller always plans against *healthy* capacity: crashed
replicas are excluded from ``Observation.n_active_replicas`` by the
simulator, so the absolute target it returns is a healthy-replica
target and the fleet provisions replacements for the dead.

Heterogeneous placement: give the controller a ``hardware_pool`` of
profile names and it additionally decides *which hardware* scale-up
replicas should run on.  Each candidate's throughput is the fitted
prediction times an optional analytic ``hardware_scale`` ratio (the
roofline transfer scaler of ``repro.core.registry``), and its
confidence is the Alg 8 region confidence *re-squashed with the
hardware-descriptor distance* from the fitted hardware
(``repro.core.uncertainty.confidence_from_dmin``) — so a faraway
accelerator must promise proportionally more derated throughput to win
the placement.  The winner rides out on ``Action.hardware``;
``placement="roundrobin"`` is the hardware-blind baseline that cycles
the pool without consulting predictions.

``StaticPolicy`` is the static-bb baseline the benchmark compares
against: fixed replica count, fixed admission cap, no feedback.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.ala import ALA
from repro.inference.scheduler import derate_confidence
from repro.serving.simulator import Action, Observation


@dataclasses.dataclass
class StaticPolicy:
    """No-op controller: whatever it was told at construction, forever."""
    n_replicas: int = 1
    batch_cap: int = 64

    def control(self, obs: Observation) -> Action:
        return Action(n_replicas=self.n_replicas, batch_cap=self.batch_cap)


@dataclasses.dataclass
class ALAAutoscaler:
    ala: ALA
    candidate_bb: Tuple[int, ...] = (8, 16, 32, 64, 128)
    confidence_floor: float = 0.7
    min_derate: float = 0.25
    util_target: float = 0.75         # provision 1/util_target headroom
    min_replicas: int = 1
    max_replicas: int = 8
    # diagnostics: (confidence, derate, used_fallback) per control tick
    log: list = dataclasses.field(default_factory=list)
    # streaming mode: online engine + this fleet's combination key
    online: Optional[object] = None       # repro.core.online.OnlineALA
    combo: Optional[Tuple[str, ...]] = None
    drift_window: int = 6                 # ticks of evidence before acting
    drift_ape_threshold: float = 50.0     # median window APE (%) trigger
    drift_conf_floor: float = 0.05        # median window confidence trigger
    # (t, median_ape, median_conf) per requested recalibration
    recalibrations: list = dataclasses.field(default_factory=list)
    # graceful degradation: backoff after sustained unreliable ticks,
    # hysteresis against crash-restart flapping
    backoff_after: int = 3            # consecutive unreliable ticks to arm
    backoff_base: int = 2             # ticks held on first backoff
    backoff_cap: int = 16             # doubling stops here
    backoff_conf_floor: float = 0.05  # conf below this counts as unreliable
    scale_down_patience: int = 2      # consecutive shrink-wanting ticks
    # coarse time-bucketed stepping can deliver a control tick whose
    # window collapsed to (near) zero width; rates computed over it are
    # meaningless, so the controller holds the fleet instead
    min_window_s: float = 1e-6
    # (t, kind) per degradation action: "backoff" | "hold_down" |
    # "zero_window"
    degradations: list = dataclasses.field(default_factory=list)
    # heterogeneous placement: candidate hardware (profile names) for
    # replicas this controller *creates*.  Empty -> hardware-agnostic
    # (Action.hardware stays None, slot defaults apply).
    hardware_pool: Tuple[str, ...] = ()
    # hardware the ALA database was fitted on; cross-hardware candidates
    # are derated by descriptor distance from it.  None -> distance 0.
    fitted_hardware: Optional[str] = None
    # optional analytic scalers: profile name -> f(ii, oo, bb) ->
    # throughput multiplier vs the fitted hardware (see
    # repro.core.registry roofline transfer)
    hardware_scale: Optional[dict] = None
    placement: str = "aware"          # "aware" | "roundrobin" (blind)
    # (t, hardware, derated score) per placement decision
    placements: list = dataclasses.field(default_factory=list)
    # observability (repro.obs): an ObsConfig and/or a CalibrationAudit.
    # Passing `obs` with no audit builds one; every control tick then
    # lands in the audit as a typed "tick" event (predicted vs measured
    # throughput, Alg 7 predicted error, Alg 8 confidence) alongside the
    # degradation / recalibration decision events.
    obs: Optional[object] = None          # repro.obs.tracing.ObsConfig
    audit: Optional[object] = None        # repro.obs.CalibrationAudit
    # ring-cap for log/recalibrations/degradations/placements; None ->
    # unbounded (falls back to obs.max_log_entries when obs is set)
    max_log_entries: Optional[int] = None
    _rr_idx: int = dataclasses.field(default=0, repr=False)
    _last_pred_err: float = dataclasses.field(default=float("nan"),
                                              repr=False)
    _resid: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=64), repr=False)
    _generation: int = dataclasses.field(default=0, repr=False)
    _unreliable_streak: int = dataclasses.field(default=0, repr=False)
    _backoff_left: int = dataclasses.field(default=0, repr=False)
    _backoff_len: int = dataclasses.field(default=0, repr=False)
    _down_streak: int = dataclasses.field(default=0, repr=False)

    def __post_init__(self) -> None:
        if self.audit is None and self.obs is not None \
                and getattr(self.obs, "enabled", True):
            from repro.obs.calibration import CalibrationAudit
            self.audit = CalibrationAudit(cfg=self.obs)
        cap = self.max_log_entries or getattr(self.obs, "max_log_entries",
                                              None)
        if cap:
            from repro.obs.metrics import RingLog
            self.log = RingLog(cap, self.log)
            self.recalibrations = RingLog(cap, self.recalibrations)
            self.degradations = RingLog(cap, self.degradations)
            self.placements = RingLog(cap, self.placements)

    def _degrade(self, t: float, kind: str) -> None:
        self.degradations.append((t, kind))
        if self.audit is not None:
            self.audit.event(t, "degradation", reason=kind)

    def _refresh_online(self) -> None:
        """Rebind to the engine's freshest fit for our combination —
        how a mid-run recalibration reaches the control loop.  The
        engine refits ALA objects *in place*, so recalibrations are
        detected through its generation counter, not object identity."""
        if self.online is None or self.combo is None:
            return
        gen = self.online.generation_of(self.combo)
        if gen != self._generation:
            self._generation = gen
            fresh = self.online.ala_for(self.combo)
            if fresh is not None:
                self.ala = fresh
            self._resid.clear()       # evidence against the old fit

    def _note_drift(self, obs: Observation, conf: float) -> None:
        """Tick-level drift evidence: measured vs predicted throughput at
        the batch size the fleet is *actually running* (the admission
        cap would overstate throughput on a lightly loaded fleet and
        read as permanent drift), plus the Alg 8 confidence."""
        if obs.measured_tok_s <= 0.0 or obs.n_running <= 0:
            return
        bb_now = min(max(obs.n_running
                         / max(obs.n_active_replicas, 1), 1.0),
                     float(obs.batch_cap))
        pred = float(self.ala.predict([obs.mean_ii], [obs.mean_oo],
                                      [bb_now])[0])
        # a poisoned fit predicting NaN/inf is maximal drift evidence,
        # not a reason to go quiet — count it as an unbounded residual
        ape = (abs(obs.measured_tok_s - pred) / max(abs(pred), 1e-9)
               * 100.0 if np.isfinite(pred) else float("inf"))
        self._resid.append((ape, conf))
        if self.audit is not None:
            # the predict->observe->trust audit record: Alg 4 prediction
            # vs the realized window, with Alg 7's own error estimate
            # (captured by the last _predict_per_replica) riding along
            self.audit.tick(obs.now, predicted=pred,
                            measured=obs.measured_tok_s, confidence=conf,
                            ape=ape, pred_err=self._last_pred_err)
        if self.online is None or self.combo is None:
            return
        if len(self._resid) < self.drift_window:
            return
        recent = list(self._resid)[-self.drift_window:]
        med_ape = float(np.median([a for a, _ in recent]))
        med_conf = float(np.median([c for _, c in recent]))
        if med_ape > self.drift_ape_threshold \
                or med_conf < self.drift_conf_floor:
            self.online.request_refit(self.combo)
            self.recalibrations.append((obs.now, med_ape, med_conf))
            if self.audit is not None:
                self.audit.event(obs.now, "recalibration",
                                 median_ape=med_ape,
                                 median_confidence=med_conf)
            self._resid.clear()

    def _predict_per_replica(self, ii: float, oo: float
                             ) -> Tuple[int, float, float]:
        """(best bb, predicted tok/s at it, confidence of the region)."""
        bbs = np.asarray(self.candidate_bb, np.float64)
        thpt = np.asarray(self.ala.predict(np.full(len(bbs), ii),
                                           np.full(len(bbs), oo), bbs),
                          np.float64)
        conf = 1.0
        self._last_pred_err = float("nan")
        if self.ala.error_model is not None and self.ala.sa_log is not None:
            q = (np.full(len(bbs), ii), np.full(len(bbs), oo), bbs,
                 np.full(len(bbs), np.nan))
            pred_err, conf = self.ala.estimate(q)
            self._last_pred_err = float(pred_err)   # Alg 7 predicted APE
        # a corrupted fit can emit NaN/inf/negative throughput; never let
        # argmax pick it — if nothing valid remains, report the
        # degenerate sentinel so the caller falls back to measured rates
        thpt = np.where(np.isfinite(thpt), thpt, -np.inf)
        if not (thpt > 0.0).any():
            return int(bbs[-1]), float("nan"), 0.0
        i = int(np.argmax(thpt))
        return int(bbs[i]), float(thpt[i]), float(conf)

    def _choose_hardware(self, obs: Observation, bb: int, pred: float,
                         conf: float) -> Tuple[str, float, float]:
        """Pick the hardware for scale-up replicas.

        Returns ``(profile name, predicted tok/s on it, transferred
        confidence)``.  The score is the transfer-scaled prediction
        derated by the *cross-hardware* confidence: the fitted-hardware
        region distance re-squashed with the descriptor distance
        (identical hardware keeps ``conf`` exactly)."""
        pool = self.hardware_pool
        if self.placement == "roundrobin":
            # hardware-blind baseline: cycle the pool, never consult
            # predictions or descriptor distances
            name = pool[self._rr_idx % len(pool)]
            self._rr_idx += 1
            self.placements.append((obs.now, name, float("nan")))
            return name, pred, conf
        from repro.core.uncertainty import confidence_from_dmin
        from repro.perfmodel.hardware import PROFILES, hardware_distance
        # invert the Alg 8 squash to recover the region's workload
        # distance, then re-squash per candidate with its hw distance
        d_min = (1.0 / conf - 1.0) if np.isfinite(conf) and conf > 0.0 \
            else float("inf")
        best = None
        for name in pool:
            if self.fitted_hardware is None or name == self.fitted_hardware:
                d_hw = 0.0
            elif self.fitted_hardware in PROFILES and name in PROFILES:
                d_hw = hardware_distance(PROFILES[self.fitted_hardware],
                                         PROFILES[name])
            else:
                d_hw = float("inf")   # unknown descriptor: no trust
            conf_hw = confidence_from_dmin(d_min, hw_dist=d_hw)
            scale = 1.0
            if self.hardware_scale and name in self.hardware_scale:
                scale = float(self.hardware_scale[name](
                    obs.mean_ii, obs.mean_oo, float(bb)))
            pred_hw = pred * scale
            score = pred_hw * derate_confidence(
                conf_hw, self.confidence_floor, self.min_derate)
            if best is None or score > best[0]:
                best = (score, name, pred_hw, conf_hw)
        score, name, pred_hw, conf_hw = best
        self.placements.append((obs.now, name, float(score)))
        return name, pred_hw, conf_hw

    def control(self, obs: Observation) -> Action:
        self._refresh_online()
        if obs.window_s < self.min_window_s:
            # degenerate zero-width window (coarse bucketed stepping):
            # arrival_rate/backlog terms would divide by ~0 — hold
            self._degrade(obs.now, "zero_window")
            return Action(n_replicas=max(obs.n_active_replicas,
                                         self.min_replicas),
                          batch_cap=obs.batch_cap)
        if obs.n_arrivals == 0:
            # idle window: hold the fleet, nothing to infer demand from
            return Action(n_replicas=obs.n_active_replicas,
                          batch_cap=obs.batch_cap)
        bb, pred, conf = self._predict_per_replica(obs.mean_ii, obs.mean_oo)
        self._note_drift(obs, conf)
        hw_choice = None
        if self.hardware_pool:
            hw_choice, pred_hw, conf_hw = self._choose_hardware(
                obs, bb, pred, conf)
            if self.placement == "aware" and np.isfinite(pred_hw) \
                    and pred_hw > 0.0:
                # size the fleet against the hardware we will actually
                # provision, at its transferred confidence
                pred, conf = pred_hw, conf_hw
        # --- backoff bookkeeping: sustained unreliable ticks arm an
        # exponential hold during which the model is not consulted ------
        unreliable = (not np.isfinite(pred)) or pred <= 0.0 \
            or (not np.isfinite(conf)) or conf <= self.backoff_conf_floor
        if unreliable:
            self._unreliable_streak += 1
        else:
            self._unreliable_streak = 0
            self._backoff_len = 0
        in_backoff = False
        if self._backoff_left > 0:
            self._backoff_left -= 1
            in_backoff = True
        elif self._unreliable_streak >= self.backoff_after:
            self._backoff_len = int(min(
                max(2 * self._backoff_len, self.backoff_base),
                self.backoff_cap))
            self._backoff_left = self._backoff_len - 1
            self._unreliable_streak = 0
            in_backoff = True
            self._degrade(obs.now, "backoff")
        derate = derate_confidence(conf, self.confidence_floor,
                                   self.min_derate)
        fallback = obs.measured_tok_s > 0.0 and (
            conf <= 0.0 or in_backoff
            or not np.isfinite(pred) or pred <= 0.0)
        if fallback:
            # degenerate sentinel / backoff: trust what the fleet served
            supply = obs.measured_tok_s
            if in_backoff:
                bb = obs.batch_cap    # don't re-plan the cap off the model
        else:
            supply = pred * derate
        if not np.isfinite(supply) or supply <= 0.0:
            # poisoned prediction and nothing measured: hold the fleet
            self.log.append((float(conf), float(derate), True))
            return Action(n_replicas=max(obs.n_active_replicas,
                                         self.min_replicas),
                          batch_cap=obs.batch_cap)
        self.log.append((float(conf), float(derate), bool(fallback)))
        # demand: fresh output tokens/s plus draining the standing queue
        demand = obs.arrival_rate * obs.mean_oo
        backlog = (obs.queue_len * obs.mean_oo) / max(obs.window_s, 1e-9)
        need = (demand + backlog) / max(self.util_target * supply, 1e-9)
        n = int(np.clip(int(np.ceil(need)), self.min_replicas,
                        self.max_replicas))
        # --- scale-down hysteresis: a crash-restart flap reads as a
        # capacity dip then a jump; require sustained evidence to shrink
        cur = max(obs.n_active_replicas, self.min_replicas)
        if n < cur:
            self._down_streak += 1
            if self._down_streak < self.scale_down_patience:
                self._degrade(obs.now, "hold_down")
                n = cur
        else:
            self._down_streak = 0
        return Action(n_replicas=n, batch_cap=bb, hardware=hw_choice)
