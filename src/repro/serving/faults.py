"""Seed-deterministic fault injection for the serving fleet.

Real fleets lose replicas, straggle, and emit garbage telemetry; the
paper's promise of *robust* statistical prediction is only credible if
the serving loop survives all three.  This module builds replayable
fault timelines the same way ``serving.traces`` builds request
timelines: one ``np.random.default_rng(seed)`` drives every draw, so a
``FaultPlan`` is a pure function of its config and two builds at the
same seed are bit-identical (pinned by ``tests/test_fault_injection.py``
and recorded as a timeline digest in ``results/BENCH_faults.json``).

Three fault classes:

  * **crash/restart cycles** — per-replica exponential MTTF/MTTR draws
    produce ``CrashWindow(replica, t_down, t_up)`` outages.  The
    simulator loses the replica's KV state at ``t_down`` (in-flight
    sequences requeue under a bounded retry budget + deadline shedding)
    and pays ``restart_warmup_s`` after ``t_up`` before the replica
    serves again.
  * **straggler windows** — per-replica Poisson-arriving
    ``StragglerWindow(replica, t0, t1, slow)`` spans during which every
    step on that replica runs ``slow``× longer (thermal throttling,
    noisy neighbours, collective stragglers).
  * **telemetry corruption** — ``corrupt_rows`` mangles the adapter's
    window rows on their way to the online engine: rows are dropped,
    duplicated, NaN/inf-poisoned, or scale-poisoned (finite but wildly
    wrong throughput — the dangerous direction for an autoscaler is
    *optimistic* corruption, so scale poison is biased upward).  The
    returned ``CorruptionReport`` marks exactly which rows a perfect
    filter would have removed, which is what the quarantine parity
    tests compare the robust-ingestion gate against.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    seed: int = 0
    horizon_s: float = 60.0
    n_replicas: int = 8               # plan covers replica ids [0, n)
    # crash/restart: exponential MTTF / MTTR per replica
    mttf_s: float = float("inf")      # inf -> no crashes
    mttr_s: float = 5.0
    restart_warmup_s: float = 1.0     # paid after t_up, before serving
    # transient stragglers: Poisson windows per replica
    straggler_rate_hz: float = 0.0    # windows / second / replica
    straggler_dur_s: float = 5.0      # mean (exponential) window length
    straggler_slow: float = 3.0       # step-time multiplier inside a window
    # telemetry corruption: per-row probabilities on the adapter stream
    drop_p: float = 0.0
    dup_p: float = 0.0
    poison_nan_p: float = 0.0
    poison_scale_p: float = 0.0
    poison_scale: float = 50.0        # magnitude of finite scale poison


@dataclasses.dataclass(frozen=True)
class CrashWindow:
    replica: int
    t_down: float
    t_up: float


@dataclasses.dataclass(frozen=True)
class StragglerWindow:
    replica: int
    t0: float
    t1: float
    slow: float


@dataclasses.dataclass
class FaultEvent:
    """One entry of ``SimResult.fault_log`` — what actually fired."""
    t: float
    kind: str                         # "crash" | "restore" | "warm"
    replica: int
    n_displaced: int = 0


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    cfg: FaultConfig
    crashes: Tuple[CrashWindow, ...]
    stragglers: Tuple[StragglerWindow, ...]

    @classmethod
    def build(cls, cfg: FaultConfig) -> "FaultPlan":
        """Deterministic timeline from config + seed.  Replicas are drawn
        in id order from one RNG, so the plan replays exactly."""
        rng = np.random.default_rng(cfg.seed)
        crashes: List[CrashWindow] = []
        if np.isfinite(cfg.mttf_s) and cfg.mttf_s > 0:
            for r in range(cfg.n_replicas):
                t = float(rng.exponential(cfg.mttf_s))
                while t < cfg.horizon_s:
                    down = float(rng.exponential(cfg.mttr_s))
                    crashes.append(CrashWindow(replica=r, t_down=t,
                                               t_up=t + down))
                    t += down + float(rng.exponential(cfg.mttf_s))
        stragglers: List[StragglerWindow] = []
        if cfg.straggler_rate_hz > 0:
            for r in range(cfg.n_replicas):
                t = float(rng.exponential(1.0 / cfg.straggler_rate_hz))
                while t < cfg.horizon_s:
                    dur = float(rng.exponential(cfg.straggler_dur_s))
                    stragglers.append(StragglerWindow(
                        replica=r, t0=t, t1=t + dur,
                        slow=float(cfg.straggler_slow)))
                    t += dur + float(
                        rng.exponential(1.0 / cfg.straggler_rate_hz))
        return cls(cfg=cfg, crashes=tuple(crashes),
                   stragglers=tuple(stragglers))

    def fingerprint(self) -> str:
        """Stable digest of the timeline — reruns at a fixed seed must
        reproduce it bit-identically."""
        h = hashlib.sha256()
        for c in self.crashes:
            h.update(f"c{c.replica}:{c.t_down!r}:{c.t_up!r};".encode())
        for s in self.stragglers:
            h.update(f"s{s.replica}:{s.t0!r}:{s.t1!r}:{s.slow!r};".encode())
        return h.hexdigest()[:16]


@dataclasses.dataclass
class CorruptionReport:
    """What ``corrupt_rows`` did — and what a perfect filter would keep.

    ``clean_rows`` is the corrupted stream minus poisoned rows and minus
    duplicate copies (dropped rows are simply gone; no filter can
    recover them).  The robust-ingestion gate is graded against it."""
    n_in: int = 0
    n_dropped: int = 0
    n_duplicated: int = 0
    n_poisoned: int = 0
    clean_rows: List[Dict] = dataclasses.field(default_factory=list)


class FaultInjector:
    """Runtime face of a ``FaultPlan``.

    The crash/straggler timeline is the immutable plan; telemetry
    corruption consumes a dedicated RNG stream (derived from the plan
    seed), so two injectors built from the same plan corrupt identical
    row streams identically — per-policy benchmark runs see the same
    corruption sequence."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.cfg = plan.cfg
        self._windows: Dict[int, List[StragglerWindow]] = {}
        for w in plan.stragglers:
            self._windows.setdefault(w.replica, []).append(w)
        for ws in self._windows.values():
            ws.sort(key=lambda w: w.t0)
        self._telemetry_rng = np.random.default_rng(
            [self.cfg.seed, 0x7E1E])

    # -- crash / straggler queries ------------------------------------------
    def crash_windows(self) -> Tuple[CrashWindow, ...]:
        return self.plan.crashes

    def slow_factor(self, replica: int, t: float) -> float:
        for w in self._windows.get(replica, ()):  # few windows per replica
            if w.t0 <= t < w.t1:
                return w.slow
            if w.t0 > t:
                break
        return 1.0

    def straggler_boundaries(self, replica: int) -> np.ndarray:
        """Sorted times at which ``slow_factor`` changes for a replica.

        The vectorized fleet engine segments its batched decode runs at
        these boundaries so every step still picks up the slow factor in
        force at its *start* time — the event-heap semantics."""
        out: List[float] = []
        for w in self._windows.get(replica, ()):
            out.append(w.t0)
            out.append(w.t1)
        return np.array(sorted(out), np.float64)

    # -- telemetry corruption -----------------------------------------------
    def corrupt_rows(self, rows: List[Dict]
                     ) -> Tuple[List[Dict], CorruptionReport]:
        """Mangle adapter window rows on the way to the online engine.

        Per row, mutually exclusive draws: drop it, duplicate it (the
        copy is the corruption artifact), poison ``thpt`` with NaN/inf,
        or scale-poison ``thpt`` by ``poison_scale`` (biased upward —
        optimistic corruption under-provisions a trusting autoscaler).
        """
        cfg, rng = self.cfg, self._telemetry_rng
        rep = CorruptionReport(n_in=len(rows))
        out: List[Dict] = []
        for row in rows:
            u = float(rng.random())
            if u < cfg.drop_p:
                rep.n_dropped += 1
                continue
            u -= cfg.drop_p
            if u < cfg.dup_p:
                rep.n_duplicated += 1
                out.append(dict(row))
                out.append(dict(row))        # exact duplicate copy
                rep.clean_rows.append(dict(row))
                continue
            u -= cfg.dup_p
            if u < cfg.poison_nan_p:
                bad = dict(row)
                bad["thpt"] = float("nan") if rng.random() < 0.5 \
                    else float("inf")
                rep.n_poisoned += 1
                out.append(bad)
                continue
            u -= cfg.poison_nan_p
            if u < cfg.poison_scale_p:
                bad = dict(row)
                # 3:1 biased toward inflation — the dangerous direction
                scale = (cfg.poison_scale if rng.random() < 0.75
                         else 1.0 / cfg.poison_scale)
                bad["thpt"] = float(bad["thpt"]) * scale
                rep.n_poisoned += 1
                out.append(bad)
                continue
            out.append(dict(row))
            rep.clean_rows.append(dict(row))
        return out, rep


def injector(cfg: FaultConfig) -> FaultInjector:
    """One-call convenience: ``FaultInjector(FaultPlan.build(cfg))``."""
    return FaultInjector(FaultPlan.build(cfg))
