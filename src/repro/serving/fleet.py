"""Vectorized time-bucketed fleet serving engine.

The event-heap reference (``repro.serving.simulator``) pays Python-level
heap traffic for every arrival and every step of every replica, capping
it at a few thousand simulated events per second.  This engine keeps the
*semantics* of that reference — prefill-prioritized continuous batching,
``ii + oo`` KV reservation at admission, crash/straggler fault
injection, drain/provision autoscaling — but advances per-replica state
as batched array operations:

  * **bucketed admissions** — arrivals are quantized to ``cfg.bucket_s``
    boundaries and routed in batches.  This is the engine's *only*
    semantic divergence from the heap reference, and it bounds the
    per-request parity error: a request is admitted at most one bucket
    later than the reference, so TTFT/E2E agree within roughly
    ``bucket_s`` plus one step time (pinned by
    ``tests/test_fleet_parity.py``).
  * **vectorized decode runs** — between buckets, a replica's decode
    progress is computed in closed form: sort the running batch by
    remaining tokens, derive the whole batch-size / context-sum
    trajectory with ``searchsorted`` + suffix sums, evaluate every step
    duration in one call to the ``decode_time_fn`` cost closure (which
    matches ``decode_step_time_group`` to ~1 ulp), and ``cumsum`` the
    durations into completion times.  Hundreds of steps apply per numpy
    call instead of one per heap event.
  * **one-step in-flight buffer** — a step straddling a bucket boundary
    becomes the replica's single *pending* step (its duration fixed at
    start time, like the heap engine's in-flight event) and is applied
    or — on a crash — discarded later, mirroring the reference's
    incarnation-counter semantics.
  * **exact fault/control timing** — crash, restore, provision and
    control-tick events keep their exact times in a small event heap
    (a few thousand entries instead of one per request/step); straggler
    windows segment decode runs so each step still sees the slow factor
    in force at its start.

Results come back as a ``FleetSimResult``: an array-backed
``SimResult`` subclass whose records/steps materialize lazily and whose
metrics (attainment, percentiles, per-tenant meta-metrics) are
vectorized — ``benchmarks/run.py fleet_engine`` pushes 100k+ request
traces through it at a ≥50x events/s multiple of the heap engine.

``cfg.traj_backend="jax"`` swaps the decode-trajectory math for a
jitted, power-of-two-padded ``jax.numpy`` closure (float32 — an opt-in
for accelerator experiments, parity-tested loosely).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import RingLog
from repro.perfmodel.simulator import (decode_time_fn, kv_capacity_tokens,
                                       prefill_time_fn)
from repro.serving.faults import FaultEvent
from repro.serving.simulator import (Action, Observation, RequestRecord,
                                     SimConfig, SimResult, StepRecord)
from repro.serving.traces import Trace

# event kinds, matching the heap engine's same-time ordering
# (arrival < control < provision < crash < restore); _FLUSH drains
# trailing in-flight work at the deadline
_BUCKET, _CONTROL, _PROVISION, _CRASH, _RESTORE, _FLUSH = 0, 2, 3, 4, 5, 6

_SHED_NAMES = ("", "oversized", "retry_budget", "deadline", "unserved")
_SHED_CODE = {n: i for i, n in enumerate(_SHED_NAMES)}


class _JaxTraj:
    """Jitted decode-trajectory durations, padded to powers of two so a
    growing run reuses XLA compiles (the repo's shape-bucketing idiom)."""

    def __init__(self, setup):
        import jax
        import jax.numpy as jnp
        self._f = jax.jit(decode_time_fn(setup, xp=jnp))

    def __call__(self, bb: np.ndarray, ctx_sum: np.ndarray) -> np.ndarray:
        n = len(bb)
        if n == 0:
            return np.zeros(0, np.float64)
        p = 1 << max(int(np.ceil(np.log2(n))), 0)
        bbp = np.zeros(p, np.float64)
        bbp[:n] = bb
        csp = np.zeros(p, np.float64)
        csp[:n] = ctx_sum
        return np.asarray(self._f(bbp, csp), np.float64)[:n]


class _VecReplica:
    """Array/queue state of one replica inside the vectorized engine."""
    __slots__ = ("rid", "batch_cap", "max_prefill", "kv_capacity", "clock",
                 "waiting", "run_rem", "run_ctx", "run_gdx", "kv_reserved",
                 "pend_end", "pend_kind", "pend_admit", "pend_dur",
                 "pend_bb", "draining", "active", "provisioning", "failed",
                 "restore_to_active", "load", "k_hint",
                 "prefill_f", "traj", "hw_name")

    def __init__(self, rid: int, batch_cap: int, max_prefill: int,
                 kv_capacity: float, clock: float, active: bool = True,
                 prefill_f=None, traj=None, hw_name: str = ""):
        self.rid = rid
        self.batch_cap = batch_cap
        self.max_prefill = max_prefill
        self.kv_capacity = kv_capacity
        # per-replica cost closures: heterogeneous fleets give each
        # replica its own hardware's roofline
        self.prefill_f = prefill_f
        self.traj = traj
        self.hw_name = hw_name
        self.clock = clock                # applied-state time
        self.waiting: Deque[int] = collections.deque()   # global req idx
        self.run_rem = np.zeros(0, np.int64)   # tokens left per seq
        self.run_ctx = np.zeros(0, np.int64)   # current context per seq
        self.run_gdx = np.zeros(0, np.int64)   # global req idx per seq
        self.kv_reserved = 0.0
        self.k_hint = 64                  # decode-run length estimate
        self.pend_end: Optional[float] = None   # in-flight step end time
        self.pend_kind = ""
        self.pend_admit: Tuple[int, ...] = ()   # prefill participants
        self.pend_dur = 0.0
        self.pend_bb = 0
        self.draining = False
        self.active = active
        self.provisioning = False
        self.failed = False
        self.restore_to_active = True
        self.load = 0                     # waiting + running + prefilling

    @property
    def busy(self) -> bool:
        return self.pend_end is not None


class _LazySeq(Sequence):
    """List-like view materializing elements on demand (and caching)."""

    def __init__(self, n: int, make):
        self._n = n
        self._make = make
        self._cache: Dict[int, object] = {}

    def __len__(self) -> int:
        return self._n

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(self._n))]
        if i < 0:
            i += self._n
        if not 0 <= i < self._n:
            raise IndexError(i)
        r = self._cache.get(i)
        if r is None:
            r = self._make(i)
            self._cache[i] = r
        return r


class FleetSimResult(SimResult):
    """Array-backed ``SimResult``.

    ``req`` / ``step_arrays`` hold the raw per-request / per-step columns
    (the adapter's vectorized fast path reads them directly);
    ``records`` / ``steps`` materialize ``RequestRecord`` /
    ``StepRecord`` objects lazily for code written against the heap
    engine's interface.  All headline metrics are overridden with
    vectorized equivalents."""

    def __init__(self, req: Dict[str, np.ndarray],
                 step_arrays: Dict[str, np.ndarray], **kw):
        self.req = req
        self.step_arrays = step_arrays
        super().__init__(records=_LazySeq(len(req["rid"]),
                                          self._make_record),
                         steps=_LazySeq(len(step_arrays["t_end"]),
                                        self._make_step), **kw)

    def _make_record(self, i: int) -> RequestRecord:
        q = self.req

        def opt(a):
            return float(a[i]) if np.isfinite(a[i]) else None

        return RequestRecord(
            rid=int(q["rid"][i]), ii=int(q["ii"][i]), oo=int(q["oo"][i]),
            arrival_s=float(q["arrival_s"][i]), tenant=str(q["tenant"][i]),
            replica=int(q["replica"][i]),
            first_token_s=opt(q["first_token_s"]), done_s=opt(q["done_s"]),
            retries=int(q["retries"][i]), shed=bool(q["shed"][i]),
            shed_s=opt(q["shed_s"]),
            shed_reason=_SHED_NAMES[int(q["shed_reason"][i])])

    def _make_step(self, i: int) -> StepRecord:
        a = self.step_arrays
        return StepRecord(t_end=float(a["t_end"][i]),
                          replica=int(a["replica"][i]),
                          kind="prefill" if a["kind"][i] == 0 else "decode",
                          bb=int(a["bb"][i]),
                          duration_s=float(a["duration_s"][i]),
                          tokens_out=int(a["tokens_out"][i]))

    # -- vectorized metric overrides ----------------------------------------
    @property
    def completed(self) -> List[RequestRecord]:
        return [self.records[i] for i in
                np.flatnonzero(np.isfinite(self.req["done_s"]))]

    @property
    def shed(self) -> List[RequestRecord]:
        return [self.records[i] for i in np.flatnonzero(self.req["shed"])]

    @property
    def n_retries(self) -> int:
        return int(self.req["retries"].sum())

    def accounting(self) -> Dict[str, int]:
        comp = np.isfinite(self.req["done_s"])
        return {"admitted": int(len(self.req["rid"])),
                "completed": int(comp.sum()),
                "shed": int(self.req["shed"].sum())}

    def check_conservation(self) -> None:
        comp = np.isfinite(self.req["done_s"])
        both = int((comp & self.req["shed"]).sum())
        acc = self.accounting()
        if both or acc["completed"] + acc["shed"] != acc["admitted"]:
            raise RuntimeError(
                f"request conservation violated: {acc}, "
                f"completed&shed overlap={both}")

    def _ttft_values(self) -> np.ndarray:
        q = self.req
        ttft = q["first_token_s"] - q["arrival_s"]
        miss = q["shed"] | ~np.isfinite(q["first_token_s"])
        return np.where(miss, np.inf, ttft)

    def slo_attainment(self, ttft_slo_s: float) -> float:
        if not len(self.req["rid"]):
            return 1.0
        return float(np.mean(self._ttft_values() <= ttft_slo_s))

    @property
    def goodput_tok_s(self) -> float:
        comp = np.isfinite(self.req["done_s"])
        toks = int(self.req["oo"][comp].sum())
        return toks / max(self.sim_end_s - self.t_start, 1e-9)

    def _tenant_arrays(self):
        # raw columns straight into the shared tenant_rollup — the
        # rollup itself lives in repro.obs.metrics, one copy for both
        # engines
        q = self.req
        return (q["tenant"], q["oo"], np.isfinite(q["done_s"]),
                np.asarray(q["shed"], bool), q["retries"])


class VectorFleetSimulator:
    """Drop-in engine for ``simulate(..., engine="fleet")``."""

    def __init__(self, trace: Trace, cfg: SimConfig, policy=None):
        if cfg.bucket_s <= 0:
            raise ValueError("cfg.bucket_s must be positive")
        self.trace = trace
        self.cfg = cfg
        self.policy = policy
        if cfg.traj_backend not in ("numpy", "jax"):
            raise KeyError(f"unknown traj_backend {cfg.traj_backend!r}; "
                           f"known: numpy, jax")
        # per-setup cost-closure cache (ServingSetup is frozen/hashable):
        # heterogeneous fleets mix hardware, each distinct setup compiles
        # its closures once and every replica on it shares them
        self._closure_cache: Dict[object, Tuple[float, object, object]] = {}
        # admission bound mirrors the heap engine: shed only what the
        # *largest* slot cannot hold; per-replica fit re-checked at route
        self.kv_cap = max(self._closures(s)[0] for s in cfg.slot_setups())
        self.decode_f = decode_time_fn(cfg.setup)
        self.prefill_f = prefill_time_fn(cfg.setup)
        inj = cfg.faults
        self._sb: Dict[int, np.ndarray] = {}
        self._sf: Dict[int, np.ndarray] = {}
        if inj is not None:
            ids = {w.replica for w in inj.plan.stragglers}
            for rid in ids:
                self._sb[rid] = inj.straggler_boundaries(rid)
                self._sf[rid] = np.array(
                    [w.slow for w in sorted(
                        (w for w in inj.plan.stragglers
                         if w.replica == rid), key=lambda w: w.t0)],
                    np.float64)

    # -- fault helpers ------------------------------------------------------
    def _slow(self, rid: int, t: float) -> float:
        b = self._sb.get(rid)
        if b is None or not len(b):
            return 1.0
        i = int(np.searchsorted(b, t, side="right"))
        if i % 2 == 1:                    # inside window (i-1)//2
            return float(self._sf[rid][(i - 1) // 2])
        return 1.0

    def _next_boundary(self, rid: int, t: float) -> float:
        b = self._sb.get(rid)
        if b is None or not len(b):
            return float("inf")
        i = int(np.searchsorted(b, t, side="right"))
        return float(b[i]) if i < len(b) else float("inf")

    # -- engine -------------------------------------------------------------
    def run(self) -> FleetSimResult:
        cfg, trace = self.cfg, self.trace
        N = len(trace.requests)
        arr = trace.to_arrays() if N else {
            "arrival_s": np.zeros(0), "ii": np.zeros(0, np.int64),
            "oo": np.zeros(0, np.int64),
            "tenant": np.zeros(0, dtype=object)}
        self.arrival_a = np.asarray(arr["arrival_s"], np.float64)
        self.ii_a = np.asarray(arr["ii"], np.int64)
        self.oo_a = np.asarray(arr["oo"], np.int64)
        self.tenant_a = np.asarray(arr["tenant"], dtype=object)
        self.rid_a = np.array([r.rid for r in trace.requests], np.int64)
        self.kvneed_a = (self.ii_a + self.oo_a).astype(np.float64)
        self.first_a = np.full(N, np.nan)
        self.done_a = np.full(N, np.nan)
        self.shed_a = np.zeros(N, bool)
        self.sheds_a = np.full(N, np.nan)
        self.shedr_a = np.zeros(N, np.uint8)
        self.retries_a = np.zeros(N, np.int32)
        self.replica_a = np.full(N, -1, np.int32)
        # step buffers: scalar lists (prefill / pending applies) + decode
        # run chunks
        self.ps_t: List[float] = []
        self.ps_dur: List[float] = []
        self.ps_bb: List[int] = []
        self.ps_kind: List[int] = []
        self.ps_rep: List[int] = []
        self.ch_t: List[np.ndarray] = []
        self.ch_dur: List[np.ndarray] = []
        self.ch_bb: List[np.ndarray] = []
        self.ch_rep: List[Tuple[int, int]] = []
        # observability: optional step ring cap (periodic compaction
        # bounds peak memory at ~2x cap) + lossless step aggregates
        obs_cfg = cfg.obs if (cfg.obs is not None
                              and getattr(cfg.obs, "enabled", True)) \
            else None
        self._step_cap = getattr(obs_cfg, "max_steps", None)
        self._comp_steps: Optional[Dict[str, np.ndarray]] = None
        self._retained = 0
        self._steps_dropped = 0
        self._tot_steps = 0
        self._tot_busy = 0.0
        self._tot_tokens = 0
        self.win = dict(arrivals=0, ii=0, oo=0, tokens=0, busy=0.0,
                        last=cfg.t_start)
        self.n_events = 0
        self.n_resolved = 0
        self.last_event_t = cfg.t_start
        # piecewise-constant active/failed-count timeline for the
        # replica-seconds and availability integrals (exact change times)
        self.state_changes: List[Tuple[float, int, int]] = []
        fault_cap = getattr(obs_cfg, "max_fault_events", None)
        fault_log: List[FaultEvent] = RingLog(fault_cap) if fault_cap \
            else []
        controls: List[Tuple[float, Action]] = []

        replicas = [self._new_replica(i, cfg.t_start)
                    for i in range(max(cfg.n_replicas, 1))]
        self._n_active0 = len(replicas)

        heap: List[Tuple[float, int, int, object]] = []
        tick = 0

        def push(t: float, kind: int, payload=None):
            nonlocal tick
            heapq.heappush(heap, (t, kind, tick, payload))
            tick += 1

        # arrivals, quantized to bucket boundaries
        if N:
            bidx = np.ceil((self.arrival_a - cfg.t_start)
                           / cfg.bucket_s).astype(np.int64)
            bidx = np.maximum(bidx, 0)
            bt = cfg.t_start + bidx * cfg.bucket_s
            cut = np.flatnonzero(np.diff(bt) != 0) + 1
            starts = np.concatenate([[0], cut])
            ends = np.concatenate([cut, [N]])
            for lo, hi in zip(starts, ends):
                push(float(bt[lo]), _BUCKET, (int(lo), int(hi)))
        if self.policy is not None and cfg.control_interval_s > 0:
            push(cfg.t_start + cfg.control_interval_s, _CONTROL, None)
        inj = cfg.faults
        warmup_s = float(inj.cfg.restart_warmup_s) if inj is not None \
            else 0.0
        if inj is not None:
            for w in inj.crash_windows():
                if w.replica >= cfg.max_replicas or w.t_up <= cfg.t_start:
                    continue
                push(max(w.t_down, cfg.t_start), _CRASH, w.replica)
                push(w.t_up, _RESTORE, w.replica)
        deadline = trace.horizon_s + cfg.drain_s
        push(deadline, _FLUSH, None)

        while heap:
            t, kind, _, payload = heapq.heappop(heap)
            if t > deadline:
                break
            for r in replicas:
                self._advance(r, t)
            if kind == _BUCKET:
                lo, hi = payload
                self._route_bucket(replicas, t, lo, hi)
            elif kind == _CONTROL:
                self._control(replicas, t, controls, push)
            elif kind == _PROVISION:
                r = payload
                if r.failed:
                    r.provisioning = False
                else:
                    r.provisioning = False
                    if not r.draining:
                        self._set_state(r, t, active=True)
                self.n_events += 1
                self.last_event_t = max(self.last_event_t, t)
            elif kind == _CRASH:
                if payload < len(replicas) \
                        and not replicas[payload].failed:
                    self._crash(replicas, replicas[payload], t, fault_log)
                    self.n_events += 1
                    self.last_event_t = max(self.last_event_t, t)
            elif kind == _RESTORE:
                if payload < len(replicas) and replicas[payload].failed:
                    r = replicas[payload]
                    self._set_state(r, t, failed=False)
                    fault_log.append(FaultEvent(t=t, kind="restore",
                                                replica=r.rid))
                    if r.restore_to_active:
                        if warmup_s > 0:
                            r.provisioning = True
                            push(t + warmup_s, _PROVISION, r)
                        else:
                            self._set_state(r, t, active=True)
                    self.n_events += 1
                    self.last_event_t = max(self.last_event_t, t)
            # _FLUSH: the advance above already drained applied work
            if self.n_resolved >= N \
                    and not any(r.pend_end is not None for r in replicas):
                break

        # unresolved requests were never served within horizon + drain
        now = self.last_event_t
        open_m = ~(np.isfinite(self.done_a) | self.shed_a)
        if open_m.any():
            self.shed_a[open_m] = True
            self.sheds_a[open_m] = now
            self.shedr_a[open_m] = _SHED_CODE["unserved"]
            self.n_resolved += int(open_m.sum())

        active_s, failed_s = self._integrate_states(cfg.t_start, now)
        denom = active_s + failed_s
        step_arrays = self._collect_steps()
        req = {"rid": self.rid_a, "ii": self.ii_a, "oo": self.oo_a,
               "arrival_s": self.arrival_a, "tenant": self.tenant_a,
               "replica": self.replica_a, "first_token_s": self.first_a,
               "done_s": self.done_a, "retries": self.retries_a,
               "shed": self.shed_a, "shed_s": self.sheds_a,
               "shed_reason": self.shedr_a}
        res = FleetSimResult(
            req=req, step_arrays=step_arrays, sim_end_s=now,
            n_events=self.n_events, replica_seconds=active_s,
            controls=controls, t_start=cfg.t_start,
            availability=(active_s / denom if denom > 0 else 1.0),
            fault_log=fault_log,
            replica_hw={r.rid: r.hw_name for r in replicas})
        res.steps_dropped = self._steps_dropped
        res.faults_dropped = getattr(fault_log, "n_dropped", 0)
        res.step_totals = {"n": self._tot_steps, "busy_s": self._tot_busy,
                           "tokens_out": self._tot_tokens}
        if obs_cfg is not None:
            from repro.obs.tracing import record_spans
            res.spans = record_spans(res, obs_cfg)
        return res

    # -- replica lifecycle --------------------------------------------------
    def _closures(self, setup) -> Tuple[float, object, object]:
        """(kv_capacity, prefill_time_fn, decode trajectory fn) for a
        setup, cached so replicas sharing hardware share closures."""
        got = self._closure_cache.get(setup)
        if got is None:
            traj = (_JaxTraj(setup) if self.cfg.traj_backend == "jax"
                    else decode_time_fn(setup))
            got = (self.cfg.kv_cap_for(setup), prefill_time_fn(setup), traj)
            self._closure_cache[setup] = got
        return got

    def _new_replica(self, rid: int, clock: float, active: bool = True,
                     hardware: Optional[str] = None) -> _VecReplica:
        setup = self.cfg.setup_for(rid, hardware)
        kv, pre, traj = self._closures(setup)
        return _VecReplica(rid, self.cfg.batch_cap,
                           self.cfg.max_prefill_requests, kv,
                           clock, active=active, prefill_f=pre, traj=traj,
                           hw_name=setup.hw.name)

    def _set_state(self, r: _VecReplica, t: float,
                   active: Optional[bool] = None,
                   failed: Optional[bool] = None) -> None:
        da = df = 0
        if active is not None and active != r.active:
            da = 1 if active else -1
            r.active = active
        if failed is not None and failed != r.failed:
            df = 1 if failed else -1
            r.failed = failed
        if da or df:
            self.state_changes.append((t, da, df))

    def _integrate_states(self, t0: float, t1: float
                          ) -> Tuple[float, float]:
        """∫ n_active dt and ∫ n_failed dt over [t0, t1] from the exact
        change timeline (matches the heap engine's per-pop integrals)."""
        events = sorted(self.state_changes)
        na, nf = self._n_active0, 0
        active_s = failed_s = 0.0
        last = t0
        for t, da, df in events:
            tc = min(max(t, t0), t1)
            active_s += na * (tc - last)
            failed_s += nf * (tc - last)
            last = tc
            na += da
            nf += df
        active_s += na * max(t1 - last, 0.0)
        failed_s += nf * max(t1 - last, 0.0)
        return active_s, failed_s

    # -- routing ------------------------------------------------------------
    def _cands(self, replicas: List[_VecReplica]) -> List[_VecReplica]:
        return ([r for r in replicas
                 if r.active and not r.draining and not r.failed]
                or [r for r in replicas if r.active and not r.failed]
                or [r for r in replicas if not r.failed]
                or replicas)

    def _dispatch(self, g: int, t: float,
                  replicas: List[_VecReplica]) -> None:
        # mirror the heap engine's requeue dispatch: progressively wider
        # pools, each filtered to replicas whose KV fits the sequence;
        # shed as oversized if no live replica can hold it
        need = self.kvneed_a[g]
        tgt = None
        for pool in (
                [r for r in replicas
                 if r.active and not r.draining and not r.failed],
                [r for r in replicas if r.active and not r.failed],
                [r for r in replicas if not r.failed],
                replicas):
            fit = [r for r in pool if need <= r.kv_capacity]
            if fit:
                tgt = min(fit, key=lambda r: (r.load, r.rid))
                break
        if tgt is None:
            self._shed(g, t, "oversized")
            return
        self.replica_a[g] = tgt.rid
        tgt.waiting.append(g)
        tgt.load += 1
        if tgt.pend_end is None:
            tgt.clock = max(tgt.clock, t)

    def _shed(self, g: int, t: float, reason: str) -> None:
        self.shed_a[g] = True
        self.sheds_a[g] = t
        self.shedr_a[g] = _SHED_CODE[reason]
        self.n_resolved += 1

    def _route_bucket(self, replicas: List[_VecReplica], t: float,
                      lo: int, hi: int) -> None:
        win = self.win
        win["arrivals"] += hi - lo
        win["ii"] += int(self.ii_a[lo:hi].sum())
        win["oo"] += int(self.oo_a[lo:hi].sum())
        self.n_events += hi - lo
        self.last_event_t = max(self.last_event_t, t)
        cands = self._cands(replicas)
        kv_cap = self.kv_cap
        kvn = self.kvneed_a
        # least-loaded greedy over (load, rid) via a small heap — the
        # same assignment the per-request min() would produce, without
        # scanning every candidate per request.  Heterogeneous fleets
        # take the fit-aware path: pop until a replica's KV fits,
        # matching the heap engine's per-request candidate filter.
        hetero = len({r.kv_capacity for r in cands}) > 1
        cand_max_kv = max(r.kv_capacity for r in cands)
        hp = [(r.load, r.rid, r) for r in cands]
        heapq.heapify(hp)
        for g in range(lo, hi):
            if kvn[g] > kv_cap:
                self._shed(g, t, "oversized")
                continue
            if kvn[g] > cand_max_kv:
                # fits the fleet's largest slot but no preferred
                # candidate: fall through to the wide-pool dispatch
                self._dispatch(g, t, replicas)
                continue
            if not hetero:
                load, rid, tgt = heapq.heappop(hp)
            else:
                skipped = []
                while True:
                    load, rid, tgt = heapq.heappop(hp)
                    if kvn[g] <= tgt.kv_capacity:
                        break
                    skipped.append((load, rid, tgt))
                for it in skipped:
                    heapq.heappush(hp, it)
            self.replica_a[g] = rid
            tgt.waiting.append(g)
            tgt.load = load + 1
            if tgt.pend_end is None and tgt.clock < t:
                tgt.clock = t
            heapq.heappush(hp, (load + 1, rid, tgt))

    def _requeue_or_shed(self, g: int, t: float,
                         replicas: List[_VecReplica]) -> None:
        cfg = self.cfg
        if self.retries_a[g] > cfg.max_retries:
            self._shed(g, t, "retry_budget")
            return
        if cfg.shed_after_s is not None \
                and t - self.arrival_a[g] > cfg.shed_after_s:
            self._shed(g, t, "deadline")
            return
        # KV and generated tokens died with the replica: generation (and
        # TTFT) restarts on the retry, matching the heap engine
        self.first_a[g] = np.nan
        self._dispatch(g, t, replicas)

    def _crash(self, replicas: List[_VecReplica], r: _VecReplica, t: float,
               fault_log: List[FaultEvent]) -> None:
        inflight = (list(r.pend_admit)
                    if r.pend_end is not None and r.pend_kind == "prefill"
                    else [])
        inflight += r.run_gdx.tolist()
        queued = list(r.waiting)
        r.restore_to_active = (r.active or r.provisioning) \
            and not r.draining
        r.pend_end = None                 # in-flight step of a dead
        r.pend_admit = ()                 # incarnation: discard
        r.run_rem = np.zeros(0, np.int64)
        r.run_ctx = np.zeros(0, np.int64)
        r.run_gdx = np.zeros(0, np.int64)
        r.waiting.clear()
        r.kv_reserved = 0.0
        r.load = 0
        r.provisioning = False
        r.draining = False
        self._set_state(r, t, active=False, failed=True)
        fault_log.append(FaultEvent(t=t, kind="crash", replica=r.rid,
                                    n_displaced=len(inflight)
                                    + len(queued)))
        for g in inflight:
            self.retries_a[g] += 1        # computed KV was lost
            self._requeue_or_shed(g, t, replicas)
        for g in queued:                  # rerouted, not a retry
            self._requeue_or_shed(g, t, replicas)

    # -- control ------------------------------------------------------------
    def _control(self, replicas: List[_VecReplica], t: float,
                 controls: List[Tuple[float, Action]], push) -> None:
        cfg, win = self.cfg, self.win
        w = max(t - win["last"], 1e-9)
        n_arr = win["arrivals"]
        obs = Observation(
            now=t, window_s=w, n_arrivals=n_arr,
            mean_ii=win["ii"] / n_arr if n_arr else 0.0,
            mean_oo=win["oo"] / n_arr if n_arr else 0.0,
            arrival_rate=n_arr / w,
            queue_len=sum(len(r.waiting) for r in replicas),
            n_running=sum(len(r.run_rem)
                          + (len(r.pend_admit)
                             if r.pend_end is not None
                             and r.pend_kind == "prefill" else 0)
                          for r in replicas),
            n_active_replicas=sum(1 for r in replicas
                                  if r.active and not r.draining),
            batch_cap=replicas[0].batch_cap,
            decode_tokens=win["tokens"], busy_s=win["busy"],
            measured_tok_s=(win["tokens"] / win["busy"]
                            if win["busy"] > 0 else 0.0),
            n_failed_replicas=sum(1 for r in replicas if r.failed))
        act = self._apply_action(replicas, t,
                                 self.policy.control(obs), push)
        controls.append((t, act))
        self.win = dict(arrivals=0, ii=0, oo=0, tokens=0, busy=0.0,
                        last=t)
        self.n_events += 1
        self.last_event_t = max(self.last_event_t, t)
        if t + cfg.control_interval_s < self.trace.horizon_s:
            push(t + cfg.control_interval_s, _CONTROL, None)

    def _apply_action(self, replicas: List[_VecReplica], now: float,
                      act: Action, push) -> Action:
        cfg = self.cfg
        act = Action(n_replicas=int(np.clip(act.n_replicas, 1,
                                            cfg.max_replicas)),
                     batch_cap=max(int(act.batch_cap), 1),
                     hardware=act.hardware)
        n_active = sum(1 for r in replicas if r.active and not r.draining)
        if act.n_replicas > n_active:
            need = act.n_replicas - n_active
            for r in replicas:
                if need and r.active and r.draining:
                    r.draining = False
                    need -= 1
            for r in replicas:
                if need and not r.active and not r.provisioning \
                        and not r.failed:
                    r.draining = False
                    r.provisioning = True
                    push(now + cfg.provision_delay_s, _PROVISION, r)
                    need -= 1
            for _ in range(need):
                nr = self._new_replica(len(replicas), now, active=False,
                                       hardware=act.hardware)
                nr.provisioning = True
                replicas.append(nr)
                push(now + cfg.provision_delay_s, _PROVISION, nr)
        elif act.n_replicas < n_active:
            for r in sorted(replicas, key=lambda r: -r.rid):
                if n_active <= act.n_replicas:
                    break
                if r.active and not r.draining:
                    r.draining = True
                    if r.pend_end is None and r.load == 0:
                        self._set_state(r, now, active=False)
                    n_active -= 1
        for r in replicas:
            r.batch_cap = act.batch_cap
        return act

    # -- per-replica advancement --------------------------------------------
    def _try_admit(self, r: _VecReplica) -> List[int]:
        admit: List[int] = []
        kvn = self.kvneed_a
        while (r.waiting and len(admit) < r.max_prefill
               and len(r.run_rem) + len(admit) < r.batch_cap
               and r.kv_reserved + kvn[r.waiting[0]] <= r.kv_capacity):
            g = r.waiting.popleft()
            r.kv_reserved += kvn[g]
            admit.append(g)
        return admit

    def _advance(self, r: _VecReplica, t_limit: float) -> None:
        while True:
            if r.pend_end is not None:
                if r.pend_end > t_limit:
                    return
                self._apply_pending(r)
                continue
            if r.clock >= t_limit:
                return
            if r.waiting:
                admit = self._try_admit(r)
                if admit:
                    f = self._slow(r.rid, r.clock)
                    iis = self.ii_a[admit]
                    dur = float(r.prefill_f(
                        float(iis.sum()),
                        float((iis * iis).sum()))) * f
                    r.pend_kind = "prefill"
                    r.pend_admit = tuple(admit)
                    r.pend_dur = dur
                    r.pend_bb = len(admit)
                    r.pend_end = r.clock + dur
                    continue
            if r.run_rem.size:
                self._decode_advance(r, t_limit)
                continue
            return

    def _apply_pending(self, r: _VecReplica) -> None:
        t = r.pend_end
        if r.pend_kind == "prefill":
            started = []
            for g in r.pend_admit:
                self.first_a[g] = t
                if self.oo_a[g] <= 1:
                    self.done_a[g] = t
                    r.kv_reserved -= self.kvneed_a[g]
                    r.load -= 1
                    self.n_resolved += 1
                else:
                    started.append(g)
            if started:
                sg = np.asarray(started, np.int64)
                r.run_rem = np.concatenate([r.run_rem, self.oo_a[sg] - 1])
                r.run_ctx = np.concatenate([r.run_ctx, self.ii_a[sg] + 1])
                r.run_gdx = np.concatenate([r.run_gdx, sg])
            bbn = len(r.pend_admit)
            self.ps_kind.append(0)
        else:
            rem = r.run_rem
            done_m = rem <= 1
            nc = int(done_m.sum())
            if nc:
                dg = r.run_gdx[done_m]
                self.done_a[dg] = t
                r.kv_reserved -= float(self.kvneed_a[dg].sum())
                r.load -= nc
                self.n_resolved += nc
                keep = ~done_m
                r.run_rem = rem[keep] - 1
                r.run_ctx = r.run_ctx[keep] + 1
                r.run_gdx = r.run_gdx[keep]
            else:
                r.run_rem = rem - 1
                r.run_ctx = r.run_ctx + 1
            bbn = r.pend_bb
            self.ps_kind.append(1)
        self.ps_t.append(t)
        self.ps_dur.append(r.pend_dur)
        self.ps_bb.append(bbn)
        self.ps_rep.append(r.rid)
        self._retained += 1
        self._tot_steps += 1
        self._tot_busy += r.pend_dur
        self._tot_tokens += bbn
        self._maybe_compact()
        self.win["tokens"] += bbn
        self.win["busy"] += r.pend_dur
        self.n_events += 1
        r.clock = t
        self.last_event_t = max(self.last_event_t, t)
        r.pend_end = None
        r.pend_admit = ()
        if r.draining and r.load == 0:
            self._set_state(r, t, active=False)   # drained dry

    def _decode_advance(self, r: _VecReplica, t_limit: float) -> None:
        clock = r.clock
        seg_limit = min(t_limit, self._next_boundary(r.rid, clock))
        f = self._slow(r.rid, clock)
        rem0 = r.run_rem
        n = rem0.size
        order = np.argsort(rem0, kind="stable")
        rs = rem0[order]
        ctx_s = r.run_ctx[order].astype(np.float64)
        gdx = r.run_gdx
        kvn_s = self.kvneed_a[gdx[order]]
        sufctx = np.concatenate([np.cumsum(ctx_s[::-1])[::-1], [0.0]])
        prefkv = np.concatenate([[0.0], np.cumsum(kvn_s)])
        K_full = int(rs[-1])
        need0 = self.kvneed_a[r.waiting[0]] if r.waiting else None
        cap, kv_cap, kv_res = r.batch_cap, r.kv_capacity, r.kv_reserved
        K_try = min(K_full, max(r.k_hint, 16))
        while True:
            s = np.arange(K_try + 1)
            cnt = np.searchsorted(rs, s, side="right")   # rem <= s
            bb = n - cnt                  # alive before step s / after s
            bb_step = bb[:K_try]
            ctxsum = sufctx[cnt[:K_try]] + s[:K_try] * bb_step
            d = r.traj(bb_step, ctxsum) * f
            cum = clock + np.cumsum(d)
            K_adm = None
            if need0 is not None:
                ok = ((bb[1:] < cap)
                      & (kv_res - prefkv[cnt[1:]] + need0 <= kv_cap))
                j = int(np.argmax(ok)) if ok.any() else -1
                if j >= 0:
                    K_adm = j + 1
            K_stop = K_full if K_adm is None else min(K_adm, K_full)
            S_time = int(np.searchsorted(cum, seg_limit, side="right"))
            if S_time >= K_try and K_try < K_stop:
                K_try = min(K_try * 4, K_full)
                continue
            break
        S_apply = min(S_time, K_stop)
        r.k_hint = max(2 * S_apply, 16)   # seed the next run's chunk size
        if S_apply > 0:
            ncomp = int(np.searchsorted(rs, S_apply, side="right"))
            if ncomp:
                dg = gdx[order[:ncomp]]
                self.done_a[dg] = cum[rs[:ncomp] - 1]
                r.kv_reserved -= float(prefkv[ncomp])
                r.load -= ncomp
                self.n_resolved += ncomp
                keep = rem0 > S_apply     # original batch order preserved
                r.run_rem = rem0[keep] - S_apply
                r.run_ctx = r.run_ctx[keep] + S_apply
                r.run_gdx = gdx[keep]
            else:
                r.run_rem = rem0 - S_apply
                r.run_ctx = r.run_ctx + S_apply
            self.ch_t.append(cum[:S_apply])
            self.ch_dur.append(d[:S_apply])
            self.ch_bb.append(bb_step[:S_apply])
            self.ch_rep.append((r.rid, S_apply))
            self._retained += S_apply
            self._tot_steps += S_apply
            self._tot_busy += float(d[:S_apply].sum())
            self._tot_tokens += int(bb_step[:S_apply].sum())
            self._maybe_compact()
            self.win["tokens"] += int(bb_step[:S_apply].sum())
            self.win["busy"] += float(d[:S_apply].sum())
            self.n_events += S_apply
            r.clock = float(cum[S_apply - 1])
            self.last_event_t = max(self.last_event_t, r.clock)
            if r.draining and r.load == 0:
                self._set_state(r, r.clock, active=False)
        if S_apply < K_stop:              # straddler: one in-flight step
            r.pend_kind = "decode"
            r.pend_dur = float(d[S_apply])
            r.pend_bb = int(bb_step[S_apply])
            r.pend_end = float(cum[S_apply])

    def _gather_steps(self) -> Dict[str, np.ndarray]:
        ts = [np.asarray(self.ps_t, np.float64)] + self.ch_t
        ds = [np.asarray(self.ps_dur, np.float64)] + self.ch_dur
        bs = [np.asarray(self.ps_bb, np.int64)] + \
            [c.astype(np.int64) for c in self.ch_bb]
        ks = [np.asarray(self.ps_kind, np.uint8)] + \
            [np.full(len(c), 1, np.uint8) for c in self.ch_t]
        rp = [np.asarray(self.ps_rep, np.int32)] + \
            [np.full(cn, rid, np.int32) for rid, cn in self.ch_rep]
        if self._comp_steps is not None:          # prior compactions
            c = self._comp_steps
            ts, ds = [c["t_end"]] + ts, [c["duration_s"]] + ds
            bs, ks = [c["bb"]] + bs, [c["kind"]] + ks
            rp = [c["replica"]] + rp
        t_end = np.concatenate(ts) if ts else np.zeros(0)
        order = np.argsort(t_end, kind="stable")
        dur = np.concatenate(ds)[order]
        bb = np.concatenate(bs)[order]
        return {"t_end": t_end[order], "replica": np.concatenate(rp)[order],
                "kind": np.concatenate(ks)[order], "bb": bb,
                "duration_s": dur, "tokens_out": bb}

    def _maybe_compact(self) -> None:
        """Under an ``obs.max_steps`` ring cap, fold the step buffers
        down to the most recent ``cap`` records whenever retention
        exceeds 2x cap — peak telemetry memory stays O(cap) however
        long the run, while ``_tot_*`` keeps the lossless aggregates."""
        cap = self._step_cap
        if not cap or self._retained <= 2 * cap:
            return
        g = self._gather_steps()
        n = len(g["t_end"])
        if n > cap:
            self._steps_dropped += n - cap
            g = {k: v[n - cap:] for k, v in g.items()}
        self._comp_steps = g
        self.ps_t.clear()
        self.ps_dur.clear()
        self.ps_bb.clear()
        self.ps_kind.clear()
        self.ps_rep.clear()
        self.ch_t.clear()
        self.ch_dur.clear()
        self.ch_bb.clear()
        self.ch_rep.clear()
        self._retained = len(g["t_end"])

    def _collect_steps(self) -> Dict[str, np.ndarray]:
        g = self._gather_steps()
        cap = self._step_cap
        if cap and len(g["t_end"]) > cap:         # final truncation
            self._steps_dropped += len(g["t_end"]) - cap
            g = {k: v[len(g["t_end"]) - cap:] for k, v in g.items()}
        return g
