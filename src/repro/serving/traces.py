"""Workload trace generators for the serving simulator.

A trace is a time-ordered list of requests, each with an arrival time and
a request shape ``(ii, oo)``.  Three arrival processes cover the paper's
"dynamic workload variation" axis:

  * ``poisson`` — memoryless arrivals at a constant rate (the classic
    open-loop load model).
  * ``gamma``   — i.i.d. Gamma inter-arrival gaps with a configurable
    coefficient of variation; cv > 1 is burstier than Poisson, cv < 1
    smoother.
  * ``mmpp``    — 2-state Markov-modulated Poisson process: the rate
    switches between a quiet and a bursty regime with exponentially
    distributed dwell times.  This is the stress case for autoscaling.

Request shapes are drawn from a mixture of lognormal profiles
(chat / summarize / generate presets), clipped to sane token ranges.
Everything is driven by one ``np.random.default_rng(seed)``, so a trace
is exactly replayable from its config + seed (pinned by tests).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    rid: int
    arrival_s: float
    ii: int
    oo: int
    tenant: str = ""              # "" = single-tenant trace


@dataclasses.dataclass(frozen=True)
class ShapeProfile:
    """Lognormal (ii, oo) sampler: ``exp(N(log_mean, sigma))``, clipped."""
    name: str
    ii_log_mean: float
    ii_sigma: float
    oo_log_mean: float
    oo_sigma: float
    ii_range: Tuple[int, int] = (8, 16384)
    oo_range: Tuple[int, int] = (4, 4096)

    def sample(self, n: int, rng: np.random.Generator
               ) -> Tuple[np.ndarray, np.ndarray]:
        ii = np.exp(rng.normal(self.ii_log_mean, self.ii_sigma, n))
        oo = np.exp(rng.normal(self.oo_log_mean, self.oo_sigma, n))
        ii = np.clip(np.round(ii), *self.ii_range).astype(np.int64)
        oo = np.clip(np.round(oo), *self.oo_range).astype(np.int64)
        return ii, oo


# short prompts, medium replies / long prompts, short replies / short
# prompts, long generations — the three canonical serving shapes
CHAT = ShapeProfile("chat", np.log(256.0), 0.6, np.log(160.0), 0.5)
SUMMARIZE = ShapeProfile("summarize", np.log(2048.0), 0.5, np.log(96.0), 0.4)
GENERATE = ShapeProfile("generate", np.log(128.0), 0.5, np.log(512.0), 0.5)
PROFILES: Dict[str, ShapeProfile] = {p.name: p for p in
                                     (CHAT, SUMMARIZE, GENERATE)}


@dataclasses.dataclass(frozen=True)
class ShapeMix:
    """Weighted mixture of profiles; each request draws one component."""
    components: Tuple[ShapeProfile, ...]
    weights: Tuple[float, ...]

    def sample(self, n: int, rng: np.random.Generator
               ) -> Tuple[np.ndarray, np.ndarray]:
        w = np.asarray(self.weights, np.float64)
        w = w / w.sum()
        choice = rng.choice(len(self.components), size=n, p=w)
        ii = np.zeros(n, np.int64)
        oo = np.zeros(n, np.int64)
        for c, prof in enumerate(self.components):
            m = choice == c
            if m.any():
                ii[m], oo[m] = prof.sample(int(m.sum()), rng)
        return ii, oo


def mix(*names_weights: Tuple[str, float]) -> ShapeMix:
    names, weights = zip(*names_weights)
    return ShapeMix(tuple(PROFILES[n] for n in names), tuple(weights))


# -- arrival processes -------------------------------------------------------
def poisson_arrivals(rate: float, horizon_s: float,
                     rng: np.random.Generator) -> np.ndarray:
    n = max(int(rate * horizon_s * 2) + 16, 16)
    gaps = rng.exponential(1.0 / rate, n)
    t = np.cumsum(gaps)
    while t[-1] < horizon_s:          # tail top-up for heavy draws
        more = np.cumsum(rng.exponential(1.0 / rate, n)) + t[-1]
        t = np.concatenate([t, more])
    return t[t < horizon_s]


def gamma_arrivals(rate: float, horizon_s: float, rng: np.random.Generator,
                   cv: float = 2.0) -> np.ndarray:
    """Gamma-renewal arrivals: mean gap 1/rate, coefficient of variation cv."""
    shape = 1.0 / (cv * cv)
    scale = 1.0 / (rate * shape)
    n = max(int(rate * horizon_s * 2) + 16, 16)
    t = np.cumsum(rng.gamma(shape, scale, n))
    while t[-1] < horizon_s:
        t = np.concatenate([t, np.cumsum(rng.gamma(shape, scale, n))
                            + t[-1]])
    return t[t < horizon_s]


def mmpp_arrivals(rate_lo: float, rate_hi: float, horizon_s: float,
                  rng: np.random.Generator, dwell_lo_s: float = 8.0,
                  dwell_hi_s: float = 4.0) -> np.ndarray:
    """2-state MMPP: Poisson at rate_lo / rate_hi with exp. dwell times."""
    out: List[np.ndarray] = []
    t, state = 0.0, 0
    while t < horizon_s:
        dwell = rng.exponential(dwell_lo_s if state == 0 else dwell_hi_s)
        end = min(t + dwell, horizon_s)
        rate = rate_lo if state == 0 else rate_hi
        if rate > 0 and end > t:
            seg = poisson_arrivals(rate, end - t, rng) + t
            out.append(seg)
        t, state = end, 1 - state
    return (np.sort(np.concatenate(out)) if out
            else np.zeros(0, np.float64))


ARRIVALS = {"poisson": poisson_arrivals, "gamma": gamma_arrivals,
            "mmpp": mmpp_arrivals}


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    arrival: str = "poisson"          # poisson | gamma | mmpp
    rate: float = 4.0                 # req/s (mmpp: quiet-state rate)
    horizon_s: float = 60.0
    shape_mix: ShapeMix = dataclasses.field(
        default_factory=lambda: mix(("chat", 1.0)))
    seed: int = 0
    # process-specific knobs
    cv: float = 2.0                   # gamma burstiness
    burst_rate: Optional[float] = None  # mmpp hi-state rate (default 4x)
    dwell_lo_s: float = 8.0
    dwell_hi_s: float = 4.0


@dataclasses.dataclass(frozen=True)
class Trace:
    requests: Tuple[TraceRequest, ...]
    horizon_s: float
    config: Optional[TraceConfig] = None
    fleet_config: Optional["FleetTraceConfig"] = None

    def __len__(self) -> int:
        return len(self.requests)

    @property
    def arrivals(self) -> np.ndarray:
        return np.array([r.arrival_s for r in self.requests])

    def to_arrays(self) -> Dict[str, np.ndarray]:
        return {"arrival_s": self.arrivals,
                "ii": np.array([r.ii for r in self.requests], np.int64),
                "oo": np.array([r.oo for r in self.requests], np.int64),
                "tenant": np.array([r.tenant for r in self.requests],
                                   dtype=object)}

    def slice(self, t0: float, t1: float) -> "Trace":
        """Requests with ``t0 <= arrival < t1``, absolute times and rids
        preserved — one epoch of this trace for the streaming loop
        (pair with ``SimConfig.t_start=t0``)."""
        reqs = tuple(r for r in self.requests if t0 <= r.arrival_s < t1)
        return dataclasses.replace(self, requests=reqs,
                                   horizon_s=float(t1))

    @classmethod
    def from_arrays(cls, arrival_s, ii, oo, tenant=None,
                    horizon_s: Optional[float] = None) -> "Trace":
        order = np.argsort(np.asarray(arrival_s, np.float64),
                           kind="stable")
        ten = (lambda j: str(tenant[j])) if tenant is not None \
            else (lambda j: "")
        reqs = tuple(TraceRequest(rid=int(k), arrival_s=float(arrival_s[j]),
                                  ii=int(ii[j]), oo=int(oo[j]),
                                  tenant=ten(j))
                     for k, j in enumerate(order))
        h = float(horizon_s if horizon_s is not None
                  else (arrival_s[order[-1]] + 1.0 if len(order) else 0.0))
        return cls(requests=reqs, horizon_s=h)

    @property
    def tenants(self) -> Tuple[str, ...]:
        """Distinct tenant names, in first-appearance order."""
        seen: Dict[str, None] = {}
        for r in self.requests:
            seen.setdefault(r.tenant, None)
        return tuple(seen)


def _gen_arrivals(cfg: TraceConfig, rate: float, horizon_s: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Arrival times for ``cfg``'s process at an overridable rate."""
    if cfg.arrival == "poisson":
        return poisson_arrivals(rate, horizon_s, rng)
    if cfg.arrival == "gamma":
        return gamma_arrivals(rate, horizon_s, rng, cv=cfg.cv)
    if cfg.arrival == "mmpp":
        hi = (cfg.burst_rate if cfg.burst_rate is not None
              else 4.0 * cfg.rate)
        # scale both regimes by the same factor so burstiness survives
        hi = hi * (rate / cfg.rate) if cfg.rate > 0 else hi
        return mmpp_arrivals(rate, hi, horizon_s, rng,
                             dwell_lo_s=cfg.dwell_lo_s,
                             dwell_hi_s=cfg.dwell_hi_s)
    raise KeyError(f"unknown arrival process {cfg.arrival!r}; "
                   f"known: {sorted(ARRIVALS)}")


def make_trace(cfg: TraceConfig) -> Trace:
    """Deterministic trace from config + seed (one RNG drives everything)."""
    rng = np.random.default_rng(cfg.seed)
    t = _gen_arrivals(cfg, cfg.rate, cfg.horizon_s, rng)
    ii, oo = cfg.shape_mix.sample(len(t), rng)
    reqs = tuple(TraceRequest(rid=i, arrival_s=float(t[i]),
                              ii=int(ii[i]), oo=int(oo[i]))
                 for i in range(len(t)))
    return Trace(requests=reqs, horizon_s=cfg.horizon_s, config=cfg)


# -- multi-tenant fleet traces ----------------------------------------------
@dataclasses.dataclass(frozen=True)
class TenantConfig:
    """One tenant's workload: a base arrival process modulated in time.

    The tenant's instantaneous rate is
    ``trace.rate * rate_scale * m(t)`` where the envelope
    ``m(t) = diurnal(t) * flash(t)`` combines a sinusoidal diurnal cycle
    (``1 + diurnal_amp * sin(...)``, clipped at 0) with rectangular
    flash-crowd spikes (``flash_mult`` for ``flash_dur_s`` seconds at
    seed-deterministic start times).  Arrivals are generated at the
    envelope's peak rate and thinned by ``m(t)/m_max`` — for Poisson this
    is the exact inhomogeneous-process construction; for Gamma/MMPP it
    modulates the renewal process while preserving its burstiness.
    ``ttft_slo_s`` is the tenant's SLO tier, consumed by
    ``SimResult.per_tenant``.
    """
    name: str
    trace: TraceConfig
    ttft_slo_s: float = 2.0
    rate_scale: float = 1.0
    diurnal_amp: float = 0.0          # 0..1; 0 disables the cycle
    diurnal_period_s: float = 600.0
    diurnal_phase: float = 0.0
    flash_crowds: int = 0             # number of spikes over the horizon
    flash_mult: float = 4.0
    flash_dur_s: float = 20.0

    def envelope(self, t: np.ndarray,
                 crowd_starts: np.ndarray) -> np.ndarray:
        t = np.asarray(t, np.float64)
        m = 1.0 + self.diurnal_amp * np.sin(
            2.0 * np.pi * t / self.diurnal_period_s + self.diurnal_phase)
        m = np.maximum(m, 0.0)
        if len(crowd_starts):
            hit = np.zeros(t.shape, bool)
            for c in crowd_starts:
                hit |= (t >= c) & (t < c + self.flash_dur_s)
            m = m * np.where(hit, self.flash_mult, 1.0)
        return m

    @property
    def envelope_max(self) -> float:
        m = 1.0 + self.diurnal_amp
        return m * self.flash_mult if self.flash_crowds else m


@dataclasses.dataclass(frozen=True)
class FleetTraceConfig:
    """Multi-tenant fleet workload: the union of per-tenant traces."""
    tenants: Tuple[TenantConfig, ...]
    horizon_s: float = 600.0
    seed: int = 0

    @property
    def slo_map(self) -> Dict[str, float]:
        return {tc.name: tc.ttft_slo_s for tc in self.tenants}


def make_fleet_trace(cfg: FleetTraceConfig) -> Trace:
    """Deterministic multi-tenant trace (one sub-stream per tenant).

    Each tenant draws from ``default_rng([seed, tenant_index])`` in a
    fixed order (crowd times, base arrivals, thinning uniforms, shapes),
    so adding a tenant never perturbs the others.  The merged trace is
    time-sorted with renumbered rids; per-request tenancy rides on
    ``TraceRequest.tenant``.
    """
    if not cfg.tenants:
        raise ValueError("FleetTraceConfig needs at least one tenant")
    names = [tc.name for tc in cfg.tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    ts, iis, oos, tens = [], [], [], []
    for idx, tc in enumerate(cfg.tenants):
        rng = np.random.default_rng([cfg.seed, idx])
        crowd = (np.sort(rng.uniform(0.0, cfg.horizon_s, tc.flash_crowds))
                 if tc.flash_crowds else np.zeros(0, np.float64))
        m_max = tc.envelope_max
        peak_rate = tc.trace.rate * tc.rate_scale * m_max
        t = _gen_arrivals(tc.trace, peak_rate, cfg.horizon_s, rng)
        keep = rng.random(len(t)) < tc.envelope(t, crowd) / m_max
        t = t[keep]
        ii, oo = tc.trace.shape_mix.sample(len(t), rng)
        ts.append(t)
        iis.append(ii)
        oos.append(oo)
        tens.append(np.array([tc.name] * len(t), dtype=object))
    tr = Trace.from_arrays(np.concatenate(ts), np.concatenate(iis),
                           np.concatenate(oos),
                           tenant=np.concatenate(tens),
                           horizon_s=cfg.horizon_s)
    return dataclasses.replace(tr, fleet_config=cfg)
