# Trace-driven continuous-batching serving simulator with ALA-in-the-loop
# autoscaling.  Layers:
#   traces     — workload trace generators (arrival processes x shape
#                mixes; multi-tenant fleet traces with diurnal/flash
#                envelopes and per-tenant SLO tiers)
#   simulator  — discrete-event continuous-batching replica fleet
#   fleet      — time-bucketed vectorized engine for fleet-scale runs
#                (100k+ requests; simulate(..., engine="fleet"))
#   autoscaler — control policies (static baseline, ALA-guided; consumes
#                core.online drift signals for mid-run recalibration)
#   adapter    — steady-state windows -> core.dataset.Dataset rows
#                (the delta feed for core.online.OnlineALA)
#   faults     — seed-deterministic fault plans (crash/restart cycles,
#                straggler windows, telemetry corruption) injected into
#                the simulator and the adapter stream
# Observability (spans, mergeable histograms, calibration audit,
# Perfetto export) hooks in via SimConfig.obs / ALAAutoscaler(obs=...)
# and lives in repro.obs — see docs/observability.md.
