"""Simulated-trace results -> ``core.dataset.Dataset`` rows.

Dynamic-trace scenarios feed the same registry / ALA fit path as the
static grids: chop a ``SimResult`` into fixed windows, keep the
steady-state ones (at least ``min_completions`` finished requests and
some decode work), and summarize each into one benchmark row:

  * ``ii, oo`` — power-of-two bucketed means over the window's completed
    requests (the same bucketing ``BatchingQueue`` uses, so heterogeneous
    shapes collapse into a fittable grid);
  * ``bb``     — duration-weighted mean decode batch size;
  * ``thpt``   — output tokens per *busy* second across the window's
    steps, the per-replica saturated-throughput analog of the static
    harness measurement.

``windows_to_dataset`` stamps the registry key columns (model, acc,
acc_count, back, prec, mode) so rows from a trace run sit beside — and
group separately from — static-grid rows in one ``Dataset``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.dataset import Dataset
from repro.inference.scheduler import BatchingQueue
from repro.perfmodel.simulator import ServingSetup
from repro.serving.simulator import SimResult

TRACE_BACKEND = "sim-trace"


@dataclasses.dataclass
class WindowSummary:
    t0: float
    t1: float
    ii: int                    # bucketed mean prompt length
    oo: int                    # bucketed mean output length
    bb: float                  # duration-weighted mean decode batch
    thpt: float                # output tokens / busy second
    n_completions: int


def summarize_windows(result: SimResult, window_s: float = 5.0,
                      min_completions: int = 2) -> List[WindowSummary]:
    if window_s <= 0:
        raise ValueError("window_s must be positive")
    horizon = result.sim_end_s
    n_win = max(int(np.ceil(horizon / window_s)), 1)
    steps = [[] for _ in range(n_win)]
    for s in result.steps:
        w = min(int(s.t_end / window_s), n_win - 1)
        steps[w].append(s)
    comps = [[] for _ in range(n_win)]
    for r in result.completed:
        w = min(int(r.done_s / window_s), n_win - 1)
        comps[w].append(r)
    out: List[WindowSummary] = []
    for w in range(n_win):
        cs, ss = comps[w], steps[w]
        dec = [s for s in ss if s.kind == "decode"]
        if len(cs) < min_completions or not dec:
            continue
        busy = sum(s.duration_s for s in ss)
        toks = sum(s.tokens_out for s in ss)
        if busy <= 0 or toks <= 0:
            continue
        dec_t = sum(s.duration_s for s in dec)
        bb = sum(s.bb * s.duration_s for s in dec) / max(dec_t, 1e-12)
        bii, boo = BatchingQueue.bucket(
            float(np.mean([r.ii for r in cs])),
            float(np.mean([r.oo for r in cs])))
        out.append(WindowSummary(
            t0=w * window_s, t1=min((w + 1) * window_s, horizon),
            ii=bii, oo=boo,
            bb=float(bb), thpt=toks / busy, n_completions=len(cs)))
    return out


def windows_to_rows(windows: List[WindowSummary], setup: ServingSetup,
                    model: str, back: str = TRACE_BACKEND,
                    prec: str = "bf16", mode: str = "serve"
                    ) -> List[Dict]:
    return [dict(model=model, acc=setup.hw.name, acc_count=setup.chips,
                 back=back, prec=prec, mode=mode,
                 ii=w.ii, oo=w.oo, bb=max(int(round(w.bb)), 1),
                 thpt=float(w.thpt))
            for w in windows]


def windows_to_dataset(result: SimResult, setup: ServingSetup, model: str,
                       window_s: float = 5.0, min_completions: int = 2,
                       back: str = TRACE_BACKEND) -> Dataset:
    """Steady-state windows of one simulated run as a registry dataset.

    Raises ``ValueError`` when no window reaches steady state — callers
    should lengthen the trace or shrink ``window_s`` rather than feed an
    empty dataset into a fit."""
    rows = windows_to_rows(
        summarize_windows(result, window_s, min_completions),
        setup, model, back=back)
    if not rows:
        raise ValueError("no steady-state windows in this run; "
                         "lengthen the trace or shrink window_s")
    return Dataset.from_rows(rows)
