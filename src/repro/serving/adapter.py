"""Simulated-trace results -> ``core.dataset.Dataset`` rows.

Dynamic-trace scenarios feed the same registry / ALA fit path as the
static grids: chop a ``SimResult`` into fixed windows, keep the
steady-state ones (at least ``min_completions`` finished requests and
some decode work), and summarize each into one benchmark row:

  * ``ii, oo`` — power-of-two bucketed means over the window's completed
    requests (the same bucketing ``BatchingQueue`` uses, so heterogeneous
    shapes collapse into a fittable grid);
  * ``bb``     — duration-weighted mean decode batch size;
  * ``thpt``   — output tokens per *busy* second across the window's
    steps, the per-replica saturated-throughput analog of the static
    harness measurement.

``windows_to_dataset`` stamps the registry key columns (model, acc,
acc_count, back, prec, mode) so rows from a trace run sit beside — and
group separately from — static-grid rows in one ``Dataset``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Dict, List

import numpy as np

from repro.core.dataset import Dataset
from repro.inference.scheduler import BatchingQueue
from repro.perfmodel.simulator import ServingSetup
from repro.serving.simulator import SimResult

TRACE_BACKEND = "sim-trace"


@dataclasses.dataclass
class WindowSummary:
    t0: float
    t1: float
    ii: int                    # bucketed mean prompt length
    oo: int                    # bucketed mean output length
    bb: float                  # duration-weighted mean decode batch
    thpt: float                # output tokens / busy second
    n_completions: int


def _window_overlaps(t0: float, t1: float, window_s: float, n_win: int):
    """Yield (window index, overlap fraction) for the span [t0, t1].

    Fractions sum to 1.0; a zero-duration span credits the window
    containing ``t1`` entirely."""
    if t1 <= t0:
        yield min(max(int(t1 / window_s), 0), n_win - 1), 1.0
        return
    w0 = min(max(int(t0 / window_s), 0), n_win - 1)
    w1 = min(max(int(np.ceil(t1 / window_s)) - 1, 0), n_win - 1)
    dur = t1 - t0
    for w in range(w0, w1 + 1):
        ov = min(t1, (w + 1) * window_s) - max(t0, w * window_s)
        # clipped boundary windows absorb any out-of-range span
        if w == w0:
            ov += max(w0 * window_s - t0, 0.0)
        if w == w1:
            ov += max(t1 - (w1 + 1) * window_s, 0.0)
        yield w, ov / dur


def _accumulate_slow(result: SimResult, window_s: float, n_win: int):
    """Reference per-step accumulation over ``result.steps`` records."""
    busy = np.zeros(n_win)
    toks = np.zeros(n_win)
    dec_t = np.zeros(n_win)
    bb_wt = np.zeros(n_win)
    for s in result.steps:
        for w, frac in _window_overlaps(s.t_end - s.duration_s, s.t_end,
                                        window_s, n_win):
            d = frac * s.duration_s
            busy[w] += d
            toks[w] += frac * s.tokens_out
            if s.kind == "decode":
                dec_t[w] += d
                bb_wt[w] += s.bb * d
    n_comp = np.zeros(n_win, np.int64)
    ii_sum = np.zeros(n_win)
    oo_sum = np.zeros(n_win)
    for r in result.completed:
        w = min(int(r.done_s / window_s), n_win - 1)
        n_comp[w] += 1
        ii_sum[w] += r.ii
        oo_sum[w] += r.oo
    return busy, toks, dec_t, bb_wt, n_comp, ii_sum, oo_sum


def _accumulate_fast(result, window_s: float, n_win: int):
    """Array accumulation over a ``FleetSimResult``'s raw columns —
    identical window semantics to ``_accumulate_slow`` (steps spanning
    more than one window fall back to the per-step overlap split; they
    are a ``duration / window_s`` fraction of the stream)."""
    a = result.step_arrays
    t1 = a["t_end"]
    d = a["duration_s"]
    tok = a["tokens_out"].astype(np.float64)
    dec = a["kind"] == 1
    t0 = t1 - d
    w0 = np.clip(np.floor(t0 / window_s).astype(np.int64), 0, n_win - 1)
    w1 = np.clip(np.ceil(t1 / window_s).astype(np.int64) - 1, 0,
                 n_win - 1)
    busy = np.zeros(n_win)
    toks = np.zeros(n_win)
    dec_t = np.zeros(n_win)
    bb_wt = np.zeros(n_win)
    zero = d <= 0
    if zero.any():                        # zero-duration span: window of t1
        wz = np.clip((t1[zero] / window_s).astype(np.int64), 0, n_win - 1)
        np.add.at(toks, wz, tok[zero])
    one = ~zero & (w1 <= w0)              # span inside a single window
    np.add.at(busy, w0[one], d[one])
    np.add.at(toks, w0[one], tok[one])
    oned = one & dec
    np.add.at(dec_t, w0[oned], d[oned])
    np.add.at(bb_wt, w0[oned], a["bb"][oned] * d[oned])
    multi = ~zero & (w1 > w0)             # boundary straddlers: exact split
    for i in np.flatnonzero(multi):
        for w, frac in _window_overlaps(float(t0[i]), float(t1[i]),
                                        window_s, n_win):
            dd = frac * float(d[i])
            busy[w] += dd
            toks[w] += frac * float(tok[i])
            if dec[i]:
                dec_t[w] += dd
                bb_wt[w] += float(a["bb"][i]) * dd
    q = result.req
    comp = np.isfinite(q["done_s"])
    wc = np.minimum((q["done_s"][comp] / window_s).astype(np.int64),
                    n_win - 1)
    n_comp = np.bincount(wc, minlength=n_win)
    ii_sum = np.bincount(wc, weights=q["ii"][comp].astype(np.float64),
                         minlength=n_win)
    oo_sum = np.bincount(wc, weights=q["oo"][comp].astype(np.float64),
                         minlength=n_win)
    return busy, toks, dec_t, bb_wt, n_comp, ii_sum, oo_sum


def summarize_windows(result: SimResult, window_s: float = 5.0,
                      min_completions: int = 2) -> List[WindowSummary]:
    if window_s <= 0:
        raise ValueError("window_s must be positive")
    horizon = result.sim_end_s
    if horizon <= 0:
        # degenerate run (ended at t=0): every window would have zero
        # duration — emitting them poisons downstream rate math
        return []
    n_win = max(int(np.ceil(horizon / window_s)), 1)
    # a step spanning a window boundary splits by overlap fraction —
    # crediting it entirely to the window holding t_end would bias both
    # per-window busy time and thpt (tokens / busy second)
    if getattr(result, "step_arrays", None) is not None \
            and getattr(result, "req", None) is not None:
        acc = _accumulate_fast(result, window_s, n_win)
    else:
        acc = _accumulate_slow(result, window_s, n_win)
    busy, toks, dec_t, bb_wt, n_comp, ii_sum, oo_sum = acc
    out: List[WindowSummary] = []
    for w in range(n_win):
        nc = int(n_comp[w])
        if nc < min_completions or dec_t[w] <= 0:
            continue
        if busy[w] <= 0 or toks[w] <= 0:
            continue
        t0, t1 = w * window_s, min((w + 1) * window_s, horizon)
        if t1 <= t0:                      # zero-duration clipped window
            continue
        bb = bb_wt[w] / max(dec_t[w], 1e-12)
        bii, boo = BatchingQueue.bucket(ii_sum[w] / nc, oo_sum[w] / nc)
        out.append(WindowSummary(
            t0=t0, t1=t1, ii=bii, oo=boo,
            bb=float(bb), thpt=float(toks[w] / busy[w]),
            n_completions=nc))
    return out


def windows_to_rows(windows: List[WindowSummary], setup: ServingSetup,
                    model: str, back: str = TRACE_BACKEND,
                    prec: str = "bf16", mode: str = "serve"
                    ) -> List[Dict]:
    """One benchmark row per window, keyed and *featurized* by hardware:
    besides the ``acc`` identity column the row carries the
    ``hw_*`` descriptor features (log10 delivered rooflines) so a
    hardware-conditioned model can regress across accelerators."""
    from repro.perfmodel.hardware import feature_row
    hw_cols = feature_row(setup.hw)
    return [dict(model=model, acc=setup.hw.name, acc_count=setup.chips,
                 back=back, prec=prec, mode=mode,
                 ii=w.ii, oo=w.oo, bb=max(int(round(w.bb)), 1),
                 thpt=float(w.thpt), **hw_cols)
            for w in windows]


def _finite_row(row: Dict) -> bool:
    return all(np.isfinite(float(row[k])) for k in ("ii", "oo", "bb",
                                                    "thpt"))


def windows_to_dataset(result: SimResult, setup: ServingSetup, model: str,
                       window_s: float = 5.0, min_completions: int = 2,
                       back: str = TRACE_BACKEND,
                       on_nonfinite: str = "drop") -> Dataset:
    """Steady-state windows of one simulated run as a registry dataset.

    Raises ``ValueError`` when no window reaches steady state — callers
    should lengthen the trace or shrink ``window_s`` rather than feed an
    empty dataset into a fit.  Non-finite window rows (a degenerate or
    fault-corrupted measurement) are dropped with a warning reporting
    the count (``on_nonfinite="drop"``) or raise
    (``on_nonfinite="raise"``); they never reach the fit silently.

    Heterogeneous fleets are *rejected*: a run whose replicas span more
    than one hardware profile cannot be summarized under one ``acc``
    key — windows mix steps served at different rooflines, and stamping
    them all with ``setup``'s hardware would silently corrupt the
    database.  Use ``windows_to_datasets_by_hardware`` instead.  A
    single-hardware run whose hardware disagrees with ``setup.hw`` is
    rejected for the same reason."""
    hw_names = set(getattr(result, "replica_hw", {}).values())
    if len(hw_names) > 1:
        raise ValueError(
            f"heterogeneous fleet ({sorted(hw_names)}): rows cannot share "
            f"one 'acc' key; use windows_to_datasets_by_hardware")
    if hw_names and setup.hw.name not in hw_names:
        raise ValueError(
            f"result ran on {sorted(hw_names)[0]!r} but setup names "
            f"{setup.hw.name!r}; rows would be keyed to the wrong hardware")
    rows = windows_to_rows(
        summarize_windows(result, window_s, min_completions),
        setup, model, back=back)
    n_bad = sum(1 for r in rows if not _finite_row(r))
    if n_bad:
        if on_nonfinite == "raise":
            raise ValueError(f"windows_to_dataset: {n_bad} non-finite "
                             f"window row(s)")
        warnings.warn(f"windows_to_dataset: dropped {n_bad} non-finite "
                      f"window row(s)", RuntimeWarning, stacklevel=2)
        rows = [r for r in rows if _finite_row(r)]
    if not rows:
        raise ValueError("no steady-state windows in this run; "
                         "lengthen the trace or shrink window_s")
    return Dataset.from_rows(rows)


class _HardwareView:
    """A per-hardware slice of a SimResult: only the steps / requests
    served by the given replica ids.  Quacks just enough like a
    ``SimResult`` (or ``FleetSimResult``) for ``summarize_windows``."""

    def __init__(self, result: SimResult, rids: List[int]):
        self.sim_end_s = result.sim_end_s
        self.replica_hw = {r: h for r, h in result.replica_hw.items()
                           if r in rids}
        rid_set = set(rids)
        sa = getattr(result, "step_arrays", None)
        if sa is not None and getattr(result, "req", None) is not None:
            sm = np.isin(sa["replica"], list(rid_set))
            self.step_arrays = {k: v[sm] for k, v in sa.items()}
            qm = np.isin(result.req["replica"], list(rid_set))
            self.req = {k: v[qm] for k, v in result.req.items()}
        else:
            self.step_arrays = None
            self.req = None
            self.steps = [s for s in result.steps if s.replica in rid_set]
            self.completed = [r for r in result.completed
                              if r.replica in rid_set]


def windows_to_datasets_by_hardware(
        result: SimResult, setups: Dict[str, ServingSetup], model: str,
        window_s: float = 5.0, min_completions: int = 2,
        back: str = TRACE_BACKEND, on_nonfinite: str = "drop"
        ) -> Dict[str, Dataset]:
    """Heterogeneous-fleet run -> one dataset per hardware profile.

    ``setups`` maps each hardware name in ``result.replica_hw`` to the
    ServingSetup its replicas ran (``SimConfig.setup_for`` resolves
    them).  Steps and completions are attributed to hardware through
    their replica id, so every row is keyed — and featurized — by the
    accelerator that actually served it.  Hardware whose windows never
    reach steady state is skipped with a warning (a lightly loaded tier
    is data-starved, not an error)."""
    groups: Dict[str, List[int]] = {}
    for rid, hw in sorted(result.replica_hw.items()):
        groups.setdefault(hw, []).append(rid)
    if not groups:
        raise ValueError("result carries no replica_hw attribution")
    out: Dict[str, Dataset] = {}
    for hw, rids in sorted(groups.items()):
        if hw not in setups:
            raise KeyError(f"no ServingSetup supplied for hardware {hw!r}")
        view = _HardwareView(result, rids)
        try:
            out[hw] = windows_to_dataset(
                view, setups[hw], model, window_s=window_s,
                min_completions=min_completions, back=back,
                on_nonfinite=on_nonfinite)
        except ValueError as e:
            if "steady-state" not in str(e):
                raise
            warnings.warn(f"hardware {hw!r}: {e}; skipped",
                          RuntimeWarning, stacklevel=2)
    if not out:
        raise ValueError("no hardware tier produced steady-state windows")
    return out
