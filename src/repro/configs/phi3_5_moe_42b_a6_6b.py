"""phi3.5-moe-42b-a6.6b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.models.config import BlockSpec, ModelConfig, FFN_MOE

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab_size=32_064,
    period=(BlockSpec(ffn=FFN_MOE),),
    n_experts=16, top_k=2, moe_d_ff=6400,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_head=16, d_ff=128, vocab_size=256,
                         n_experts=4, moe_d_ff=128)
