"""xlstm-125m [ssm] 12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304 —
sLSTM + mLSTM blocks (alternating).  [arXiv:2405.04517; unverified]

d_ff=0: xLSTM blocks own their up/down projections; there is no separate
FFN sub-block.
"""
from repro.models.config import (
    BlockSpec, ModelConfig, FFN_NONE, MIXER_MLSTM, MIXER_SLSTM)

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_head=192,
    d_ff=0, vocab_size=50_304,
    period=(BlockSpec(mixer=MIXER_SLSTM, ffn=FFN_NONE),
            BlockSpec(mixer=MIXER_MLSTM, ffn=FFN_NONE)),
    xlstm_proj_factor=2.0,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_head=16, vocab_size=256)
