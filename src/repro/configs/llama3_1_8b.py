"""llama3.1-8b — the paper's own in-house benchmarking subject
(LLaMA 3.1-8B served with vLLM on H100; here the JAX/TPU engine).
[arXiv:2407.21783]"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3.1-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=128_256,
    period=(BlockSpec(),),
    rope_theta=500_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_head=16, d_ff=128, vocab_size=256)
