"""Architecture registry: ``get_config(arch_id)`` + smoke-size reductions."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "llama3.2-3b",
    "qwen2.5-32b",
    "command-r-35b",
    "qwen3-0.6b",
    "llama4-maverick-400b-a17b",
    "phi3.5-moe-42b-a6.6b",
    "jamba-1.5-large-398b",
    "xlstm-125m",
    "whisper-medium",
    "internvl2-1b",
    # the paper's own measured subject (in-house dataset)
    "llama3.1-8b",
)

_MODULES = {
    "llama3.2-3b": "llama3_2_3b",
    "qwen2.5-32b": "qwen2_5_32b",
    "command-r-35b": "command_r_35b",
    "qwen3-0.6b": "qwen3_0_6b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b_a6_6b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "xlstm-125m": "xlstm_125m",
    "whisper-medium": "whisper_medium",
    "internvl2-1b": "internvl2_1b",
    "llama3.1-8b": "llama3_1_8b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke()
