"""whisper-medium [audio] 24L d_model=1024 16H (kv=16) d_ff=4096
vocab=51865 — enc-dec, conv frontend (stub).  [arXiv:2212.04356; unverified]

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, encoder_seq, d_model).
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
    d_ff=4096, vocab_size=51_865,
    period=(BlockSpec(),),
    n_encoder_layers=24, encoder_seq=1500,
    frontend="audio",
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_head=16, d_ff=128, vocab_size=256,
                         n_encoder_layers=2, encoder_seq=32)
