"""llama4-maverick-400b-a17b [moe] 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 — MoE, early fusion (dense/MoE
interleave).  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import BlockSpec, ModelConfig, FFN_DENSE, FFN_MOE

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=202_048,
    period=(BlockSpec(ffn=FFN_DENSE), BlockSpec(ffn=FFN_MOE)),
    n_experts=128, top_k=1, moe_d_ff=8192,
    rope_theta=500_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_head=16, d_ff=128, vocab_size=256,
                         n_experts=4, moe_d_ff=128)
