"""jamba-1.5-large-398b [hybrid] 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2 — Mamba+attn 1:7 interleave, MoE.
[arXiv:2403.19887; hf]

Period of 8 layers: one attention layer (position 3) per 7 mamba layers;
MoE FFN on every other layer (4 per period), dense SwiGLU on the rest.
"""
from repro.models.config import (
    BlockSpec, ModelConfig, FFN_DENSE, FFN_MOE, MIXER_ATTN, MIXER_MAMBA)

_PERIOD = tuple(
    BlockSpec(
        mixer=MIXER_ATTN if i == 3 else MIXER_MAMBA,
        ffn=FFN_MOE if i % 2 == 1 else FFN_DENSE,
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=24576, vocab_size=65_536,
    period=_PERIOD,
    n_experts=16, top_k=2, moe_d_ff=24576,
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                         d_head=16, d_ff=128, vocab_size=256,
                         n_experts=4, moe_d_ff=128)
