"""Shared smoke-shape helper used by per-arch smoke tests."""
from repro.configs.shapes import ShapeSpec

SMOKE_TRAIN = ShapeSpec("smoke_train", seq_len=32, global_batch=2,
                        kind="train")
SMOKE_PREFILL = ShapeSpec("smoke_prefill", seq_len=32, global_batch=2,
                          kind="prefill")
SMOKE_DECODE = ShapeSpec("smoke_decode", seq_len=32, global_batch=2,
                         kind="decode")
