"""Assigned input-shape grid. Each shape names the step it lowers."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeSpec:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def cell_applicable(cfg, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runs?, reason). long_500k only for sub-quadratic decoders."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 512k dense-KV decode skipped per assignment"
    return True, ""
