"""internvl2-1b [vlm] 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2/Qwen2 backbone.
[arXiv:2404.16821; hf]

The ViT frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, n_patches, d_model) prepended to the text
sequence.
"""
from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_head=64,
    d_ff=4864, vocab_size=151_655,
    period=(BlockSpec(),),
    qkv_bias=True,
    frontend="vision", n_patches=256,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_head=16, d_ff=128, vocab_size=256, n_patches=8)
