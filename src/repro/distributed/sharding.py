"""Sharding policy: logical activation rules + parameter partition specs.

Design
------
* A :class:`ShardingPolicy` binds a mesh to *logical rules*.  Model code
  calls ``constrain(x, "act_qkv")`` at a handful of points; outside a policy
  context this is a no-op, so single-device tests never touch device state.
* Every rule is a priority list of ``(dim, axes)`` preferences.  Each
  preference is applied greedily iff the dim size is divisible by the mesh
  axes' product and the axes are not already used — this makes one rule set
  work across all 10 architectures (heads that don't divide the TP width
  fall back to sequence/context parallelism instead of failing).
* Parameter specs are derived from (path, shape): hidden/vocab/expert dims
  go over ``model``; ZeRO-1 additionally shards optimizer state over
  ``data`` on the first free divisible dim.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)
    # rule name -> priority list of (dim, "data"|"model")
    rules: Optional[dict] = None
    # hillclimb knobs
    seq_parallel_attn: bool = True      # allow CP fallback on seq dims
    zero1: bool = True                   # shard optimizer state over data
    shard_scores_dhead: bool = False     # last-resort d_head sharding
    # serving: weights are read-only -> shard them over data too (2D weight
    # sharding across the whole slice, vLLM-style full TP), bf16 params.
    # serving_2d False keeps weights TP-only (replicated over data): no
    # per-step weight all-gathers — the right choice whenever params fit
    # HBM (hillclimb iteration 1; see EXPERIMENTS.md §Perf).
    serving: bool = False
    serving_2d: bool = True
    # context-parallel prefill (hillclimb iteration 2): when attention
    # heads don't divide the TP width, replicate block weights over
    # ``model`` and shard the sequence end-to-end instead of bouncing
    # between seq- and head-sharding per layer.
    cp_replicate_weights: bool = False
    # shard_map expert-parallel MoE (hillclimb iteration 3) — local
    # dispatch + psum instead of GSPMD's replicated-buffer scatter.
    ep_moe: bool = True

    def __post_init__(self):
        if self.rules is None:
            self.rules = dict(DEFAULT_RULES)

    def resolve(self, name: str, shape: Sequence[int]) -> P:
        prefs = self.rules.get(name)
        if self.cp_replicate_weights and name == "act_btd" and \
                len(shape) >= 2:
            prefs = [(0, "data"), (1, "model")]
        if prefs is None:
            return P()
        spec = [None] * len(shape)
        used: set = set()
        for dim, group in prefs:
            if dim >= len(shape) or spec[dim] is not None:
                continue
            axes = self.data_axes if group == "data" else self.model_axes
            if any(a in used for a in axes):
                continue
            if not self.seq_parallel_attn and name.startswith("act") and \
                    dim in (1,) and group == "model":
                continue
            if shape[dim] % _axes_size(self.mesh, axes) == 0:
                spec[dim] = axes if len(axes) > 1 else axes[0]
                used.update(axes)
        return P(*spec)

    def named(self, name: str, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(name, shape))


# Activation rules. dims refer to the logical layout noted per rule.
DEFAULT_RULES = {
    # (B, S, D)
    "act_btd": [(0, "data"), (1, "data")],
    # (B, S, H, Dh) query/out projections
    "act_qkv": [(0, "data"), (2, "model"), (1, "model"), (1, "data")],
    # (B, S, KV, Dh)
    "act_kv": [(0, "data"), (2, "model"), (1, "model"), (1, "data")],
    # (B, T, KV, Dh) decode-time cache — prefer sharding the long T axis
    # over model when KV heads don't divide (distributed flash-decoding).
    "kv_cache": [(0, "data"), (2, "model"), (1, "model"), (1, "data")],
    # (E, C, D) MoE expert-major buffers
    "moe_ecd": [(0, "model"), (1, "data")],
    # (B, S, F) mlp hidden
    "act_bsf": [(0, "data"), (2, "model"), (1, "model")],
    # (B, L, d_inner, d_state) mamba scan states (chunk-local)
    "mamba_h": [(0, "data"), (2, "model"), (1, "data")],
    # (B, d_inner, d_state) mamba decode state
    "mamba_state": [(0, "data"), (1, "model")],
    # (B, S, d_inner)
    "act_bsi": [(0, "data"), (2, "model"), (1, "model")],
    # (B, H, Dq, Dv) mlstm matrix state
    "mlstm_state": [(0, "data"), (1, "model"), (2, "model")],
    # (B, H, Dk) mlstm normalizer
    "mlstm_n": [(0, "data"), (1, "model")],
    # (B, Dp) slstm scalar state
    "slstm_state": [(0, "data"), (1, "model")],
    # (B, dc-1, d_inner) mamba conv carry
    "mamba_conv": [(0, "data"), (2, "model")],
    # (B, V) / (B, S, V) logits
    "logits": [(0, "data"), (-1, "model")],
}


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    prev = getattr(_STATE, "policy", None)
    _STATE.policy = policy
    try:
        yield policy
    finally:
        _STATE.policy = prev


def get_policy() -> Optional[ShardingPolicy]:
    return getattr(_STATE, "policy", None)


def constrain(x, rule: str):
    policy = get_policy()
    if policy is None:
        return x
    spec = policy.resolve(rule, x.shape)
    if rule == "logits":
        # negative-dim rules resolved against concrete rank
        spec = policy.resolve_logits(x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(policy.mesh, spec))


def _resolve_logits(self, shape):
    spec = [None] * len(shape)
    if shape[0] % _axes_size(self.mesh, self.data_axes) == 0:
        spec[0] = (self.data_axes if len(self.data_axes) > 1
                   else self.data_axes[0])
    if shape[-1] % _axes_size(self.mesh, self.model_axes) == 0:
        spec[-1] = (self.model_axes if len(self.model_axes) > 1
                    else self.model_axes[0])
    return P(*spec)


ShardingPolicy.resolve_logits = _resolve_logits


# ---------------------------------------------------------------------------
# Parameter partition specs
# ---------------------------------------------------------------------------

# (path regex, preferences) — dims are *after* stripping any leading
# period-stack dim (handled by param_spec). "model"/"data" groups as above.
_PARAM_RULES = [
    (r"tok_embed$", [(0, "model")]),
    (r"lm_head$", [(1, "model")]),
    (r"(wq|wk|wv)$", [(1, "model"), (2, "model"), (0, "model")]),
    (r"wo$", [(0, "model"), (1, "model"), (2, "model")]),
    (r"(w_gate|w_up)$", [(1, "model")]),
    (r"w_down$", [(0, "model")]),
    # MoE experts: (E, D, F) — expert parallelism on E.
    (r"experts/.*$", [(0, "model")]),
    (r"router.*$", []),
    # Mamba: shard d_inner wherever it appears.
    (r"mamba/in_proj$", [(1, "model")]),
    (r"mamba/(conv_w|conv_b|A_log|D|dt_bias)$", [(0, "model")]),
    (r"mamba/x_proj$", [(0, "model")]),
    (r"mamba/dt_proj$", [(1, "model")]),
    (r"mamba/out_proj$", [(0, "model")]),
    # xLSTM inner projections
    (r"(up_proj|gate_proj)$", [(1, "model")]),
    (r"down_proj$", [(0, "model")]),
    (r"(wqk|wv2)$", [(1, "model")]),
    (r"conv1d.*$", [(0, "model")]),
]


def param_spec(path: str, shape: Sequence[int], policy: ShardingPolicy,
               stacked: bool = False, for_opt_state: bool = False) -> P:
    """PartitionSpec for a parameter leaf.

    ``stacked`` marks per-period scan stacks whose dim0 is the period count.
    Optimizer-state variants (ZeRO-1) add ``data`` on the first free
    divisible dim.
    """
    offset = 1 if stacked else 0
    spec = [None] * len(shape)
    if policy.cp_replicate_weights and stacked:
        # context-parallel mode: block weights replicated over model;
        # only the (huge) embedding / lm_head stay model-sharded.
        if policy.serving and policy.serving_2d:
            for d in range(offset, len(shape)):
                if shape[d] % _axes_size(policy.mesh, policy.data_axes) == 0:
                    spec[d] = (policy.data_axes if len(policy.data_axes) > 1
                               else policy.data_axes[0])
                    break
        return P(*spec)
    for pat, prefs in _PARAM_RULES:
        if re.search(pat, path):
            used = set()
            for dim, group in prefs:
                d = dim + offset
                if d >= len(shape) or spec[d] is not None:
                    continue
                axes = (policy.model_axes if group == "model"
                        else policy.data_axes)
                if any(a in used for a in axes):
                    continue
                if shape[d] % _axes_size(policy.mesh, axes) == 0:
                    spec[d] = axes if len(axes) > 1 else axes[0]
                    used.update(axes)
            break
    if (for_opt_state and policy.zero1) or \
            (policy.serving and policy.serving_2d):
        used_names = {a for s in spec if s is not None
                      for a in (s if isinstance(s, tuple) else (s,))}
        if not any(a in used_names for a in policy.data_axes):
            for d in range(len(shape)):
                if spec[d] is None and shape[d] % _axes_size(
                        policy.mesh, policy.data_axes) == 0:
                    spec[d] = (policy.data_axes if len(policy.data_axes) > 1
                               else policy.data_axes[0])
                    break
    return P(*spec)


def tree_param_specs(params, policy: ShardingPolicy,
                     for_opt_state: bool = False):
    """Map a param pytree -> pytree of PartitionSpec (period stacks under
    any path containing 'blocks' get their leading dim skipped)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    specs = []
    for keypath, leaf in flat:
        path = "/".join(_key_str(k) for k in keypath)
        stacked = "blocks" in path
        specs.append(param_spec(path, leaf.shape, policy,
                                stacked=stacked,
                                for_opt_state=for_opt_state))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tree_shardings(params, policy: ShardingPolicy, **kw):
    specs = tree_param_specs(params, policy, **kw)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(policy.mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)
