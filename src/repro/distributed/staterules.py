"""PartitionSpecs for decode caches / recurrent state (period-stacked)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingPolicy
from repro.models.attention import KVCache
from repro.models.ssm import MLSTMState, MambaState, SLSTMState
from repro.models.transformer import DecodeCache


def _prepend_none(spec: P) -> P:
    return P(None, *spec)


def _state_spec(policy: ShardingPolicy, st, stacked: bool):
    """Spec pytree for one block state (shapes possibly period-stacked)."""
    off = 1 if stacked else 0

    def shp(t):
        return t.shape[off:]

    if isinstance(st, KVCache):
        s = policy.resolve("kv_cache", shp(st.k))
        s = _prepend_none(s) if stacked else s
        return KVCache(k=s, v=s)
    if isinstance(st, MambaState):
        conv = policy.resolve("mamba_conv", shp(st.conv))
        ssm = policy.resolve("mamba_state", shp(st.ssm))
        if stacked:
            conv, ssm = _prepend_none(conv), _prepend_none(ssm)
        return MambaState(conv=conv, ssm=ssm)
    if isinstance(st, MLSTMState):
        c = policy.resolve("mlstm_state", shp(st.C))
        n = policy.resolve("mlstm_n", shp(st.n))
        if stacked:
            c, n = _prepend_none(c), _prepend_none(n)
        return MLSTMState(C=c, n=n)
    if isinstance(st, SLSTMState):
        s = policy.resolve("slstm_state", shp(st.c))
        s = _prepend_none(s) if stacked else s
        return SLSTMState(c=s, n=s, h=s)
    if st is None:
        return None
    raise TypeError(type(st))


def decode_cache_specs(policy: ShardingPolicy, cache: DecodeCache):
    blocks = tuple(_state_spec(policy, st, stacked=True)
                   for st in cache.blocks)
    cross = None
    if cache.cross is not None:
        cross = tuple(_state_spec(policy, kv, stacked=True)
                      for kv in cache.cross)
    return DecodeCache(blocks=blocks, cross=cross, pos=P())


def decode_cache_shardings(policy: ShardingPolicy, cache: DecodeCache):
    specs = decode_cache_specs(policy, cache)
    return jax.tree.map(lambda s: NamedSharding(policy.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
