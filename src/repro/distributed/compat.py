"""JAX version-compat shims for the distributed layer.

The public ``shard_map`` moved twice across JAX releases: old versions
only ship ``jax.experimental.shard_map.shard_map`` (whose replication
check is spelled ``check_rep``); newer ones export ``jax.shard_map``
(spelled ``check_vma``).  Every call site goes through this wrapper so
the rest of the codebase can target the modern signature.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` when available, else the experimental fallback
    (translating ``check_vma`` to the legacy ``check_rep`` keyword)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a per-device list on older
    JAX and a flat dict on newer — normalize to a dict either way."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}
