"""Expert-parallel MoE via shard_map (hillclimb iteration 3).

The GSPMD lowering of scatter-based MoE dispatch cannot partition a
scatter whose indices cross shards: it replicates the (E, C, D) dispatch
buffer on every device and combines contributions with full-buffer
all-reduces (~13 GB per MoE layer for llama4-maverick at train_4k;
measured 326 GB of all-reduce per period — see EXPERIMENTS.md §Perf).

This implementation makes the dispatch *local by construction*:

  device (i, j) holds tokens of data-shard i and experts of model-shard j
    1. route locally (router weights are replicated),
    2. keep only assignments to the local expert block [j*E_loc, ...),
    3. local sort -> rank -> capacity-bucketed local scatter,
    4. local expert FFN (weights already sharded over `model` on E),
    5. local combine back to token order, weighted by gate values,
    6. one psum over `model` sums each token's expert contributions.

Collectives per layer: a single (T_loc, D) psum (plus scalar aux-loss
psums) instead of replicated-buffer all-reduces.  Capacity semantics are
per-data-shard (capacity_factor applies within each shard), the standard
distributed-capacity variant (MaxText/GShard do the same).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.models.config import ModelConfig


def _axis_sizes(policy):
    n_model = 1
    for a in policy.model_axes:
        n_model *= policy.mesh.shape[a]
    n_data = 1
    for a in policy.data_axes:
        n_data *= policy.mesh.shape[a]
    return n_data, n_model


def ep_available(cfg: ModelConfig, policy, batch: int = 0,
                 seq: int = 0) -> bool:
    if policy is None:
        return False
    n_data, n_model = _axis_sizes(policy)
    if cfg.n_experts % n_model or n_model <= 1:
        return False
    if batch and seq:
        # tokens must shard over data on either the batch or seq dim
        return batch % n_data == 0 or seq % n_data == 0
    return True


def moe_ffn_ep(cfg: ModelConfig, params, x, policy):
    """x: (B, S, D) -> (out, aux).  Drop-in for moe.moe_ffn."""
    mesh = policy.mesh
    data_axes = tuple(policy.data_axes)
    model_ax = policy.model_axes[0]
    n_data, n_model = _axis_sizes(policy)
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // n_model
    d = cfg.d_model
    dtype = cfg.compute_dtype

    b, s, _ = x.shape
    t_loc = (b * s) // n_data
    cap = max(8, int(cfg.capacity_factor * k * t_loc / e) + 1)
    cap = ((cap + 7) // 8) * 8

    def local_fn(x_loc, router_w, wg, wu, wd):
        bl, sl, _ = x_loc.shape
        t = bl * sl
        xt = x_loc.reshape(t, d)
        logits = jnp.einsum("td,de->te", xt,
                            router_w.astype(dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        if k > 1:
            gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1,
                                            keepdims=True)
        # aux loss from global stats (psum over data shards)
        me = jax.lax.pmean(jnp.mean(probs, axis=0), data_axes)
        ce = jax.lax.pmean(
            jnp.mean(jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32),
                     axis=0), data_axes)
        aux = e * jnp.sum(me * ce)

        # local expert block
        j = jax.lax.axis_index(model_ax)
        e_start = j * e_loc
        flat_e = gate_idx.reshape(-1)
        flat_g = gate_vals.reshape(-1)
        flat_t = (jnp.repeat(jnp.arange(t), k) if k > 1
                  else jnp.arange(t))
        local = (flat_e >= e_start) & (flat_e < e_start + e_loc)
        le = jnp.where(local, flat_e - e_start, e_loc)   # e_loc = "dropped"
        order = jnp.argsort(le)
        se, st, sg = le[order], flat_t[order], flat_g[order]
        starts = jnp.searchsorted(se, jnp.arange(e_loc))
        rank = jnp.arange(se.shape[0]) - starts[jnp.clip(se, 0, e_loc - 1)]
        keep = (se < e_loc) & (rank < cap)
        slot_e = jnp.where(keep, se, 0)
        slot_c = jnp.where(keep, rank, 0)

        gathered = xt[st] * keep[:, None].astype(dtype)
        buf = jnp.zeros((e_loc, cap, d), dtype)
        buf = buf.at[slot_e, slot_c].add(gathered)

        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(dtype))
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))

        contrib = out_buf[slot_e, slot_c] \
            * (sg * keep).astype(dtype)[:, None]
        yt = jnp.zeros_like(xt)
        yt = yt.at[st].add(contrib)
        # sum each token's expert contributions across model shards
        yt = jax.lax.psum(yt, model_ax)
        return yt.reshape(bl, sl, d), aux

    w = params["experts"]
    batch_spec = data_axes if len(data_axes) > 1 else data_axes[0]
    if b % n_data == 0:
        x_spec = P(batch_spec, None, None)
    else:
        # small-batch serving (e.g. long-context bb=1): shard tokens on seq
        x_spec = P(None, batch_spec, None)
    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(), P(model_ax), P(model_ax), P(model_ax)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], w["w_gate"], w["w_up"], w["w_down"])
    return out, aux
