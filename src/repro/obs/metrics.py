"""Low-overhead streaming metrics: counters, gauges, and mergeable
fixed-bin histograms.

The histogram shares the ``SubsetBank`` *fixed-bin contract*
(``repro.core.uncertainty``): edges derive from a fixed [lo, hi] range
(geomspace for decade-spanning features, linspace otherwise), the two
boundary bins are reserved for out-of-range mass, and bin *assignment*
compares float32 values against float32 edges via
``searchsorted(side="right")`` — so a histogram built here buckets
exactly like the uncertainty bank does, and two shards built on the
same edges merge by plain addition.  ``tests/test_obs_metrics.py``
pins ``fixed_edges`` against ``uncertainty._bank_edges`` per feature.

Inf-mass convention (shared with ``percentile_with_inf``): shed /
never-served requests carry TTFT = +inf.  ``StreamHist`` keeps that
mass in explicit ``n_inf`` / ``n_neg_inf`` counters outside the finite
bins, and ``quantile`` returns the signed infinity whenever the
requested rank lands inside an inf mass — a run that shed half its
traffic can never report a rosy p95 from a histogram any more than it
can from the raw values.  NaN observations carry *no* mass (tracked in
``n_nan`` for accounting, excluded from quantiles).

``percentile_with_inf`` lives here (moved from
``repro.serving.simulator``, which re-exports it) — the single exact
percentile used by both serving engines and by every obs consumer.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

__all__ = [
    "percentile_with_inf", "fixed_edges", "bucketize", "StreamHist",
    "Counter", "Gauge", "RingLog", "tenant_rollup",
]


def percentile_with_inf(vals: np.ndarray, q: float) -> float:
    """Linear-interpolation percentile that tolerates an inf mass.

    ``np.percentile`` returns NaN when the quantile straddles infs
    (inf - inf inside its lerp); the correct answer there is inf, and on
    finite data this matches numpy exactly."""
    vals = np.asarray(vals, np.float64)
    if vals.size == 0:
        return float("inf")
    svals = np.sort(vals)
    pos = (len(svals) - 1) * q / 100.0
    lo = int(np.floor(pos))
    frac = pos - lo
    if frac == 0.0:
        return float(svals[lo])
    if not np.isfinite(svals[lo + 1]):
        return float("inf")
    return float(svals[lo] * (1.0 - frac) + svals[lo + 1] * frac)


def fixed_edges(lo: float, hi: float, n_bins: int,
                log: bool = False) -> np.ndarray:
    """(B-1,) float32 inner bucketize edges — the ``SubsetBank``
    contract for one feature.

    The [lo, hi] range splits into the B-2 core bins; the first inner
    edge sits at ``lo`` (``side="right"`` keeps v == lo in the core)
    and the last one ulp above ``hi``, so in-range values never occupy
    bins 0 / B-1 — those boundary bins are reserved for out-of-range
    mass, exactly like ``uncertainty._bank_edges``."""
    if n_bins < 3:
        raise ValueError(f"n_bins {n_bins} < 3 (need core + 2 boundary)")
    if log:
        lo = max(float(lo), 1e-9)
        hi = max(float(hi), lo * (1 + 1e-9))
        core = np.geomspace(lo, hi, n_bins - 1)[1:-1]
    else:
        lo = float(lo)
        hi = float(hi) if hi > lo else lo + 1.0
        core = np.linspace(lo, hi, n_bins - 1)[1:-1]
    lo32, hi32 = np.float32(lo), np.float32(hi)
    edges = np.concatenate(
        [[lo32], core.astype(np.float32),
         [np.nextafter(hi32, np.float32(np.inf))]])
    # float32 rounding of near-equal float64 edges must stay sorted
    return np.maximum.accumulate(edges)


def bucketize(vals: np.ndarray, inner_f32: np.ndarray) -> np.ndarray:
    """Fixed-bin assignment (float32 compare, out-of-range values clip
    into the boundary bins) — identical to the bank kernel's
    searchsorted."""
    return np.searchsorted(inner_f32, np.asarray(vals, np.float32),
                           side="right").astype(np.int32)


@dataclasses.dataclass
class StreamHist:
    """Mergeable fixed-bin histogram with explicit inf/NaN mass.

    Build once from a fixed range (``from_range``) or from a sample
    (``from_values``), feed it value batches with ``observe``, merge
    shards built on the same edges with ``merge`` — counts add, so
    merge order never matters and shard-merge quantiles equal the
    whole-stream quantiles exactly.  ``quantile`` is accurate to one
    bin width on finite mass and honors the inf-mass convention."""
    inner_edges: np.ndarray               # (B-1,) float32
    counts: np.ndarray                    # (B,) float64 finite mass
    n_inf: float = 0.0                    # +inf mass (the miss mass)
    n_neg_inf: float = 0.0
    n_nan: float = 0.0                    # tracked, never mass

    @classmethod
    def from_range(cls, lo: float, hi: float, n_bins: int = 48,
                   log: bool = False) -> "StreamHist":
        return cls(inner_edges=fixed_edges(lo, hi, n_bins, log=log),
                   counts=np.zeros(n_bins, np.float64))

    @classmethod
    def from_values(cls, vals: np.ndarray, n_bins: int = 48,
                    log: bool = False) -> "StreamHist":
        """Edges from the finite value range, then observe everything
        (inf/NaN land in their explicit masses)."""
        vals = np.asarray(vals, np.float64)
        fin = vals[np.isfinite(vals)]
        lo = float(fin.min()) if len(fin) else 0.0
        hi = float(fin.max()) if len(fin) else 1.0
        h = cls.from_range(lo, hi, n_bins, log=log)
        h.observe(vals)
        return h

    @property
    def n_bins(self) -> int:
        return len(self.counts)

    @property
    def total(self) -> float:
        """Total observed mass (finite + inf; NaN excluded)."""
        return float(self.counts.sum() + self.n_inf + self.n_neg_inf)

    def observe(self, vals: np.ndarray,
                weights: Optional[np.ndarray] = None) -> "StreamHist":
        vals = np.atleast_1d(np.asarray(vals, np.float64))
        w = np.ones(len(vals)) if weights is None \
            else np.asarray(weights, np.float64)
        nan = np.isnan(vals)
        pos = np.isposinf(vals)
        neg = np.isneginf(vals)
        self.n_nan += float(w[nan].sum())
        self.n_inf += float(w[pos].sum())
        self.n_neg_inf += float(w[neg].sum())
        fin = ~(nan | pos | neg)
        if fin.any():
            bins = bucketize(vals[fin], self.inner_edges)
            self.counts += np.bincount(bins, w[fin],
                                       minlength=self.n_bins)
        return self

    def merge(self, other: "StreamHist") -> "StreamHist":
        """Accumulate another shard in place (edges must match)."""
        if not np.array_equal(self.inner_edges, other.inner_edges):
            raise ValueError("cannot merge histograms with different edges")
        self.counts = self.counts + other.counts
        self.n_inf += other.n_inf
        self.n_neg_inf += other.n_neg_inf
        self.n_nan += other.n_nan
        return self

    def copy(self) -> "StreamHist":
        return StreamHist(inner_edges=self.inner_edges,
                          counts=self.counts.copy(), n_inf=self.n_inf,
                          n_neg_inf=self.n_neg_inf, n_nan=self.n_nan)

    @classmethod
    def merged(cls, hists: Iterable["StreamHist"]) -> "StreamHist":
        out = None
        for h in hists:
            out = h.copy() if out is None else out.merge(h)
        if out is None:
            raise ValueError("nothing to merge")
        return out

    def quantile(self, q: float) -> float:
        """q-th percentile of the observed mass.

        Mass ordering: [-inf][finite bins, interpolated][+inf].  A rank
        inside an inf mass returns that signed infinity — the same miss
        convention as ``percentile_with_inf``.  Finite answers are
        linear within the bin (boundary bins collapse to their single
        known edge), so the error vs the exact percentile is at most
        one bin width for in-range data."""
        tot = self.total
        if tot <= 0:
            return float("inf")
        target = q / 100.0 * tot
        if self.n_neg_inf > 0 and target <= self.n_neg_inf:
            return float("-inf")
        cum = self.n_neg_inf + np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        if i >= self.n_bins or self.counts[i:].sum() <= 0:
            return float("inf")
        e = self.inner_edges.astype(np.float64)
        if i == 0:                         # below-range boundary bin
            return float(e[0])
        if i == self.n_bins - 1:           # above-range boundary bin
            return float(e[-1])
        lo_e, hi_e = float(e[i - 1]), float(e[i])
        prev = float(cum[i - 1]) if i else self.n_neg_inf
        frac = (target - prev) / max(float(cum[i]) - prev, 1e-300)
        return lo_e + frac * (hi_e - lo_e)

    def to_dict(self) -> Dict[str, object]:
        return {"edges": self.inner_edges.astype(float).tolist(),
                "counts": self.counts.tolist(), "n_inf": self.n_inf,
                "n_neg_inf": self.n_neg_inf, "n_nan": self.n_nan}


@dataclasses.dataclass
class Counter:
    """Streaming monotone counter; merges by addition."""
    value: float = 0.0

    def inc(self, k: float = 1.0) -> None:
        self.value += k

    def merge(self, other: "Counter") -> "Counter":
        self.value += other.value
        return self


@dataclasses.dataclass
class Gauge:
    """Streaming summary of a sampled series (no raw retention)."""
    n: int = 0
    sum: float = 0.0
    min: float = float("inf")
    max: float = float("-inf")
    last: float = float("nan")

    def set(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.last = v

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else float("nan")

    def merge(self, other: "Gauge") -> "Gauge":
        if other.n:
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)
            self.sum += other.sum
            self.n += other.n
            self.last = other.last
        return self


class RingLog(Sequence):
    """Bounded append-only log: keeps the most recent ``cap`` entries
    while counting everything — ``n_total`` stays lossless even when
    samples are dropped, so 10M-request runs can't grow telemetry
    unboundedly but accounting still adds up.  Duck-types as a list for
    the common consumers (append / len / iterate / index)."""

    def __init__(self, cap: int, init: Iterable = ()):
        if cap < 1:
            raise ValueError(f"RingLog cap {cap} < 1")
        self.cap = int(cap)
        init = list(init)
        self._dq: collections.deque = collections.deque(init,
                                                        maxlen=self.cap)
        self.n_total = len(init)

    def append(self, item) -> None:
        self._dq.append(item)
        self.n_total += 1

    def extend(self, items: Iterable) -> None:
        for it in items:
            self.append(it)

    def clear(self) -> None:
        # drops the retained window; total stays lossless
        self._dq.clear()

    @property
    def n_dropped(self) -> int:
        return self.n_total - len(self._dq)

    def __len__(self) -> int:
        return len(self._dq)

    def __iter__(self):
        return iter(self._dq)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return list(self._dq)[i]
        return self._dq[i]

    def __repr__(self) -> str:
        return (f"RingLog(cap={self.cap}, kept={len(self._dq)}, "
                f"total={self.n_total})")


def tenant_rollup(tenant: np.ndarray, ttft_vals: np.ndarray,
                  oo: np.ndarray, completed: np.ndarray,
                  shed: np.ndarray, retries: np.ndarray,
                  slo_map: Optional[Dict[str, float]] = None
                  ) -> Dict[str, Dict[str, float]]:
    """Per-tenant request accounting, TTFT tail and SLO attainment —
    the single rollup behind ``SimResult.per_tenant`` in both serving
    engines.

    ``ttft_vals`` follows the shared miss convention (inf for shed /
    no-first-token requests); tenants absent from ``slo_map`` get
    ``attainment = nan``; ``goodput_share`` is the tenant's fraction of
    completed output tokens."""
    tenant = np.asarray(tenant, dtype=object)
    ttft_vals = np.asarray(ttft_vals, np.float64)
    oo = np.asarray(oo, np.int64)
    completed = np.asarray(completed, bool)
    shed = np.asarray(shed, bool)
    retries = np.asarray(retries, np.int64)
    total_tok = int(oo[completed].sum())
    out: Dict[str, Dict[str, float]] = {}
    for name in sorted(set(tenant.tolist())):
        m = tenant == name
        v = ttft_vals[m]
        slo = slo_map.get(name) if slo_map else None
        tok = int(oo[m & completed].sum())
        out[name] = {
            "n_requests": int(m.sum()),
            "n_completed": int((m & completed).sum()),
            "n_shed": int(shed[m].sum()),
            "n_retries": int(retries[m].sum()),
            "ttft_slo_s": float(slo) if slo is not None else float("nan"),
            "attainment": (float(np.mean(v <= slo)) if slo is not None
                           else float("nan")),
            "ttft_p50_s": percentile_with_inf(v, 50.0),
            "ttft_p95_s": percentile_with_inf(v, 95.0),
            "ttft_p99_s": percentile_with_inf(v, 99.0),
            "goodput_share": tok / total_tok if total_tok else 0.0,
        }
    return out
