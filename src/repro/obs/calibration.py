"""ALA calibration audit: does predicted trust track realized error?

The audit is a typed event stream fed from two places — every
``ALAAutoscaler`` control tick (predicted vs realized throughput, the
Alg 7 predicted error, the Alg 8 confidence) and every ``OnlineALA``
ingest (refit / drift / quarantine outcomes) — plus the autoscaler's
degradation and recalibration decisions, unified into one log.  From
the tick stream it derives the two headline calibration artifacts:

* predicted-vs-realized APE (is Alg 7's error estimate honest?), and
* a confidence **reliability curve** — binned Alg 8 confidence against
  the empirical accuracy rate (APE <= ``ape_ok_pct``) in each bin,
  optionally monotonized with pool-adjacent-violators so the curve is
  non-decreasing in confidence, as a well-calibrated score must be.

Events live in a ``RingLog`` when ``ObsConfig.max_cal_events`` is set;
``counts`` stays lossless per kind either way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import numpy as np

from repro.obs.metrics import RingLog

__all__ = ["CalEvent", "CalibrationAudit", "reliability_curve", "pav"]

EVENT_KINDS = ("tick", "drift", "quarantine", "refit", "recalibration",
               "degradation", "decision")


@dataclasses.dataclass
class CalEvent:
    """One audit event.  ``t`` is sim-time seconds for autoscaler-fed
    events and the (float) online epoch for ingest-fed ones — the
    ``clock`` field says which."""
    t: float
    kind: str                         # one of EVENT_KINDS
    clock: str = "sim"                # "sim" | "epoch"
    data: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, object]:
        return {"t": self.t, "kind": self.kind, "clock": self.clock,
                **self.data}


class CalibrationAudit:
    """Unified predict→observe→trust event log (see module docstring)."""

    def __init__(self, cfg=None):
        cap = getattr(cfg, "max_cal_events", None) if cfg else None
        self.events: Union[List[CalEvent], RingLog] = \
            RingLog(cap) if cap else []
        self.counts: Dict[str, int] = {}
        self.ape_ok_pct = float(getattr(cfg, "ape_ok_pct", 25.0)
                                if cfg else 25.0)
        self.reliability_bins = int(getattr(cfg, "reliability_bins", 10)
                                    if cfg else 10)

    def event(self, t: float, kind: str, clock: str = "sim",
              **data) -> CalEvent:
        ev = CalEvent(t=float(t), kind=kind, clock=clock, data=data)
        self.counts[kind] = self.counts.get(kind, 0) + 1
        self.events.append(ev)
        return ev

    # -- autoscaler feed -----------------------------------------------------
    def tick(self, t: float, predicted: float, measured: float,
             confidence: float, ape: Optional[float] = None,
             pred_err: float = float("nan")) -> CalEvent:
        """One control-tick observation: Alg 4 predicted throughput vs
        the realized window measurement, with the Alg 7 predicted error
        and Alg 8 confidence attached."""
        if ape is None:
            ape = (abs(predicted - measured) / measured * 100.0
                   if measured > 0 and np.isfinite(predicted)
                   else float("inf"))
        return self.event(t, "tick", predicted=float(predicted),
                          measured=float(measured),
                          confidence=float(confidence), ape=float(ape),
                          pred_err=float(pred_err))

    # -- online-loop feed ----------------------------------------------------
    def ingest_report(self, report) -> None:
        """Fold one ``RefitReport`` into the log (epoch clock)."""
        t = float(report.epoch)
        for combo, sig in report.drift.items():
            if sig.drifted:
                self.event(t, "drift", clock="epoch",
                           combo="/".join(combo), reason=sig.reason,
                           confidence=float(sig.confidence),
                           pred_err=float(sig.pred_err),
                           resid_ape=float(sig.resid_ape))
        if report.n_quarantined:
            self.event(t, "quarantine", clock="epoch",
                       n_rows=int(report.n_quarantined))
        self.event(t, "refit", clock="epoch",
                   n_changed=len(report.changed),
                   n_refit=len(report.refit),
                   n_skipped=len(report.skipped),
                   wall_s=float(report.wall_s))

    # -- views ---------------------------------------------------------------
    def ticks(self) -> Dict[str, np.ndarray]:
        """Column view of the retained tick events."""
        evs = [e for e in self.events if e.kind == "tick"]
        return {k: np.array([e.data[k] for e in evs], np.float64)
                for k in ("predicted", "measured", "confidence", "ape",
                          "pred_err")} | \
            {"t": np.array([e.t for e in evs], np.float64)}

    def reliability(self, n_bins: Optional[int] = None,
                    monotone: bool = True) -> Dict[str, List[float]]:
        tk = self.ticks()
        ok = (tk["ape"] <= self.ape_ok_pct).astype(np.float64)
        return reliability_curve(tk["confidence"], ok,
                                 n_bins or self.reliability_bins,
                                 monotone=monotone)

    def summary(self) -> Dict[str, object]:
        tk = self.ticks()
        ape = tk["ape"]
        fin = ape[np.isfinite(ape)]
        pe = tk["pred_err"]
        pe_fin = pe[np.isfinite(pe)]
        out: Dict[str, object] = {
            "n_events": dict(sorted(self.counts.items())),
            "n_events_retained": len(self.events),
            "ape_ok_pct": self.ape_ok_pct,
            "n_ticks": int(len(ape)),
            "median_ape": float(np.median(fin)) if len(fin) else
            float("inf"),
            "median_confidence": (float(np.median(tk["confidence"]))
                                  if len(ape) else float("nan")),
            "accuracy_rate": (float(np.mean(ape <= self.ape_ok_pct))
                              if len(ape) else float("nan")),
            "median_pred_err": (float(np.median(pe_fin))
                                if len(pe_fin) else float("nan")),
            "reliability": self.reliability(),
        }
        # honesty ratio: realized over predicted error (~1 == honest,
        # >>1 == overconfident)
        if len(fin) and len(pe_fin) and np.median(pe_fin) > 0:
            out["ape_over_pred_err"] = float(np.median(fin)
                                             / np.median(pe_fin))
        return out


def pav(y: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Pool-adjacent-violators: the weighted least-squares
    non-decreasing fit to ``y`` (isotonic regression)."""
    y = np.asarray(y, np.float64).copy()
    w = np.asarray(w, np.float64).copy()
    # blocks as (mean, weight, length) merged right-to-left on violation
    means: List[float] = []
    wts: List[float] = []
    lens: List[int] = []
    for yi, wi in zip(y, w):
        means.append(float(yi))
        wts.append(float(wi))
        lens.append(1)
        while len(means) > 1 and means[-2] > means[-1]:
            m2, w2, l2 = means.pop(), wts.pop(), lens.pop()
            m1, w1, l1 = means.pop(), wts.pop(), lens.pop()
            wt = w1 + w2
            means.append((m1 * w1 + m2 * w2) / wt if wt > 0
                         else (m1 * l1 + m2 * l2) / (l1 + l2))
            wts.append(wt)
            lens.append(l1 + l2)
    return np.concatenate([np.full(l, m) for m, l in zip(means, lens)])


def reliability_curve(conf: np.ndarray, ok: np.ndarray,
                      n_bins: int = 10, monotone: bool = True
                      ) -> Dict[str, List[float]]:
    """Binned confidence vs empirical accuracy.

    ``conf`` in [0, 1] is binned on a uniform grid; empty bins are
    dropped.  With ``monotone=True`` the per-bin accuracies are
    replaced by their PAV fit (weighted by bin count), making the
    returned ``bin_acc`` non-decreasing in confidence — the gate shape
    the obs benchmark asserts.  ``raw_acc`` keeps the pre-PAV values so
    plots can show both."""
    conf = np.asarray(conf, np.float64)
    ok = np.asarray(ok, np.float64)
    keep = np.isfinite(conf)
    conf, ok = conf[keep], ok[keep]
    edges = np.linspace(0.0, 1.0, n_bins + 1)
    idx = np.clip(np.digitize(conf, edges[1:-1]), 0, n_bins - 1)
    bc, ba, bn = [], [], []
    for b in range(n_bins):
        m = idx == b
        if not m.any():
            continue
        bc.append(float(conf[m].mean()))
        ba.append(float(ok[m].mean()))
        bn.append(int(m.sum()))
    raw = list(ba)
    if monotone and len(ba) > 1:
        ba = pav(np.array(ba), np.array(bn, np.float64)).tolist()
    return {"bin_conf": bc, "bin_acc": ba, "raw_acc": raw, "bin_n": bn,
            "monotone": bool(monotone)}
