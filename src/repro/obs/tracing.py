"""Per-request span tracing as vectorized column buffers.

Spans cover the full request lifecycle the engines model —
arrival → admission/first token (prefill) → decode → completion, or
shed (with reason) and crash-driven retries — plus replica crash /
restore annotations carried alongside from the fault log.  Rather than
instrumenting the engines' hot loops, spans are *derived post-run*
from the columns both engines already record (the fleet engine's
``req`` arrays directly; the heap engine's ``RequestRecord`` objects
via one bulk pass), so the vectorized engine keeps its ~400k events/s:
the <5% overhead gate at ``sample_rate=1.0`` is enforced by
``benchmarks/run.py obs_engine``.

Sampling is a deterministic hash of the request id (no RNG state), so
the same requests are kept regardless of engine, shard order, or
sample timing — heap and fleet runs over one seeded trace yield
byte-identical span populations.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.obs.metrics import StreamHist, percentile_with_inf

__all__ = ["ObsConfig", "SpanTable", "record_spans", "span_stats",
           "span_hists", "queue_depth_series"]


@dataclasses.dataclass
class ObsConfig:
    """Observability hook carried on ``SimConfig.obs`` (and accepted by
    ``ALAAutoscaler`` / ``OnlineALA``).  Everything defaults to "on,
    unbounded" except the ring caps, which default to None (current
    behavior: keep everything)."""
    enabled: bool = True
    sample_rate: float = 1.0          # request-span keep fraction
    sample_seed: int = 0              # perturbs the rid keep-hash
    max_steps: Optional[int] = None   # ring cap on retained step records
    max_fault_events: Optional[int] = None   # ring cap on fault_log
    max_cal_events: Optional[int] = None     # ring cap on audit events
    max_log_entries: Optional[int] = None    # autoscaler decision logs
    hist_bins: int = 48               # StreamHist bins for span_hists
    ape_ok_pct: float = 25.0          # calibration: tick "accurate" iff
    reliability_bins: int = 10        # APE <= ape_ok_pct, binned conf


def _keep_mask(rid: np.ndarray, rate: float, seed: int) -> np.ndarray:
    """Deterministic per-rid sampling — order / engine independent."""
    if rate >= 1.0:
        return np.ones(len(rid), bool)
    if rate <= 0.0:
        return np.zeros(len(rid), bool)
    with np.errstate(over="ignore"):          # wrap-around is the hash
        h = rid.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15)
    h ^= np.uint64((seed * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF)
    h ^= h >> np.uint64(31)
    h *= np.uint64(0x94D049BB133111EB)
    h ^= h >> np.uint64(29)
    return (h >> np.uint64(11)).astype(np.float64) / 2.0 ** 53 < rate


@dataclasses.dataclass
class SpanTable:
    """Column-oriented request spans.  All times are absolute sim
    seconds; missing phase boundaries are NaN (a shed request has NaN
    ``first_token_s`` / ``done_s`` and a finite ``shed_s``)."""
    rid: np.ndarray                   # (n,) int64
    tenant: np.ndarray                # (n,) object (str)
    replica: np.ndarray               # (n,) int32; -1 = never placed
    ii: np.ndarray                    # (n,) int64 input tokens
    oo: np.ndarray                    # (n,) int64 output tokens
    arrival_s: np.ndarray             # (n,) float64
    first_token_s: np.ndarray         # (n,) float64; NaN = no first token
    done_s: np.ndarray                # (n,) float64; NaN = not completed
    shed_s: np.ndarray                # (n,) float64; NaN = not shed
    retries: np.ndarray               # (n,) int64 crash requeues
    shed: np.ndarray                  # (n,) bool
    shed_reason: np.ndarray           # (n,) object (str; "" = served)
    sample_rate: float = 1.0
    n_source: int = 0                 # pre-sampling request count

    @property
    def n(self) -> int:
        return len(self.rid)

    # derived phases -- inf marks the miss mass (shared convention)
    def ttft_s(self) -> np.ndarray:
        v = self.first_token_s - self.arrival_s
        miss = self.shed | ~np.isfinite(self.first_token_s)
        return np.where(miss, np.inf, v)

    def e2e_s(self) -> np.ndarray:
        v = self.done_s - self.arrival_s
        return np.where(np.isfinite(self.done_s), v, np.inf)

    def decode_s(self) -> np.ndarray:
        """first-token -> completion wall time (the decode phase)."""
        v = self.done_s - self.first_token_s
        ok = np.isfinite(self.done_s) & np.isfinite(self.first_token_s)
        return np.where(ok, v, np.inf)

    def tpot_s(self) -> np.ndarray:
        """Decode seconds per output token past the first."""
        dec = self.decode_s()
        steps = np.maximum(self.oo - 1, 1)
        return np.where(np.isfinite(dec), dec / steps, np.inf)

    def select(self, mask: np.ndarray) -> "SpanTable":
        return SpanTable(
            rid=self.rid[mask], tenant=self.tenant[mask],
            replica=self.replica[mask], ii=self.ii[mask],
            oo=self.oo[mask], arrival_s=self.arrival_s[mask],
            first_token_s=self.first_token_s[mask],
            done_s=self.done_s[mask], shed_s=self.shed_s[mask],
            retries=self.retries[mask], shed=self.shed[mask],
            shed_reason=self.shed_reason[mask],
            sample_rate=self.sample_rate, n_source=self.n_source)


def record_spans(result, obs: Optional[ObsConfig] = None) -> SpanTable:
    """Build the span table from a finished ``SimResult``.

    Fleet results expose the columns directly (``result.req`` — zero
    copies beyond the sampling gather); heap results are converted in
    one bulk pass over ``records``."""
    rate = float(getattr(obs, "sample_rate", 1.0)) if obs else 1.0
    seed = int(getattr(obs, "sample_seed", 0)) if obs else 0
    req = getattr(result, "req", None)
    if req is not None:                       # fleet: vectorized path
        from repro.serving.fleet import _SHED_NAMES
        n = len(req["rid"])
        reasons = np.asarray(_SHED_NAMES, object)[
            np.asarray(req["shed_reason"], np.int64)]
        t = SpanTable(
            rid=np.asarray(req["rid"], np.int64),
            tenant=np.asarray(req["tenant"], object),
            replica=np.asarray(req["replica"], np.int32),
            ii=np.asarray(req["ii"], np.int64),
            oo=np.asarray(req["oo"], np.int64),
            arrival_s=np.asarray(req["arrival_s"], np.float64),
            first_token_s=np.asarray(req["first_token_s"], np.float64),
            done_s=np.asarray(req["done_s"], np.float64),
            shed_s=np.asarray(req["shed_s"], np.float64),
            retries=np.asarray(req["retries"], np.int64),
            shed=np.asarray(req["shed"], bool),
            shed_reason=reasons, sample_rate=rate, n_source=n)
    else:                                     # heap: one bulk pass
        recs = result.records
        n = len(recs)

        def col(get, dtype, missing=np.nan):
            out = np.empty(n, dtype)
            for i, r in enumerate(recs):
                v = get(r)
                out[i] = missing if v is None else v
            return out

        t = SpanTable(
            rid=col(lambda r: r.rid, np.int64, 0),
            tenant=np.array([r.tenant for r in recs], object),
            replica=col(lambda r: r.replica, np.int32, -1),
            ii=col(lambda r: r.ii, np.int64, 0),
            oo=col(lambda r: r.oo, np.int64, 0),
            arrival_s=col(lambda r: r.arrival_s, np.float64),
            first_token_s=col(lambda r: r.first_token_s, np.float64),
            done_s=col(lambda r: r.done_s, np.float64),
            shed_s=col(lambda r: r.shed_s, np.float64),
            retries=col(lambda r: r.retries, np.int64, 0),
            shed=col(lambda r: r.shed, bool, False),
            shed_reason=np.array([r.shed_reason for r in recs], object),
            sample_rate=rate, n_source=n)
    if rate < 1.0:
        t = t.select(_keep_mask(t.rid, rate, seed))
        t.sample_rate = rate
        t.n_source = n
    return t


def span_stats(table: SpanTable) -> Dict[str, float]:
    """Engine-comparable span statistics — the parity surface checked
    between heap and fleet runs of one seeded trace."""
    ttft = table.ttft_s()
    e2e = table.e2e_s()
    tpot = table.tpot_s()
    reasons: Dict[str, int] = {}
    for r in table.shed_reason[table.shed]:
        reasons[str(r)] = reasons.get(str(r), 0) + 1
    return {
        "n_spans": int(table.n),
        "n_source": int(table.n_source),
        "n_completed": int(np.isfinite(table.done_s).sum()),
        "n_shed": int(table.shed.sum()),
        "n_retries": int(table.retries.sum()),
        "shed_by_reason": reasons,
        "out_tokens": int(table.oo[np.isfinite(table.done_s)].sum()),
        "ttft_p50_s": percentile_with_inf(ttft, 50.0),
        "ttft_p95_s": percentile_with_inf(ttft, 95.0),
        "e2e_p50_s": percentile_with_inf(e2e, 50.0),
        "e2e_p95_s": percentile_with_inf(e2e, 95.0),
        "tpot_p50_s": percentile_with_inf(tpot, 50.0),
    }


def span_hists(table: SpanTable, n_bins: int = 48,
               by: Optional[np.ndarray] = None
               ) -> Dict[str, "StreamHist"]:
    """TTFT / TPOT / e2e histograms for the table (or, with ``by`` set
    to a per-span key array, mergeable per-group shards: callers merge
    group hists and read fleet-wide percentiles without raw values)."""
    ttft = table.ttft_s()
    fin = ttft[np.isfinite(ttft)]
    lo = float(fin.min()) if len(fin) else 0.0
    hi = float(fin.max()) if len(fin) else 1.0

    def build(vals):
        h = StreamHist.from_range(lo, hi, n_bins)
        h.observe(vals)
        return h

    if by is None:
        return {"ttft_s": build(ttft),
                "tpot_s": StreamHist.from_values(table.tpot_s(), n_bins),
                "e2e_s": StreamHist.from_values(table.e2e_s(), n_bins)}
    by = np.asarray(by, object)
    return {str(k): build(ttft[by == k]) for k in sorted(set(by.tolist()))}


def queue_depth_series(table: SpanTable, bucket_s: float = 1.0,
                       t_end: Optional[float] = None
                       ) -> Dict[str, np.ndarray]:
    """Queue depth (arrived, not yet started or shed) sampled on a
    regular grid — vectorized from span boundaries, feeds a StreamHist
    for queue-depth percentiles."""
    if table.n == 0:
        return {"t_s": np.zeros(0), "depth": np.zeros(0, np.int64)}
    start = np.where(np.isfinite(table.first_token_s),
                     table.first_token_s, np.inf)
    leave = np.minimum(start, np.where(np.isfinite(table.shed_s),
                                       table.shed_s, np.inf))
    t0 = float(table.arrival_s.min())
    t1 = float(t_end) if t_end is not None else \
        float(leave[np.isfinite(leave)].max()) if np.isfinite(leave).any() \
        else float(table.arrival_s.max())
    grid = np.arange(t0, t1 + bucket_s, bucket_s)
    arr = np.sort(table.arrival_s)
    lv = np.sort(leave[np.isfinite(leave)])
    depth = (np.searchsorted(arr, grid, side="right")
             - np.searchsorted(lv, grid, side="right"))
    return {"t_s": grid, "depth": depth.astype(np.int64)}
