"""Telemetry export: JSONL events, Chrome trace-event timelines
(Perfetto-loadable), and the markdown scorecard.

The Chrome trace uses the legacy JSON trace-event format that both
``chrome://tracing`` and https://ui.perfetto.dev load directly:
replica step slices are complete ("X") events on the *replicas*
process, sampled request spans are async begin/end ("b"/"e") pairs on
per-tenant tracks, and faults / control decisions are instant ("i")
events.  Timestamps are microseconds of sim time.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, List, Optional

import numpy as np

__all__ = ["write_jsonl", "spans_to_dicts", "chrome_trace",
           "write_chrome_trace", "scorecard_markdown"]

_US = 1e6


def _jsonable(v):
    if isinstance(v, (np.floating, np.integer)):
        return v.item()
    if isinstance(v, float) and not np.isfinite(v):
        return str(v)                 # "inf"/"nan" — JSONL stays valid
    return v


def write_jsonl(records: Iterable, path) -> int:
    """One JSON object per line.  Accepts dicts or objects with a
    ``to_dict`` (e.g. ``CalEvent``); returns the line count."""
    path = pathlib.Path(path)
    n = 0
    with path.open("w") as f:
        for rec in records:
            d = rec.to_dict() if hasattr(rec, "to_dict") else dict(rec)
            f.write(json.dumps({k: _jsonable(v) for k, v in d.items()})
                    + "\n")
            n += 1
    return n


def spans_to_dicts(table) -> List[Dict[str, object]]:
    """SpanTable rows as JSONL-ready dicts (NaN boundaries omitted)."""
    out = []
    for i in range(table.n):
        d = {"rid": int(table.rid[i]), "tenant": str(table.tenant[i]),
             "replica": int(table.replica[i]), "ii": int(table.ii[i]),
             "oo": int(table.oo[i]),
             "arrival_s": float(table.arrival_s[i]),
             "retries": int(table.retries[i]),
             "shed": bool(table.shed[i])}
        for k in ("first_token_s", "done_s", "shed_s"):
            v = float(getattr(table, k)[i])
            if np.isfinite(v):
                d[k] = v
        if table.shed[i]:
            d["shed_reason"] = str(table.shed_reason[i])
        out.append(d)
    return out


def _meta(pid: int, name: str, tid: Optional[int] = None,
          tname: Optional[str] = None) -> List[dict]:
    evs = [{"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": name}}]
    if tid is not None:
        evs.append({"name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid, "args": {"name": tname or f"t{tid}"}})
    return evs


def chrome_trace(result, spans=None, max_step_events: int = 20000,
                 max_span_events: int = 5000) -> Dict[str, object]:
    """Chrome trace-event dict for a ``SimResult``.

    pid 0 carries one track per replica with its prefill/decode step
    slices plus crash/restore instants; pid 1 carries one track per
    tenant with sampled request spans (async b/e, id = rid); pid 2
    carries autoscaler control instants.  Step/span event counts are
    capped (most recent kept) so traces of huge runs stay loadable —
    the truncation is reported in ``metadata``."""
    evs: List[dict] = []
    evs += _meta(0, "replicas")
    evs += _meta(1, "tenants")
    evs += _meta(2, "control")

    # -- replica step slices ------------------------------------------------
    sa = getattr(result, "step_arrays", None)
    if sa is not None:
        t_end = np.asarray(sa["t_end"], np.float64)
        rep = np.asarray(sa["replica"], np.int64)
        kind = np.asarray(sa["kind"])
        dur = np.asarray(sa["duration_s"], np.float64)
        bb = np.asarray(sa["bb"], np.int64)
        tok = np.asarray(sa["tokens_out"], np.int64)
        kind_name = np.where(np.asarray(kind) == 0, "prefill", "decode")
    else:
        steps = list(result.steps)
        t_end = np.array([s.t_end for s in steps], np.float64)
        rep = np.array([s.replica for s in steps], np.int64)
        kind_name = np.array([s.kind for s in steps], object)
        dur = np.array([s.duration_s for s in steps], np.float64)
        bb = np.array([s.bb for s in steps], np.int64)
        tok = np.array([s.tokens_out for s in steps], np.int64)
    n_steps = len(t_end)
    lo = max(0, n_steps - max_step_events)
    for i in range(lo, n_steps):
        evs.append({"name": str(kind_name[i]), "ph": "X", "pid": 0,
                    "tid": int(rep[i]),
                    "ts": (t_end[i] - dur[i]) * _US,
                    "dur": max(dur[i] * _US, 1.0),
                    "args": {"bb": int(bb[i]),
                             "tokens_out": int(tok[i])}})
    for r in sorted(set(rep.tolist())):
        evs += _meta(0, "replicas", tid=int(r), tname=f"replica {r}")

    # -- fault annotations --------------------------------------------------
    for ev in getattr(result, "fault_log", ()):
        evs.append({"name": f"{ev.kind} r{ev.replica}", "ph": "i",
                    "pid": 0, "tid": int(ev.replica), "ts": ev.t * _US,
                    "s": "g",
                    "args": {"n_displaced": int(ev.n_displaced)}})

    # -- control decisions --------------------------------------------------
    for t, action in getattr(result, "controls", ()):
        evs.append({"name": f"n_replicas={action.n_replicas}", "ph": "i",
                    "pid": 2, "tid": 0, "ts": float(t) * _US, "s": "t",
                    "args": {"batch_cap": int(action.batch_cap)}})

    # -- sampled request spans ---------------------------------------------
    if spans is None:
        spans = getattr(result, "spans", None)
    n_spans_src = 0
    if spans is not None and spans.n:
        n_spans_src = spans.n
        keep = min(spans.n, max_span_events)
        idx = np.argsort(spans.arrival_s)[-keep:]
        tenants = {t: i for i, t in
                   enumerate(sorted(set(spans.tenant.tolist())))}
        for t, tid in tenants.items():
            evs += _meta(1, "tenants", tid=tid, tname=t or "default")
        ttft = spans.ttft_s()
        for i in idx:
            tid = tenants[spans.tenant[i]]
            rid = int(spans.rid[i])
            t0 = float(spans.arrival_s[i])
            end = float(spans.done_s[i]) if np.isfinite(spans.done_s[i]) \
                else float(spans.shed_s[i]) \
                if np.isfinite(spans.shed_s[i]) else t0
            shed = bool(spans.shed[i])
            args = {"rid": rid, "ii": int(spans.ii[i]),
                    "oo": int(spans.oo[i]),
                    "retries": int(spans.retries[i])}
            if shed:
                args["shed_reason"] = str(spans.shed_reason[i])
            name = "shed" if shed else "request"
            common = {"cat": "request", "id": rid, "pid": 1, "tid": tid}
            evs.append({**common, "name": name, "ph": "b",
                        "ts": t0 * _US, "args": args})
            if np.isfinite(ttft[i]):
                evs.append({**common, "name": "first_token", "ph": "n",
                            "ts": (t0 + float(ttft[i])) * _US})
            evs.append({**common, "name": name, "ph": "e",
                        "ts": max(end, t0) * _US})

    return {"traceEvents": evs, "displayTimeUnit": "ms",
            "metadata": {"n_steps_total": int(n_steps),
                         "n_steps_emitted": int(n_steps - lo),
                         "n_spans_total": int(n_spans_src),
                         "sim_end_s": float(result.sim_end_s)}}


def write_chrome_trace(result, path, spans=None, **kw) -> pathlib.Path:
    path = pathlib.Path(path)
    path.write_text(json.dumps(chrome_trace(result, spans=spans, **kw)))
    return path


def _fmt(v: object) -> str:
    if isinstance(v, float):
        if not np.isfinite(v):
            return str(v)
        return f"{v:.3f}" if abs(v) < 1000 else f"{v:,.0f}"
    return str(v)


def scorecard_markdown(meta: Optional[Dict[str, object]] = None,
                       per_tenant: Optional[Dict[str, Dict]] = None,
                       calibration: Optional[Dict[str, object]] = None,
                       title: str = "Observability scorecard") -> str:
    """Markdown scorecard from the pieces ``BENCH_obs.json`` stores:
    fleet meta-metrics, the per-tenant rollup, and the calibration
    audit summary.  ``analysis/perf_report.py`` appends this section
    when the obs benchmark artifact is present."""
    lines = [f"## {title}", ""]
    if meta:
        lines += ["| fleet metric | value |", "| --- | --- |"]
        lines += [f"| {k} | {_fmt(v)} |" for k, v in sorted(meta.items())]
        lines.append("")
    if per_tenant:
        cols = ("n_requests", "n_shed", "attainment", "ttft_p95_s",
                "goodput_share")
        lines += ["| tenant | " + " | ".join(cols) + " |",
                  "| --- |" + " --- |" * len(cols)]
        for name, row in sorted(per_tenant.items()):
            lines.append("| " + name + " | "
                         + " | ".join(_fmt(row.get(c)) for c in cols)
                         + " |")
        lines.append("")
    if calibration:
        lines += ["| calibration | value |", "| --- | --- |"]
        for k in ("n_ticks", "median_ape", "median_pred_err",
                  "median_confidence", "accuracy_rate",
                  "ape_over_pred_err"):
            if k in calibration:
                lines.append(f"| {k} | {_fmt(calibration[k])} |")
        rel = calibration.get("reliability")
        if rel and rel.get("bin_conf"):
            conf = ", ".join(f"{c:.2f}" for c in rel["bin_conf"])
            acc = ", ".join(f"{a:.2f}" for a in rel["bin_acc"])
            lines += ["",
                      f"Reliability curve (conf -> accuracy, "
                      f"{'monotone' if rel.get('monotone') else 'raw'}): "
                      f"[{conf}] -> [{acc}]"]
        lines.append("")
    return "\n".join(lines)
