"""Observability layer: streaming metrics, request-span tracing, ALA
calibration audit, and timeline export.

The serving engines, autoscaler, and online loop all accept an
``ObsConfig`` hook (``SimConfig.obs``, ``ALAAutoscaler.obs``,
``OnlineALA(audit=...)``); everything here also works standalone on a
finished ``SimResult``.  See ``docs/observability.md``.
"""
from repro.obs.calibration import (CalEvent, CalibrationAudit,
                                   reliability_curve)
from repro.obs.export import (chrome_trace, scorecard_markdown,
                              spans_to_dicts, write_chrome_trace,
                              write_jsonl)
from repro.obs.metrics import (Counter, Gauge, RingLog, StreamHist,
                               fixed_edges, percentile_with_inf,
                               tenant_rollup)
from repro.obs.tracing import (ObsConfig, SpanTable, queue_depth_series,
                               record_spans, span_hists, span_stats)

__all__ = [
    "CalEvent", "CalibrationAudit", "reliability_curve",
    "chrome_trace", "scorecard_markdown", "spans_to_dicts",
    "write_chrome_trace", "write_jsonl",
    "Counter", "Gauge", "RingLog", "StreamHist", "fixed_edges",
    "percentile_with_inf", "tenant_rollup",
    "ObsConfig", "SpanTable", "queue_depth_series", "record_spans",
    "span_hists", "span_stats",
]
