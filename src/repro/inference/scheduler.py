"""ALA-driven request scheduler / capacity planner.

This is the paper's motivation made concrete: the serving layer consults
ALA's throughput predictions (with confidence) to pick batch sizes and
replica counts without benchmarking every configuration.

* ``plan_batch_size`` — smallest bb whose predicted throughput meets a
  target, or the bb maximizing predicted throughput under a per-token
  latency SLO.  Low-confidence predictions are derated by the clamped
  ``derate_confidence`` safety factor (proportional below the floor,
  never under ``min_derate`` — so the implied scale-out headroom is
  bounded and the degenerate confidence=0.0 sentinel stays finite).
* ``BatchingQueue``  — groups incoming requests into (ii, oo)-homogeneous
  batches of the planned size (the regime the engine serves).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ala import ALA


@dataclasses.dataclass
class Request:
    rid: int
    ii: int
    oo: int


@dataclasses.dataclass
class CapacityPlan:
    bb: int
    predicted_thpt: float
    confidence: float
    derated_thpt: float
    replicas: int = 1
    degenerate: bool = False   # confidence hit the (inf, 0.0) sentinel


def derate_confidence(conf: float, floor: float = 0.7,
                      min_derate: float = 0.25) -> float:
    """Safety multiplier applied to a prediction with confidence ``conf``.

    Full trust at or above ``floor``; below it, derate proportionally but
    never under ``min_derate`` — the PR-3 degenerate sentinel
    (``confidence == 0.0``) and non-finite garbage land on ``min_derate``
    instead of zeroing the plan (whose 1/derate headroom would divide by
    zero).  Shared by the static ``CapacityPlanner`` and the dynamic
    ``repro.serving.autoscaler``."""
    if not np.isfinite(conf):
        return min_derate
    if conf >= floor:
        return 1.0
    return float(np.clip(conf, min_derate, 1.0))


class CapacityPlanner:
    def __init__(self, ala: ALA, candidate_bb: Tuple[int, ...] = (
            1, 2, 4, 8, 16, 32, 64, 128, 256),
            confidence_floor: float = 0.7,
            min_derate: float = 0.25, max_replicas: int = 64):
        self.ala = ala
        self.candidate_bb = candidate_bb
        self.confidence_floor = confidence_floor
        self.min_derate = min_derate
        self.max_replicas = max_replicas

    def _confidence(self, ii: int, oo: int, bbs: np.ndarray) -> float:
        if self.ala.error_model is None or self.ala.sa_log is None:
            return 1.0
        new = (np.full(len(bbs), float(ii)), np.full(len(bbs), float(oo)),
               bbs.astype(np.float64), np.full(len(bbs), np.nan))
        _, conf = self.ala.estimate(new)
        return conf

    def plan_batch_size(self, ii: int, oo: int,
                        target_thpt: Optional[float] = None,
                        max_token_latency_s: Optional[float] = None
                        ) -> CapacityPlan:
        bbs = np.asarray(self.candidate_bb, np.float64)
        thpt = self.ala.predict(np.full(len(bbs), float(ii)),
                                np.full(len(bbs), float(oo)), bbs)
        conf = self._confidence(ii, oo, bbs)
        derate = derate_confidence(conf, self.confidence_floor,
                                   self.min_derate)
        eff = thpt * derate
        ok = np.ones(len(bbs), bool)
        if max_token_latency_s is not None:
            # per-token latency for a request ~ bb / thpt(bb)
            lat = bbs / np.maximum(eff, 1e-9)
            ok &= lat <= max_token_latency_s
        if target_thpt is not None:
            ok &= eff >= target_thpt
        if ok.any():
            # smallest qualifying batch (lowest latency at target)
            i = int(np.argmax(ok))
        else:
            # nothing qualifies: max effective throughput, scale out
            i = int(np.argmax(eff))
        replicas = 1
        if target_thpt is not None and eff[i] < target_thpt:
            replicas = int(min(np.ceil(target_thpt / max(eff[i], 1e-9)),
                               self.max_replicas))
        return CapacityPlan(bb=int(bbs[i]), predicted_thpt=float(thpt[i]),
                            confidence=float(conf),
                            derated_thpt=float(eff[i]), replicas=replicas,
                            degenerate=bool(conf <= 0.0))


class BatchingQueue:
    """Groups same-(ii,oo)-bucket requests into planned batch sizes."""

    def __init__(self, planner: CapacityPlanner,
                 target_thpt: Optional[float] = None):
        self.planner = planner
        self.target_thpt = target_thpt
        self.queues: Dict[Tuple[int, int], Deque[Request]] = \
            collections.defaultdict(collections.deque)
        self.plans: Dict[Tuple[int, int], CapacityPlan] = {}

    @staticmethod
    def bucket(ii: int, oo: int) -> Tuple[int, int]:
        b = lambda v: 1 << int(np.ceil(np.log2(max(v, 1))))
        return b(ii), b(oo)

    def submit(self, req: Request) -> None:
        self.queues[self.bucket(req.ii, req.oo)].append(req)

    def ready_batches(self) -> List[Tuple[Tuple[int, int], List[Request]]]:
        out = []
        for key, q in self.queues.items():
            if key not in self.plans:
                self.plans[key] = self.planner.plan_batch_size(
                    key[0], key[1], target_thpt=self.target_thpt)
            bb = self.plans[key].bb
            while len(q) >= bb:
                out.append((key, [q.popleft() for _ in range(bb)]))
        return out

    def flush(self) -> List[Tuple[Tuple[int, int], List[Request]]]:
        out = []
        for key, q in self.queues.items():
            if q:
                out.append((key, list(q)))
                q.clear()
        return out
