"""Batched serving engine.

Serves homogeneous batches (fixed ii -> oo at batch size bb) — the same
workload regime the paper benchmarks and that ALA models.  Prefill and
decode are jitted once per (batch, prompt_len, max_len) signature; decode
runs as one jitted multi-token loop (``lax.scan`` over steps) so the CPU
measurement path times real compiled step execution, not Python dispatch.

``measure_throughput`` is the real-wall-clock counterpart of the
analytical simulator: it produces (ii, oo, bb, thpt) rows by actually
running the model — at tiny scale on CPU, at full scale on TPU.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.inference.sampling import sample
from repro.models.transformer import DecodeCache, Model


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, oo)
    prefill_s: float
    decode_s: float
    tokens_per_s: float         # output-token throughput (the paper's thpt)


class ServingEngine:
    def __init__(self, model: Model, params, temperature: float = 0.0,
                 donate_cache: bool = True):
        self.model = model
        self.params = params
        self.temperature = temperature
        self._prefill = jax.jit(
            lambda p, b, ml: model.prefill(p, b, max_len=ml),
            static_argnums=(2,))
        self._decode_n = jax.jit(
            self._decode_scan, static_argnums=(3,),
            donate_argnums=(1,) if donate_cache else ())

    # one jitted scan over n decode steps
    def _decode_scan(self, params, cache: DecodeCache, first_tok, n: int):
        cfg = self.model.cfg

        def body(carry, key):
            cache, tok = carry
            logits, cache = self.model.decode_step(params, cache, tok)
            nxt = sample(logits, key, temperature=self.temperature,
                         vocab_size=cfg.vocab_size)
            return (cache, nxt), nxt[:, 0]

        keys = jax.random.split(jax.random.key(0), n)
        (cache, _), toks = jax.lax.scan(body, (cache, first_tok), keys)
        return toks.T, cache      # (B, n)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 max_len: Optional[int] = None) -> GenerationResult:
        """prompts: (B, ii) int32."""
        b, ii = prompts.shape
        max_len = max_len or (ii + max_new_tokens)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)},
                                      max_len)
        first = sample(logits, jax.random.key(1),
                       temperature=self.temperature,
                       vocab_size=self.model.cfg.vocab_size)
        first.block_until_ready()
        t1 = time.perf_counter()
        toks, _ = self._decode_n(self.params, cache, first,
                                 max_new_tokens - 1)
        toks = jax.block_until_ready(toks)
        t2 = time.perf_counter()
        out = np.concatenate([np.asarray(first), np.asarray(toks)], axis=1)
        decode_s = t2 - t1
        total_out = b * max_new_tokens
        return GenerationResult(
            tokens=out, prefill_s=t1 - t0, decode_s=decode_s,
            tokens_per_s=total_out / max(t2 - t0, 1e-9))

    # -- benchmarking path ---------------------------------------------------
    def measure_throughput(self, ii: int, oo: int, bb: int, reps: int = 3,
                           seed: int = 0, warmup: int = 1) -> List[Dict]:
        rng = np.random.default_rng(seed)
        rows = []
        for r in range(warmup + reps):
            prompts = rng.integers(
                0, self.model.cfg.vocab_size, size=(bb, ii), dtype=np.int32)
            res = self.generate(prompts, oo)
            if r >= warmup:
                rows.append(dict(ii=ii, oo=oo, bb=bb,
                                 thpt=res.tokens_per_s,
                                 prefill_s=res.prefill_s,
                                 decode_s=res.decode_s))
        return rows
