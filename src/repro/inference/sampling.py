"""Token sampling: greedy / temperature / top-k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key, temperature: float = 0.0, top_k: int = 0,
           vocab_size: int | None = None):
    """logits: (B, 1, V) -> tokens (B, 1) int32."""
    logits = logits[:, -1, :].astype(jnp.float32)
    if vocab_size is not None:
        # mask vocab padding
        logits = jnp.where(jnp.arange(logits.shape[-1]) < vocab_size,
                           logits, -jnp.inf)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(
        jnp.int32)[:, None]
