"""State-space / recurrent mixers: Mamba (selective SSM) and xLSTM blocks.

Mamba uses a *chunked* selective scan: the (B, L, d_inner, d_state) hidden
states are materialized one chunk at a time inside a ``lax.scan`` over
chunks, carrying only the (B, d_inner, d_state) boundary state.  This keeps
both the traced HLO and the working set O(chunk), which is what makes the
jamba 32k-prefill dry-run compile.

xLSTM: mLSTM is chunkwise-parallel linear attention with scalar per-head
decay (matrix memory); sLSTM is a genuinely sequential scan (recurrent gate
coupling through h_{t-1}), executed with ``lax.scan`` over time steps.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.distributed.sharding import constrain

MAMBA_CHUNK = 16
MLSTM_CHUNK = 64


# ===========================================================================
# Mamba
# ===========================================================================

class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, d_inner) trailing inputs
    ssm: jax.Array   # (B, d_inner, d_state)


def init_mamba(cfg: ModelConfig, key):
    d, di, ds = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state
    dtr, dc = cfg.dt_rank, cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {"mamba": {
        "in_proj": dense_init(ks[0], (d, 2 * di), cfg.param_dtype),
        "conv_w": dense_init(ks[1], (di, dc), cfg.param_dtype, in_axis=1),
        "conv_b": jnp.zeros((di,), cfg.param_dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * ds), cfg.param_dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), cfg.param_dtype),
        "dt_bias": jnp.full((di,), -4.6, cfg.param_dtype),  # softplus ~ 0.01
        "A_log": jnp.log(A).astype(cfg.param_dtype),
        "D": jnp.ones((di,), cfg.param_dtype),
        "out_proj": dense_init(ks[4], (di, d), cfg.param_dtype),
    }}


def _mamba_inner(cfg, p, xz, conv_carry):
    """Shared pre-SSM path. xz: (B, S, d_model) -> x,(B,S,di) gate z."""
    dtype = cfg.compute_dtype
    proj = jnp.einsum("bsd,de->bse", xz, p["in_proj"].astype(dtype))
    x, z = jnp.split(proj, 2, axis=-1)
    return x, z


def _causal_conv(cfg, p, x, carry=None):
    """Depthwise causal conv over seq. x: (B,S,di); carry: (B,dc-1,di)."""
    dc = cfg.mamba_d_conv
    dtype = x.dtype
    if carry is None:
        carry = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), dtype)
    xp = jnp.concatenate([carry, x], axis=1)  # (B, S+dc-1, di)
    w = p["conv_w"].astype(dtype)             # (di, dc)
    out = sum(xp[:, i:i + x.shape[1], :] * w[:, i] for i in range(dc))
    out = out + p["conv_b"].astype(dtype)
    new_carry = xp[:, -(dc - 1):, :] if dc > 1 else carry
    return jax.nn.silu(out), new_carry


def _ssm_params(cfg, p, x):
    """dt, B, C from x. x: (B,S,di)."""
    dtype = x.dtype
    ds, dtr = cfg.mamba_d_state, cfg.dt_rank
    dbc = jnp.einsum("bsi,ie->bse", x, p["x_proj"].astype(dtype))
    dt, Bc, Cc = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"].astype(dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32)


def mamba_full(cfg: ModelConfig, params, xz, state: MambaState = None):
    """Train/prefill path. Returns (y, final MambaState)."""
    p = params["mamba"]
    b, s, _ = xz.shape
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    x, z = _mamba_inner(cfg, p, xz, None)
    conv_carry = None if state is None else state.conv
    x, conv_out = _causal_conv(cfg, p, x, conv_carry)
    x = constrain(x, "act_bsi")
    dt, Bc, Cc = _ssm_params(cfg, p, x)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, ds)
    xf = x.astype(jnp.float32)

    chunk = MAMBA_CHUNK
    n_chunks = s // chunk
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"

    def to_chunks(t):
        return t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    dt_c, B_c, C_c, x_c = map(to_chunks, (dt, Bc, Cc, xf))
    h0 = (jnp.zeros((b, di, ds), jnp.float32) if state is None
          else state.ssm.astype(jnp.float32))

    def chunk_body(h, inp):
        dtk, Bk, Ck, xk = inp             # (B, L, ...)
        dA = jnp.exp(dtk[..., None] * A)                    # (B,L,di,ds)
        dBx = (dtk * xk)[..., None] * Bk[:, :, None, :]     # (B,L,di,ds)
        # inclusive cumulative: h_t = dA_t h_{t-1} + dBx_t
        logs = jnp.log(jnp.maximum(dA, 1e-20))
        cum = jnp.exp(jnp.cumsum(logs, axis=1))             # prod dA_1..t
        scaled = dBx / jnp.maximum(cum, 1e-20)
        hs = cum * (jnp.cumsum(scaled, axis=1) + h[:, None] / 1.0)
        y = jnp.einsum("blis,bls->bli", hs, Ck)
        return hs[:, -1], y

    hT, ys = jax.lax.scan(chunk_body, h0, (dt_c, B_c, C_c, x_c))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    y = y + xf * p["D"].astype(jnp.float32)
    y = (y.astype(cfg.compute_dtype)) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cfg.compute_dtype))
    new_state = MambaState(conv=conv_out, ssm=hT.astype(jnp.float32))
    return out, new_state


def mamba_decode(cfg: ModelConfig, params, xz, state: MambaState):
    """One-token step. xz: (B, 1, d_model)."""
    p = params["mamba"]
    b = xz.shape[0]
    x, z = _mamba_inner(cfg, p, xz, None)
    # conv over carry + current token
    dc = cfg.mamba_d_conv
    xp = jnp.concatenate([state.conv.astype(x.dtype), x], axis=1)
    w = p["conv_w"].astype(x.dtype)
    xc = sum(xp[:, -dc + i, :] * w[:, i] for i in range(dc))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))[:, None, :]
    new_conv = xp[:, -(dc - 1):, :]
    dt, Bc, Cc = _ssm_params(cfg, p, xc)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt0, B0, C0, x0 = dt[:, 0], Bc[:, 0], Cc[:, 0], \
        xc[:, 0].astype(jnp.float32)
    dA = jnp.exp(dt0[..., None] * A)                        # (B,di,ds)
    h = dA * state.ssm + (dt0 * x0)[..., None] * B0[:, None, :]
    h = constrain(h, "mamba_state")
    y = jnp.einsum("bis,bs->bi", h, C0) + x0 * p["D"].astype(jnp.float32)
    y = y.astype(cfg.compute_dtype)[:, None, :] * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(cfg.compute_dtype))
    return out, MambaState(conv=new_conv, ssm=h)


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    return MambaState(
        conv=jnp.zeros((batch, cfg.mamba_d_conv - 1, cfg.mamba_d_inner),
                       cfg.compute_dtype),
        ssm=jnp.zeros((batch, cfg.mamba_d_inner, cfg.mamba_d_state),
                      jnp.float32))


# ===========================================================================
# xLSTM — mLSTM (matrix memory, chunk-parallel)
# ===========================================================================

class MLSTMState(NamedTuple):
    C: jax.Array  # (B, H, Dk, Dv)
    n: jax.Array  # (B, H, Dk)


def init_mlstm(cfg: ModelConfig, key):
    d = cfg.d_model
    dp = int(cfg.xlstm_proj_factor * d)
    h = cfg.n_heads
    dk = dp // h
    ks = jax.random.split(key, 5)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * dp), cfg.param_dtype),
        "wqk": dense_init(ks[1], (dp, 2 * h * dk), cfg.param_dtype),
        "wv2": dense_init(ks[2], (dp, h * dk), cfg.param_dtype),
        "w_gates": dense_init(ks[3], (dp, 2 * h), cfg.param_dtype),
        "down_proj": dense_init(ks[4], (dp, d), cfg.param_dtype),
    }


def _mlstm_qkv(cfg, p, xin):
    dtype = cfg.compute_dtype
    b, s, dp = xin.shape
    h = cfg.n_heads
    dk = dp // h
    qk = jnp.einsum("bse,ef->bsf", xin, p["wqk"].astype(dtype))
    q, k = jnp.split(qk.reshape(b, s, 2 * h, dk), 2, axis=2)
    v = jnp.einsum("bse,ef->bsf", xin,
                   p["wv2"].astype(dtype)).reshape(b, s, h, dk)
    gates = jnp.einsum("bse,ef->bsf", xin, p["w_gates"].astype(dtype))
    ig, fg = jnp.split(gates.astype(jnp.float32), 2, axis=-1)  # (B,S,H)
    i = jnp.exp(jnp.minimum(ig, 10.0))          # stabilized exp input gate
    f = jax.nn.sigmoid(fg)
    return q, k, v, i, f, dk


def mlstm_full(cfg: ModelConfig, params, x, state: MLSTMState = None):
    dtype = cfg.compute_dtype
    b, s, _ = x.shape
    hN = cfg.n_heads
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(dtype))
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, i, f, dk = _mlstm_qkv(cfg, params, xin)
    scale = 1.0 / (dk ** 0.5)

    L = min(MLSTM_CHUNK, s)
    assert s % L == 0
    n_chunks = s // L

    def to_chunks(t):
        return t.reshape(b, n_chunks, L, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = map(to_chunks, (q, k, v, i, f))
    C0 = (jnp.zeros((b, hN, dk, dk), jnp.float32) if state is None
          else state.C)
    n0 = (jnp.zeros((b, hN, dk), jnp.float32) if state is None
          else state.n)

    def chunk_body(carry, inp):
        C, n = carry
        qk_, kk_, vk_, ik_, fk_ = inp
        logf = jnp.log(jnp.maximum(fk_, 1e-20))          # (B,L,H)
        F = jnp.cumsum(logf, axis=1)
        # intra-chunk "attention" with decay exp(F_t - F_s) i_s, causal
        qf = qk_.astype(jnp.float32)
        kf = kk_.astype(jnp.float32)
        scores = jnp.einsum("bthk,bshk->bhts", qf, kf) * scale
        Fh = F.swapaxes(1, 2)                             # (B,H,L)
        dmat = Fh[:, :, :, None] - Fh[:, :, None, :]      # (B,H,T,S) F_t-F_s
        causal = jnp.tril(jnp.ones((L, L), bool))
        w = jnp.where(causal[None, None], jnp.exp(dmat), 0.0)
        w = w * ik_.swapaxes(1, 2)[:, :, None, :]
        intra = jnp.einsum("bhts,bshk->bthk", scores * w,
                           vk_.astype(jnp.float32))
        # inter-chunk: carry contribution
        decay_t = jnp.exp(F).swapaxes(1, 2)               # (B,H,T)
        inter = jnp.einsum("bthk,bhkv->bthv", qf * scale, C) \
            * decay_t.swapaxes(1, 2)[..., None]
        nq = jnp.einsum("bthk,bhk->bth", qf * scale, n) \
            * decay_t.swapaxes(1, 2)
        # normalizer: intra part
        n_intra = jnp.einsum("bhts,bshk->bthk", w, kf)
        denom_intra = jnp.einsum("bthk,bthk->bth", qf * scale, n_intra)
        denom = jnp.maximum(jnp.abs(nq + denom_intra), 1.0)[..., None]
        y = (intra + inter) / denom
        # update carry
        tot_decay = jnp.exp(F[:, -1])                     # (B,H)
        rev = jnp.exp(F[:, -1][:, None, :] - F)           # (B,L,H)
        kw = kf * (rev * ik_)[..., None]
        C_new = C * tot_decay[..., None, None] + \
            jnp.einsum("bshk,bshv->bhkv", kw, vk_.astype(jnp.float32))
        n_new = n * tot_decay[..., None] + jnp.einsum("bshk->bhk", kw)
        return (C_new, n_new), y.astype(dtype)

    (CT, nT), ys = jax.lax.scan(chunk_body, (C0, n0), (qc, kc, vc, ic, fc))
    y = ys.swapaxes(0, 1).reshape(b, s, -1)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["down_proj"].astype(dtype))
    return out, MLSTMState(C=CT, n=nT)


def mlstm_decode(cfg: ModelConfig, params, x, state: MLSTMState):
    dtype = cfg.compute_dtype
    b = x.shape[0]
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(dtype))
    xin, z = jnp.split(up, 2, axis=-1)
    q, k, v, i, f, dk = _mlstm_qkv(cfg, params, xin)
    scale = 1.0 / (dk ** 0.5)
    qf = q[:, 0].astype(jnp.float32)           # (B,H,Dk)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    i0, f0 = i[:, 0], f[:, 0]                  # (B,H)
    C = state.C * f0[..., None, None] + \
        (kf * i0[..., None])[..., :, None] * vf[..., None, :]
    C = constrain(C, "mlstm_state")
    n = state.n * f0[..., None] + kf * i0[..., None]
    num = jnp.einsum("bhk,bhkv->bhv", qf * scale, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf * scale, n)),
                      1.0)[..., None]
    y = (num / den).reshape(b, 1, -1).astype(dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["down_proj"].astype(dtype))
    return out, MLSTMState(C=C, n=n)


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    dk = dp // cfg.n_heads
    return MLSTMState(C=jnp.zeros((batch, cfg.n_heads, dk, dk), jnp.float32),
                      n=jnp.zeros((batch, cfg.n_heads, dk), jnp.float32))


# ===========================================================================
# xLSTM — sLSTM (scalar memory, sequential)
# ===========================================================================

class SLSTMState(NamedTuple):
    c: jax.Array  # (B, Dp)
    n: jax.Array  # (B, Dp)
    h: jax.Array  # (B, Dp)


def init_slstm(cfg: ModelConfig, key):
    d = cfg.d_model
    dp = int(cfg.xlstm_proj_factor * d)
    ks = jax.random.split(key, 3)
    return {
        "up_proj": dense_init(ks[0], (d, 4 * dp), cfg.param_dtype),
        "r_proj": dense_init(ks[1], (dp, 4 * dp), cfg.param_dtype),
        "down_proj": dense_init(ks[2], (dp, d), cfg.param_dtype),
    }


def _slstm_step(p, dtype, carry, wx_t):
    c, n, h = carry
    pre = wx_t + jnp.einsum("be,ef->bf", h,
                            p["r_proj"].astype(dtype)).astype(jnp.float32)
    z, i, f, o = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z)
    i = jnp.exp(jnp.minimum(i, 10.0))
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c2 = f * c + i * z
    n2 = f * n + i
    h2 = o * (c2 / jnp.maximum(n2, 1.0))
    return (c2, n2, h2), h2


def slstm_full(cfg: ModelConfig, params, x, state: SLSTMState = None):
    dtype = cfg.compute_dtype
    b, s, d = x.shape
    dp = int(cfg.xlstm_proj_factor * d)
    wx = jnp.einsum("bsd,df->bsf", x,
                    params["up_proj"].astype(dtype)).astype(jnp.float32)
    if state is None:
        state = init_slstm_state(cfg, b)
    carry = (state.c, state.n, state.h)
    carry, hs = jax.lax.scan(
        lambda cr, w: _slstm_step(params, dtype, cr, w),
        carry, wx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["down_proj"].astype(dtype))
    return out, SLSTMState(*carry)


def slstm_decode(cfg: ModelConfig, params, x, state: SLSTMState):
    dtype = cfg.compute_dtype
    wx = jnp.einsum("bsd,df->bsf", x,
                    params["up_proj"].astype(dtype)).astype(jnp.float32)
    carry, h = _slstm_step(params, dtype, (state.c, state.n, state.h),
                           wx[:, 0])
    out = jnp.einsum("be,ed->bd", h.astype(dtype),
                     params["down_proj"].astype(dtype))[:, None]
    return out, SLSTMState(*carry)


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    z = jnp.zeros((batch, dp), jnp.float32)
    return SLSTMState(c=z, n=z, h=z)
