"""Core layer primitives shared by every architecture family.

All ``init_*`` functions return plain dict pytrees; ``apply`` functions are
pure.  Parameters are created in ``cfg.param_dtype`` and compute happens in
``cfg.compute_dtype`` (mixed precision), with norms/softmax accumulated in
fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0):
    """Truncated-normal fan-in init (matches common LLM practice)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    std = 1.0 / jnp.sqrt(jnp.maximum(fan_in, 1)).astype(jnp.float32)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(cfg: ModelConfig, dim: Optional[int] = None):
    return {"scale": jnp.ones((dim or cfg.d_model,), cfg.param_dtype)}


def rmsnorm(x, params, eps: float = 1e-5, use_kernel: bool = False):
    if use_kernel:
        from repro.kernels.rmsnorm import ops as rms_ops
        return rms_ops.rmsnorm(x, params["scale"], eps=eps)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float):
    exponent = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta ** exponent)  # (d_head/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    inv_freq = rope_frequencies(d_head, theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # (...,S,Dh/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": dense_init(k1, (d, f), cfg.param_dtype),
        "w_up": dense_init(k2, (d, f), cfg.param_dtype),
        "w_down": dense_init(k3, (f, d), cfg.param_dtype),
    }


def mlp(cfg: ModelConfig, params, x):
    dtype = cfg.compute_dtype
    g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dtype))
    u = jnp.einsum("...d,df->...f", x, params["w_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"].astype(dtype))


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def init_embeddings(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    p = {"tok_embed": dense_init(k1, (cfg.padded_vocab, cfg.d_model),
                                 cfg.param_dtype, in_axis=1)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k2, (cfg.d_model, cfg.padded_vocab),
                                  cfg.param_dtype)
    return p


def embed(cfg: ModelConfig, params, tokens):
    # one-hot-free gather; cast to compute dtype after lookup
    return params["tok_embed"][tokens].astype(cfg.compute_dtype)


def lm_logits(cfg: ModelConfig, params, x):
    w = (params["tok_embed"].T if cfg.tie_embeddings
         else params["lm_head"]).astype(cfg.compute_dtype)
    return jnp.einsum("...d,dv->...v", x, w)


def cross_entropy(logits, labels, vocab_size: int):
    """Mean CE in fp32; labels >= vocab_size (padding) are masked."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0) & (labels < vocab_size)
    safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)
