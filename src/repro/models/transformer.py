"""Unified model: init / train_loss / prefill / decode for every family.

The layer stack is a ``lax.scan`` over *periods* (cfg.period repeated
``n_periods`` times).  Block parameters and decode state are pytrees whose
leaves carry a leading ``n_periods`` dim.  The traced HLO is O(|period|)
regardless of depth — essential for the 40-cell multi-pod dry-run on a
single-core host.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm
from repro.models import moe as moe_mod
from repro.models.config import (
    FFN_DENSE, FFN_MOE, FFN_NONE, MIXER_ATTN, MIXER_MAMBA, MIXER_MLSTM,
    MIXER_SLSTM, BlockSpec, ModelConfig)
from repro.models import layers as L
from repro.distributed.sharding import constrain


class DecodeCache(NamedTuple):
    """Per-model decode state: tuple over period positions of stacked
    per-period block states (or None for stateless blocks)."""
    blocks: Any
    cross: Any          # enc-dec: stacked cross KV per decoder period pos
    pos: jax.Array      # scalar int32 — next position to write


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _init_block(cfg: ModelConfig, spec: BlockSpec, key, cross: bool):
    ks = jax.random.split(key, 6)
    p = {"norm1": L.init_rmsnorm(cfg)}
    if spec.mixer == MIXER_ATTN:
        p["attn"] = attn.init_attention(cfg, ks[0])
    elif spec.mixer == MIXER_MAMBA:
        p.update(ssm.init_mamba(cfg, ks[0]))
    elif spec.mixer == MIXER_MLSTM:
        p["mlstm"] = ssm.init_mlstm(cfg, ks[0])
    elif spec.mixer == MIXER_SLSTM:
        p["slstm"] = ssm.init_slstm(cfg, ks[0])
    if cross:
        p["cross_norm"] = L.init_rmsnorm(cfg)
        p["cross_attn"] = attn.init_attention(cfg, ks[1], cross=True)
    if spec.ffn == FFN_DENSE and cfg.d_ff > 0:
        p["norm2"] = L.init_rmsnorm(cfg)
        p["mlp"] = L.init_mlp(cfg, ks[2])
    elif spec.ffn == FFN_MOE:
        p["norm2"] = L.init_rmsnorm(cfg)
        p["moe"] = moe_mod.init_moe(cfg, ks[3])
    return p


def _block_state_init(cfg: ModelConfig, spec: BlockSpec, batch: int,
                      max_len: int):
    if spec.mixer == MIXER_ATTN:
        return attn.init_kv_cache(cfg, batch, max_len)
    if spec.mixer == MIXER_MAMBA:
        return ssm.init_mamba_state(cfg, batch)
    if spec.mixer == MIXER_MLSTM:
        return ssm.init_mlstm_state(cfg, batch)
    if spec.mixer == MIXER_SLSTM:
        return ssm.init_slstm_state(cfg, batch)
    return None


def _apply_block_full(cfg, spec, p, x, positions, memory_kv, collect_state):
    """Whole-sequence block application (train / prefill)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    state = None
    if spec.mixer == MIXER_ATTN:
        out, kv = attn.attend_full(cfg, p["attn"], h, positions)
        state = kv
    elif spec.mixer == MIXER_MAMBA:
        out, state = ssm.mamba_full(cfg, p, h)
    elif spec.mixer == MIXER_MLSTM:
        out, state = ssm.mlstm_full(cfg, p["mlstm"], h)
    elif spec.mixer == MIXER_SLSTM:
        out, state = ssm.slstm_full(cfg, p["slstm"], h)
    else:
        raise ValueError(spec.mixer)
    x = x + out
    if memory_kv is not None and "cross_attn" in p:
        hc = L.rmsnorm(x, p["cross_norm"], cfg.norm_eps)
        x = x + attn.attend_cross(cfg, p["cross_attn"], hc, memory_kv)
    if spec.ffn == FFN_DENSE and cfg.d_ff > 0:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp(cfg, p["mlp"], h2)
    elif spec.ffn == FFN_MOE:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        out2, aux = moe_mod.moe_ffn(cfg, p["moe"], h2)
        x = x + out2
    x = constrain(x, "act_btd")
    return x, (state if collect_state else None), aux


def _apply_block_decode(cfg, spec, p, x, state, pos, memory_kv):
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == MIXER_ATTN:
        out, state = attn.attend_decode(cfg, p["attn"], h, state, pos)
    elif spec.mixer == MIXER_MAMBA:
        out, state = ssm.mamba_decode(cfg, p, h, state)
    elif spec.mixer == MIXER_MLSTM:
        out, state = ssm.mlstm_decode(cfg, p["mlstm"], h, state)
    elif spec.mixer == MIXER_SLSTM:
        out, state = ssm.slstm_decode(cfg, p["slstm"], h, state)
    else:
        raise ValueError(spec.mixer)
    x = x + out
    if memory_kv is not None and "cross_attn" in p:
        hc = L.rmsnorm(x, p["cross_norm"], cfg.norm_eps)
        x = x + attn.attend_cross(cfg, p["cross_attn"], hc, memory_kv)
    if spec.ffn == FFN_DENSE and cfg.d_ff > 0:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp(cfg, p["mlp"], h2)
    elif spec.ffn == FFN_MOE:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        out2, _ = moe_mod.moe_ffn(cfg, p["moe"], h2)
        x = x + out2
    return x, state


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    """Functional model wrapper; all methods are jit-compatible.

    ``unroll=True`` replaces the period ``lax.scan`` with a Python loop —
    used by the roofline harness (XLA's cost_analysis counts a while-loop
    body once regardless of trip count, so per-period costs are measured on
    unrolled depth-1/2 graphs and extrapolated).  ``remat=True`` wraps each
    period in ``jax.checkpoint`` for training-memory realism.
    """

    def __init__(self, cfg: ModelConfig, unroll: bool = False,
                 remat: bool = False):
        self.cfg = cfg
        self.unroll = unroll
        self.remat = remat

    # -- init --------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_blocks, k_enc, k_fin = jax.random.split(key, 4)
        params = {"embed": L.init_embeddings(cfg, k_emb),
                  "final_norm": L.init_rmsnorm(cfg)}
        cross = cfg.is_encdec

        def init_period(k):
            ks = jax.random.split(k, len(cfg.period))
            return tuple(
                _init_block(cfg, spec, ks[i], cross)
                for i, spec in enumerate(cfg.period))

        pkeys = jax.random.split(k_blocks, cfg.n_periods)
        stacked = jax.vmap(init_period)(pkeys)
        params["blocks"] = stacked
        if cfg.is_encdec:
            ekeys = jax.random.split(k_enc, cfg.n_encoder_layers)
            enc_spec = BlockSpec(mixer=MIXER_ATTN, ffn=FFN_DENSE)
            params["enc_blocks"] = jax.vmap(
                lambda k: _init_block(cfg, enc_spec, k, cross=False))(ekeys)
            params["enc_norm"] = L.init_rmsnorm(cfg)
        if cfg.frontend == "vision":
            # stub projection for precomputed patch embeddings
            params["vis_proj"] = L.dense_init(
                k_fin, (cfg.d_model, cfg.d_model), cfg.param_dtype)
        return params

    # -- encoder (whisper-style; input = precomputed frame embeddings) ------
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        positions = jnp.arange(x.shape[1])[None, :]

        def body(x, p):
            spec = BlockSpec(mixer=MIXER_ATTN, ffn=FFN_DENSE)
            x, _, _ = _apply_block_full(
                _noncausal(cfg), spec, p, x, positions, None, False)
            return x, None

        if self.unroll:
            for i in range(cfg.n_encoder_layers):
                x, _ = body(x, jax.tree.map(lambda t: t[i],
                                            params["enc_blocks"]))
        else:
            x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Precompute per-decoder-period-position cross K/V from encoder
        output (stacked over periods)."""
        cfg = self.cfg

        def one_pos(pp):
            def per_period(p):
                k, v = attn._project_kv(cfg, p["cross_attn"], enc_out)
                return attn.KVCache(k=k, v=v)
            return jax.vmap(per_period)(pp)

        return tuple(one_pos(params["blocks"][i])
                     for i in range(len(cfg.period)))

    # -- full pass over a sequence ------------------------------------------
    def _stack_full(self, params, x, positions, cross_kv, collect_state):
        cfg = self.cfg

        def body(carry, inp):
            x, aux = carry
            pp, cross = inp
            states = []
            for i, spec in enumerate(cfg.period):
                mem = None if cross is None else cross[i]
                x, st, a = _apply_block_full(
                    cfg, spec, pp[i], x, positions, mem, collect_state)
                states.append(st)
                aux = aux + a
            return (x, aux), (tuple(states) if collect_state else None)

        aux0 = jnp.zeros((), jnp.float32)
        if self.remat:
            body = jax.checkpoint(body)
        if self.unroll:
            carry = (x, aux0)
            all_states = []
            for i in range(cfg.n_periods):
                pp = jax.tree.map(lambda t: t[i], params["blocks"])
                cr = (None if cross_kv is None
                      else jax.tree.map(lambda t: t[i], tuple(cross_kv)))
                carry, st = body(carry, (pp, cr))
                all_states.append(st)
            (x, aux) = carry
            states = (jax.tree.map(lambda *ts: jnp.stack(ts), *all_states)
                      if collect_state else None)
            return x, aux, states
        if cross_kv is None:
            (x, aux), states = jax.lax.scan(
                lambda c, pp: body(c, (pp, None)), (x, aux0),
                params["blocks"])
        else:
            (x, aux), states = jax.lax.scan(
                body, (x, aux0), (params["blocks"], tuple(cross_kv)))
        return x, aux, states

    # -- train loss ----------------------------------------------------------
    def train_loss(self, params, batch):
        """batch: dict with 'tokens' (B,S), 'labels' (B,S); optional
        'frames' (audio) or 'patches' (vision)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = L.embed(cfg, params["embed"], tokens)
        cross_kv = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
            cross_kv = self._cross_kv(params, enc_out)
        if cfg.frontend == "vision":
            vis = batch["patches"].astype(cfg.compute_dtype)
            vis = jnp.einsum("bpd,de->bpe", vis,
                             params["vis_proj"].astype(cfg.compute_dtype))
            x = jnp.concatenate([vis, x], axis=1)
        x = constrain(x, "act_btd")
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux, _ = self._stack_full(params, x, positions, cross_kv, False)
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        if cfg.frontend == "vision":
            x = x[:, batch["patches"].shape[1]:]
        logits = L.lm_logits(cfg, params["embed"], x)
        logits = constrain(logits, "logits")
        loss = L.cross_entropy(logits, batch["labels"], cfg.vocab_size)
        if any(b.ffn == FFN_MOE for b in cfg.period):
            loss = loss + 0.01 * aux / cfg.n_layers
        return loss

    # -- prefill -------------------------------------------------------------
    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Run the prompt; returns (last-token logits, DecodeCache).

        The KV cache is written into a ``max_len``-sized buffer so decode
        can continue in-place."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        max_len = max_len or s
        x = L.embed(cfg, params["embed"], tokens)
        cross_kv = None
        if cfg.is_encdec:
            enc_out = self.encode(params, batch["frames"])
            cross_kv = self._cross_kv(params, enc_out)
        if cfg.frontend == "vision":
            vis = batch["patches"].astype(cfg.compute_dtype)
            vis = jnp.einsum("bpd,de->bpe", vis,
                             params["vis_proj"].astype(cfg.compute_dtype))
            x = jnp.concatenate([vis, x], axis=1)
        x = constrain(x, "act_btd")
        positions = jnp.arange(x.shape[1])[None, :]
        x, _, states = self._stack_full(params, x, positions, cross_kv, True)
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.lm_logits(cfg, params["embed"], x[:, -1:])

        # pad attention KV caches out to max_len
        seq = x.shape[1]

        def pad_state(spec, st):
            if spec.mixer == MIXER_ATTN and max_len > seq:
                def padkv(t):
                    pw = [(0, 0)] * t.ndim
                    pw[-3] = (0, max_len - seq)
                    return jnp.pad(t, pw)
                return attn.KVCache(k=padkv(st.k), v=padkv(st.v))
            return st

        states = tuple(
            pad_state(spec, states[i]) if states[i] is not None else None
            for i, spec in enumerate(cfg.period))
        cache = DecodeCache(blocks=states, cross=cross_kv,
                            pos=jnp.array(seq, jnp.int32))
        return logits, cache

    # -- one-token decode ------------------------------------------------------
    def decode_step(self, params, cache: DecodeCache, tokens):
        """tokens: (B, 1) the token sampled at cache.pos-1; returns logits
        for position cache.pos and the updated cache."""
        cfg = self.cfg
        x = L.embed(cfg, params["embed"], tokens)
        pos = cache.pos

        def body(x, inp):
            pp, st, cross = inp
            new_states = []
            for i, spec in enumerate(cfg.period):
                mem = None if cross is None else cross[i]
                x, st_i = _apply_block_decode(
                    cfg, spec, pp[i], x, st[i], pos, mem)
                new_states.append(st_i)
            return x, tuple(new_states)

        if self.unroll:
            new_list = []
            for i in range(cfg.n_periods):
                pp = jax.tree.map(lambda t: t[i], params["blocks"])
                st = jax.tree.map(lambda t: t[i], cache.blocks)
                cr = (None if cache.cross is None
                      else jax.tree.map(lambda t: t[i], cache.cross))
                x, st_new = body(x, (pp, st, cr))
                new_list.append(st_new)
            new_states = jax.tree.map(lambda *ts: jnp.stack(ts), *new_list)
        elif cache.cross is None:
            x, new_states = jax.lax.scan(
                lambda c, i: body(c, (i[0], i[1], None)),
                x, (params["blocks"], cache.blocks))
        else:
            x, new_states = jax.lax.scan(
                body, x, (params["blocks"], cache.blocks, cache.cross))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.lm_logits(cfg, params["embed"], x)
        logits = constrain(logits, "logits")
        new_cache = DecodeCache(blocks=new_states, cross=cache.cross,
                                pos=pos + 1)
        return logits, new_cache

    # -- decode state allocation (for dry-run serve_step) ---------------------
    def init_cache(self, batch: int, max_len: int,
                   filled: Optional[int] = None) -> DecodeCache:
        cfg = self.cfg

        def one_pos(spec):
            st = _block_state_init(cfg, spec, batch, max_len)
            if st is None:
                return None
            return jax.tree.map(
                lambda t: jnp.broadcast_to(
                    t[None], (cfg.n_periods,) + t.shape), st)

        states = tuple(one_pos(spec) for spec in cfg.period)
        cross = None
        if cfg.is_encdec:
            kvshape = (cfg.n_periods, batch, cfg.encoder_seq,
                       cfg.n_kv_heads, cfg.d_head)
            cross = tuple(
                attn.KVCache(k=jnp.zeros(kvshape, cfg.compute_dtype),
                             v=jnp.zeros(kvshape, cfg.compute_dtype))
                for _ in cfg.period)
        pos = jnp.array(filled if filled is not None else 0, jnp.int32)
        return DecodeCache(blocks=states, cross=cross, pos=pos)


def _noncausal(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, causal=False)
