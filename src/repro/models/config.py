"""Unified model configuration covering all assigned architecture families.

A model is described as a repeating *period* of heterogeneous blocks.  Each
block has a mixer (attention / mamba / sLSTM / mLSTM) and an optional FFN
(dense SwiGLU or MoE).  ``n_layers`` must be divisible by ``len(period)``;
the stack is executed as ``lax.scan`` over ``n_layers // len(period)``
period instances, keeping the traced HLO O(period) instead of O(n_layers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Block descriptors
# ---------------------------------------------------------------------------

MIXER_ATTN = "attn"
MIXER_MAMBA = "mamba"
MIXER_SLSTM = "slstm"
MIXER_MLSTM = "mlstm"

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer of the repeating period."""

    mixer: str = MIXER_ATTN
    ffn: str = FFN_DENSE


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128

    # Repeating block structure; default = homogeneous attention+dense.
    period: Tuple[BlockSpec, ...] = (BlockSpec(),)

    # Attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    causal: bool = True
    sliding_window: Optional[int] = None

    # MoE options
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0          # expert FFN width (defaults to d_ff)
    capacity_factor: float = 1.25

    # Mamba options (jamba-style)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_dt_rank: int = 0     # 0 -> ceil(d_model/16)

    # xLSTM options
    xlstm_proj_factor: float = 2.0

    # Encoder-decoder (whisper-style)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500    # whisper: 30s audio -> 1500 frames after conv

    # Modality frontend stub: none | audio | vision
    frontend: str = "none"
    n_patches: int = 256       # vision stub: patch embeddings prepended

    # Norm / embedding
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # vocab padded up to a multiple of this for clean TP sharding
    vocab_pad_multiple: int = 256

    # Precision
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16

    # -- derived ------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by period "
            f"{len(self.period)}")
        return self.n_layers // len(self.period)

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or max(1, -(-self.d_model // 16))

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(b.mixer != MIXER_ATTN for b in self.period)

    @property
    def subquadratic(self) -> bool:
        """True when decode state does not grow quadratically with context.

        Hybrid (jamba) counts: its rare attention layers use
        sequence-parallel flash-decoding; pure full-attention archs do not.
        """
        n_attn = sum(1 for b in self.period if b.mixer == MIXER_ATTN)
        return n_attn < len(self.period) or self.attention_free

    def scaled(self, **overrides) -> "ModelConfig":
        """Return a reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)

    # Parameter count (embedding + blocks), used for MODEL_FLOPS = 6*N*D.
    def param_count(self, active_only: bool = False) -> int:
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        total = emb
        per_period = 0
        for blk in self.period:
            if blk.mixer == MIXER_ATTN:
                per_period += d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
                if self.qkv_bias:
                    per_period += (h + 2 * kv) * dh
            elif blk.mixer == MIXER_MAMBA:
                di, ds, dtr = self.mamba_d_inner, self.mamba_d_state, self.dt_rank
                per_period += d * 2 * di            # in_proj
                per_period += di * self.mamba_d_conv  # conv
                per_period += di * (dtr + 2 * ds)   # x_proj
                per_period += dtr * di + di         # dt_proj
                per_period += di * ds + di          # A_log, D
                per_period += di * d                # out_proj
            elif blk.mixer in (MIXER_SLSTM, MIXER_MLSTM):
                dp = int(self.xlstm_proj_factor * d)
                per_period += 4 * d * dp + 2 * d * dp  # gates-ish + up/down
            if blk.ffn == FFN_DENSE and self.d_ff > 0:
                per_period += 3 * d * self.d_ff
            elif blk.ffn == FFN_MOE:
                eff = self.expert_d_ff
                n_e = self.top_k if active_only else self.n_experts
                per_period += n_e * 3 * d * eff + d * self.n_experts
            per_period += 2 * d  # norms
        total += per_period * self.n_periods
        if self.is_encdec:
            # encoder: attn + dense ffn per layer, plus decoder cross-attn.
            enc = self.n_encoder_layers * (
                d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d
                + 3 * d * self.d_ff + 2 * d)
            cross = self.n_layers * (
                d * (h * dh) + 2 * d * (kv * dh) + (h * dh) * d + d)
            total += enc + cross
        return int(total)
