"""Grouped-query attention with RoPE, optional QKV bias / QK-norm, KV cache.

Three entry points:
  * ``attend_full``   — train / prefill over a whole sequence (causal or not),
  * ``attend_decode`` — one new token against a pre-allocated KV cache,
  * ``attend_cross``  — encoder-decoder cross attention.

The score/softmax math lives in ``_sdpa`` (the pure-jnp oracle that the
Pallas flash kernels are checked against).  ``use_flash``/``use_decode_kernel``
switch in the Pallas TPU kernels; the default jnp path is what the CPU
dry-run lowers (XLA fuses it into the same logical cost).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init
from repro.distributed.sharding import constrain


class KVCache(NamedTuple):
    k: jax.Array  # (B, T, KV, Dh)
    v: jax.Array  # (B, T, KV, Dh)


def init_attention(cfg: ModelConfig, key, cross: bool = False):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, dh), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, kv, dh), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, kv, dh), cfg.param_dtype),
        "wo": dense_init(ks[3], (h, dh, d), cfg.param_dtype, in_axis=0),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, dh), cfg.param_dtype)
        p["bk"] = jnp.zeros((kv, dh), cfg.param_dtype)
        p["bv"] = jnp.zeros((kv, dh), cfg.param_dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((dh,), cfg.param_dtype)
    return p


def _project_q(cfg, params, x):
    dtype = cfg.compute_dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    if "bq" in params:
        q = q + params["bq"].astype(dtype)
    if cfg.qk_norm:
        q = _head_rmsnorm(q, params["q_norm"], cfg.norm_eps)
    return q


def _project_kv(cfg, params, x):
    dtype = cfg.compute_dtype
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if "bk" in params:
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if cfg.qk_norm:
        k = _head_rmsnorm(k, params["k_norm"], cfg.norm_eps)
    return k, v


def _head_rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def _sdpa(q, k, v, mask, scale):
    """Reference scaled-dot-product attention.

    q: (B, Sq, KV, G, Dh) grouped; k/v: (B, Sk, KV, Dh); mask broadcastable
    to (B, KV, G, Sq, Sk) or None.
    """
    scores = jnp.einsum("bqhgk,bshk->bhgqs", q, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqs,bshk->bqhgk", probs, v)


CHUNKED_ATTN_MIN_SEQ = 8_192
CHUNK_KV = 2_048


def _sdpa_chunked(q, k, v, scale, causal: bool, chunk: int = CHUNK_KV):
    """Online-softmax attention scanned over KV chunks (perf iteration #5).

    The jnp twin of the Pallas flash kernel: XLA never materializes the
    (Sq, Sk) score matrix — the working set per step is (B, Sq, KV, G,
    chunk), cutting the memory roofline term ~Sk/chunk-fold for long
    prefill/train sequences.  Exactly matches ``_sdpa`` output (same
    masking semantics) and is used automatically for Sk >=
    CHUNKED_ATTN_MIN_SEQ.
    """
    b, sq, kvh, g, dh = q.shape
    sk = k.shape[1]
    assert sk % chunk == 0, (sk, chunk)
    n_chunks = sk // chunk
    qf = q.astype(jnp.float32)
    kc = k.reshape(b, n_chunks, chunk, kvh, dh).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, chunk, kvh, dh).swapaxes(0, 1)
    q_pos = jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, idx = inp
        s = jnp.einsum("bqhgk,bshk->bqhgs", qf,
                       kb.astype(jnp.float32)) * scale
        if causal:
            kv_pos = idx * chunk + jnp.arange(chunk)
            keep = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(keep[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        acc = alpha[..., None] * acc + jnp.einsum(
            "bqhgs,bshk->bqhgk", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, sq, kvh, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    acc0 = jnp.zeros((b, sq, kvh, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def _group(q, n_kv):
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh)


def attend_full(cfg: ModelConfig, params, x, positions,
                causal: Optional[bool] = None, use_flash: bool = False):
    """Full-sequence attention (train / prefill). Returns (out, KVCache)."""
    causal = cfg.causal if causal is None else causal
    q = _project_q(cfg, params, x)
    k, v = _project_kv(cfg, params, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "act_qkv")
    k = constrain(k, "act_kv")
    v = constrain(v, "act_kv")
    scale = 1.0 / (cfg.d_head ** 0.5)
    if use_flash:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=causal, scale=scale)
    elif (x.shape[1] >= CHUNKED_ATTN_MIN_SEQ
          and x.shape[1] % CHUNK_KV == 0
          and cfg.sliding_window is None):
        out = _sdpa_chunked(_group(q, cfg.n_kv_heads), k, v, scale, causal)
        out = out.reshape(x.shape[0], x.shape[1], cfg.n_heads, cfg.d_head)
    else:
        s = x.shape[1]
        mask = None
        if causal:
            idx = jnp.arange(s)
            mask = (idx[:, None] >= idx[None, :])[None, None, None]
        if cfg.sliding_window is not None:
            idx = jnp.arange(s)
            w = (idx[:, None] - idx[None, :]) < cfg.sliding_window
            win = w[None, None, None]
            mask = win if mask is None else (mask & win)
        out = _sdpa(_group(q, cfg.n_kv_heads), k, v, mask, scale)
        out = out.reshape(x.shape[0], s, cfg.n_heads, cfg.d_head)
    out = constrain(out, "act_qkv")
    out = jnp.einsum("bshk,hkd->bsd",
                     out, params["wo"].astype(cfg.compute_dtype))
    return out, KVCache(k=k, v=v)


def attend_decode(cfg: ModelConfig, params, x, cache: KVCache, pos,
                  use_kernel: bool = False):
    """One-token decode. ``x``: (B, 1, D); ``pos``: scalar index of the new
    token. Writes K/V at ``pos`` and attends to positions <= pos."""
    b = x.shape[0]
    q = _project_q(cfg, params, x)                   # (B,1,H,Dh)
    k_new, v_new = _project_kv(cfg, params, x)       # (B,1,KV,Dh)
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), pos, axis=1)
    k = constrain(k, "kv_cache")
    v = constrain(v, "kv_cache")
    scale = 1.0 / (cfg.d_head ** 0.5)
    if use_kernel:
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q[:, 0], k, v, pos, scale=scale)[:, None]
    else:
        t = k.shape[1]
        mask = (jnp.arange(t) <= pos)[None, None, None, None, :]
        out = _sdpa(_group(q, cfg.n_kv_heads), k, v, mask, scale)
        out = out.reshape(b, 1, cfg.n_heads, cfg.d_head)
    out = jnp.einsum("bshk,hkd->bsd",
                     out, params["wo"].astype(cfg.compute_dtype))
    return out, KVCache(k=k, v=v)


def attend_cross(cfg: ModelConfig, params, x, memory_kv: KVCache):
    """Cross attention against precomputed encoder K/V (no RoPE)."""
    q = _project_q(cfg, params, x)
    scale = 1.0 / (cfg.d_head ** 0.5)
    out = _sdpa(_group(q, cfg.n_kv_heads), memory_kv.k, memory_kv.v,
                None, scale)
    out = out.reshape(x.shape[0], x.shape[1], cfg.n_heads, cfg.d_head)
    return jnp.einsum("bshk,hkd->bsd",
                      out, params["wo"].astype(cfg.compute_dtype))


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=None) -> KVCache:
    dtype = dtype or cfg.compute_dtype
    shape = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
