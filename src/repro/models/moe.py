"""Mixture-of-Experts FFN (top-1 / top-k) with sorted capacity dispatch.

TPU-idiomatic implementation: instead of a dense (tokens, E, C) dispatch
tensor (GShard-style, infeasible at 1M-token prefill), tokens are sorted by
expert id, ranked within their expert via a searchsorted offset, and
gathered into an (E, C, D) expert-major buffer — O(tokens·D) memory.  Tokens
beyond an expert's capacity are dropped (standard capacity-factor
semantics); their residual path passes through untouched.

Expert weights are (E, D, F) / (E, F, D) and shard over the ``model`` axis
(expert parallelism); the gather/scatter across the sharded E dim is where
GSPMD inserts the all-to-all that shows up in the collective roofline term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.distributed.sharding import constrain


def init_moe(cfg: ModelConfig, key):
    d, f, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, (d, e), cfg.param_dtype),
        "experts": {
            "w_gate": dense_init(k2, (e, d, f), cfg.param_dtype, in_axis=1),
            "w_up": dense_init(k3, (e, d, f), cfg.param_dtype, in_axis=1),
            "w_down": dense_init(k4, (e, f, d), cfg.param_dtype, in_axis=1),
        },
    }


def expert_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    cap = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, ((cap + 7) // 8) * 8)


def moe_ffn(cfg: ModelConfig, params, x):
    """x: (B, S, D) -> (B, S, D), plus aux load-balancing loss.

    Dispatches to the shard_map expert-parallel path when a sharding
    policy with ``ep_moe`` is active and experts divide the TP width
    (see repro.distributed.ep_moe); otherwise the GSPMD path below."""
    from repro.distributed.sharding import get_policy
    policy = get_policy()
    if policy is not None and policy.ep_moe:
        from repro.distributed.ep_moe import ep_available, moe_ffn_ep
        if ep_available(cfg, policy, batch=x.shape[0], seq=x.shape[1]):
            return moe_ffn_ep(cfg, params, x, policy)
    b, s, d = x.shape
    n_tokens = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = expert_capacity(cfg, n_tokens)
    dtype = cfg.compute_dtype

    xt = x.reshape(n_tokens, d)
    logits = jnp.einsum("td,de->te", xt,
                        params["router"].astype(dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)       # (T, k)
    if k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Aux loss (Switch-style load balancing).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    # ---- sorted capacity dispatch -------------------------------------
    flat_expert = gate_idx.reshape(-1)                  # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(n_tokens), k) if k > 1 else \
        jnp.arange(n_tokens)

    order = jnp.argsort(flat_expert)
    se = flat_expert[order]
    st = flat_token[order]
    sg = flat_gate[order]
    # rank of each entry within its expert
    starts = jnp.searchsorted(se, jnp.arange(e))
    rank = jnp.arange(se.shape[0]) - starts[se]
    keep = rank < cap
    slot_e = jnp.where(keep, se, 0)
    slot_c = jnp.where(keep, rank, 0)

    gathered = xt[st] * keep[:, None].astype(dtype)     # (T*k, D)
    buf = jnp.zeros((e, cap, d), dtype)
    buf = buf.at[slot_e, slot_c].add(gathered)
    buf = constrain(buf, "moe_ecd")

    w = params["experts"]
    g = jnp.einsum("ecd,edf->ecf", buf, w["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w["w_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w["w_down"].astype(dtype))
    out_buf = constrain(out_buf, "moe_ecd")

    # ---- combine back ---------------------------------------------------
    expert_out = out_buf[slot_e, slot_c]                # (T*k, D)
    expert_out = expert_out * (sg * keep).astype(dtype)[:, None]
    yt = jnp.zeros_like(xt)
    yt = yt.at[st].add(expert_out)
    return yt.reshape(b, s, d), aux
