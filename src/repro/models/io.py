"""Model input construction: ShapeDtypeStruct specs (dry-run) and concrete
batches (tests / real runs) from an (arch config, ShapeSpec) cell."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig


def _token_shapes(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, tuple]:
    b, s = shape.global_batch, shape.seq_len
    out = {}
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision":
            text = s - cfg.n_patches
            out["tokens"] = (b, text)
            out["patches"] = (b, cfg.n_patches, cfg.d_model)
            if shape.kind == "train":
                out["labels"] = (b, text)
        else:
            out["tokens"] = (b, s)
            if shape.kind == "train":
                out["labels"] = (b, s)
        if cfg.frontend == "audio":
            out["frames"] = (b, cfg.encoder_seq, cfg.d_model)
    else:  # decode
        out["tokens"] = (b, 1)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Weak-type-correct ShapeDtypeStruct stand-ins; no device allocation."""
    shapes = _token_shapes(cfg, shape)
    specs = {}
    for name, shp in shapes.items():
        if name in ("tokens", "labels"):
            specs[name] = jax.ShapeDtypeStruct(shp, jnp.int32)
        else:
            specs[name] = jax.ShapeDtypeStruct(shp, cfg.compute_dtype)
    return specs


def make_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
    """Concrete random batch matching input_specs (for smoke tests)."""
    rng = np.random.default_rng(seed)
    shapes = _token_shapes(cfg, shape)
    batch = {}
    for name, shp in shapes.items():
        if name in ("tokens", "labels"):
            batch[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=shp, dtype=np.int32))
        else:
            batch[name] = jnp.asarray(
                rng.standard_normal(shp, dtype=np.float32),
                dtype=cfg.compute_dtype)
    return batch
