"""Three-term roofline analysis from the compiled dry-run artifacts.

Method (documented in EXPERIMENTS.md §Roofline):

* XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of
  trip count, so per-cell costs are measured on *unrolled* depth-1 and
  depth-2 variants of the full-width config:

      body  = cost(u2) - cost(u1)        # one period (+1 enc layer)
      base  = cost(u1) - body            # embeddings, head, loss, optimizer
      total = base + n_periods * body

  The same extrapolation applies to per-collective-kind bytes.
* Inner recurrent scans (mamba chunk scan, sLSTM time scan, mLSTM chunk
  scan) are also while-loops; their bodies are corrected analytically:
  ``+ (trip_count - 1) x body_flops/bytes`` from closed-form counts of our
  own block implementations (exact for FLOPs of the ops we emit).
* Terms (seconds, per chip — cost_analysis of an SPMD module is already
  per-device):
      compute    = FLOPs / peak_FLOPs
      memory     = bytes_accessed / HBM_bw
      collective = collective_bytes / ICI_bw

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional

from repro.configs import get_config
from repro.configs.shapes import ShapeSpec, get_shape
from repro.models.config import (MIXER_MAMBA, MIXER_MLSTM, MIXER_SLSTM,
                                 ModelConfig)
from repro.models import ssm

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"
DRYRUN = RESULTS / "dryrun"


# ---------------------------------------------------------------------------
# analytic corrections for inner recurrent scans (per device)
# ---------------------------------------------------------------------------

def inner_scan_correction(cfg: ModelConfig, shape: ShapeSpec,
                          chips: int) -> Dict[str, float]:
    """Extra (flops, bytes) missing from once-counted inner-scan bodies."""
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    b = shape.global_batch
    s = shape.seq_len
    if cfg.frontend == "vision":
        s = shape.seq_len  # patches included in backbone seq
    mult = 3.0 if shape.kind == "train" else 1.0   # bwd ~ 2x fwd
    flops = 0.0
    nbytes = 0.0
    reps = cfg.n_periods
    # chunked-attention scan (perf iteration 5): body counted once by XLA
    from repro.models import attention as A
    if s >= A.CHUNKED_ATTN_MIN_SEQ and s % A.CHUNK_KV == 0:
        n_chunks = s // A.CHUNK_KV
        attn_reps = sum(1 for bk in cfg.period
                        if bk.mixer == "attn") * reps
        if cfg.is_encdec:
            attn_reps += cfg.n_encoder_layers
        hd = cfg.n_heads * cfg.d_head
        attn_f = 4.0 * b * s * s * hd * (0.5 if cfg.causal else 1.0)
        # scan carries (m, l, acc) rewritten per chunk
        carry_b = b * s * cfg.n_heads * (cfg.d_head + 2) * 4 * 2
        flops += attn_reps * attn_f * (n_chunks - 1) / n_chunks * mult
        nbytes += attn_reps * carry_b * (n_chunks - 1) * mult
    for blk in cfg.period:
        if blk.mixer == MIXER_MAMBA:
            L = ssm.MAMBA_CHUNK
            trips = s // L
            di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
            body_f = 9.0 * b * L * di * ds
            body_b = 5.0 * b * L * di * ds * 4
            flops += reps * (trips - 1) * body_f * mult
            nbytes += reps * (trips - 1) * body_b * mult
        elif blk.mixer == MIXER_SLSTM:
            dp = int(cfg.xlstm_proj_factor * cfg.d_model)
            trips = s
            body_f = 8.0 * b * dp * dp + 12.0 * b * dp
            body_b = 6.0 * b * dp * 4
            flops += reps * (trips - 1) * body_f * mult
            nbytes += reps * (trips - 1) * body_b * mult
        elif blk.mixer == MIXER_MLSTM:
            L = min(ssm.MLSTM_CHUNK, s)
            trips = s // L
            dp = int(cfg.xlstm_proj_factor * cfg.d_model)
            dk = dp // cfg.n_heads
            h = cfg.n_heads
            body_f = b * h * L * L * (4 * dk + 8.0) + 4.0 * b * h * L * dk * dk
            body_b = (3.0 * b * L * dp + 2.0 * b * h * dk * dk) * 4
            flops += reps * (trips - 1) * body_f * mult
            nbytes += reps * (trips - 1) * body_b * mult
    return {"flops": flops / chips, "bytes": nbytes / chips}


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful-work floor, per device)
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeSpec, chips: int) -> float:
    n_active = cfg.param_count(active_only=True)
    attn_layers = sum(1 for bks in cfg.period
                      if bks.mixer == "attn") * cfg.n_periods
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        f = 6.0 * n_active * tokens
        f += 3 * 4 * shape.global_batch * shape.seq_len ** 2 \
            * cfg.n_heads * cfg.d_head * attn_layers / 2
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        f = 2.0 * n_active * tokens
        f += 4 * shape.global_batch * shape.seq_len ** 2 \
            * cfg.n_heads * cfg.d_head * attn_layers / 2
    else:  # decode: one token per sequence
        f = 2.0 * n_active * shape.global_batch
        f += 4 * shape.global_batch * shape.seq_len \
            * cfg.n_heads * cfg.d_head * attn_layers
    return f / chips


# ---------------------------------------------------------------------------
# record loading / extrapolation
# ---------------------------------------------------------------------------

def _load(arch: str, shape: str, mesh: str, tag: str = "") -> Optional[dict]:
    p = DRYRUN / f"{arch}__{shape}__{mesh}{tag}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def _coll_bytes(rec: dict) -> float:
    return float(sum(v["bytes"] for v in rec.get("collectives", {}).values()))


def extrapolate_cell(arch: str, shape_name: str,
                     mesh: str = "16x16") -> Optional[dict]:
    """Combine full/u1/u2 dry-run records into roofline terms."""
    full = _load(arch, shape_name, mesh)
    u1 = _load(arch, shape_name, mesh, "u1")
    u2 = _load(arch, shape_name, mesh, "u2")
    if full is None or full["status"] != "ok":
        return full
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    chips = 256 if mesh == "16x16" else 512
    n_periods = cfg.n_periods

    if u1 and u2 and u1["status"] == u2["status"] == "ok":
        body_f = max(u2["flops"] - u1["flops"], 0.0)
        body_b = max(u2["bytes_accessed"] - u1["bytes_accessed"], 0.0)
        body_c = max(_coll_bytes(u2) - _coll_bytes(u1), 0.0)
        base_f = max(u1["flops"] - body_f, 0.0)
        base_b = max(u1["bytes_accessed"] - body_b, 0.0)
        base_c = max(_coll_bytes(u1) - body_c, 0.0)
        flops = base_f + n_periods * body_f
        nbytes = base_b + n_periods * body_b
        coll = base_c + n_periods * body_c
        method = "u1/u2 extrapolation"
    else:
        flops, nbytes, coll = (full["flops"], full["bytes_accessed"],
                               _coll_bytes(full))
        method = "full-graph (scan body once; lower bound)"

    corr = inner_scan_correction(cfg, shape, chips)
    flops += corr["flops"]
    nbytes += corr["bytes"]

    t_compute = flops / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, chips)
    bound = max(terms.values())
    useful_frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh, "method": method,
        "flops": flops, "bytes": nbytes, "collective_bytes": coll,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "useful_flops_ratio": mf / flops if flops else 0,
        "roofline_fraction": useful_frac,
        "memory_per_device": full.get("memory", {}),
        "scan_correction": corr,
    }


MITIGATIONS = {
    "compute": "raise MFU: larger per-chip tiles (less TP), fuse attention "
               "(flash kernel), drop remat recompute on cheap ops",
    "memory": "cut HBM traffic: fuse norms/elementwise into matmuls, bf16 "
              "activations end-to-end, avoid full-KV rewrites per step",
    "collective": "reshard: keep activations sequence-sharded through the "
                  "block (avoid boundary re-gathers), overlap collectives "
                  "with compute, int8-compress DCN traffic",
}


def analyze_all(mesh: str = "16x16") -> list:
    from repro.configs import ARCHS
    from repro.configs.shapes import SHAPES, cell_applicable
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shp in SHAPES:
            ok, reason = cell_applicable(cfg, shp)
            if not ok:
                out.append({"arch": arch, "shape": shp.name, "mesh": mesh,
                            "status": "skipped", "reason": reason})
                continue
            rec = extrapolate_cell(arch, shp.name, mesh)
            if rec is not None:
                rec.setdefault("status", "ok")
                if rec.get("status") == "ok" and "dominant" in rec:
                    rec["mitigation"] = MITIGATIONS[rec["dominant"]]
                out.append(rec)
    (RESULTS / "roofline.json").write_text(json.dumps(out, indent=1))
    return out


def markdown_table(records: list) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "dominant | MODEL/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in records:
        if r.get("status") == "skipped" or "t_compute_s" not in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped | — | — |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} |")
    return hdr + "\n".join(rows)


if __name__ == "__main__":
    recs = analyze_all()
    print(markdown_table(recs))
