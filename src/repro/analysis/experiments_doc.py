"""Generate EXPERIMENTS.md from results artifacts (bench_report.json,
dryrun records, roofline.json, perf_report.json)."""
from __future__ import annotations

import json
import pathlib

from repro.analysis import roofline as R
from repro.analysis.perf_report import CELLS, report as perf_table

REPO = pathlib.Path(__file__).resolve().parents[3]
RESULTS = REPO / "results"


def _bench():
    p = RESULTS / "bench_report.json"
    return json.loads(p.read_text()) if p.exists() else {}


def dryrun_section() -> str:
    recs = []
    for p in sorted((RESULTS / "dryrun").glob("*.json")):
        if "u1" in p.name or "u2" in p.name or "pbase" in p.name:
            continue
        recs.append(json.loads(p.read_text()))
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skipped"]
    fail = [r for r in recs if r["status"] == "error"]
    lines = [
        f"Cells compiled: **{len(ok)} ok / {len(skip)} skipped / "
        f"{len(fail)} failed** across meshes 16x16 (256 chips) and "
        f"2x16x16 (512 chips, multi-pod).",
        "",
        "Skips are the assignment-mandated `long_500k` cells for pure "
        "full-attention archs (dense-KV 512k decode out of scope); the "
        "sub-quadratic archs (jamba-1.5-large, xlstm-125m) run it.",
        "",
        "| arch | shape | mesh | compile_s | HLO flops/dev | "
        "args GB/dev | temp GB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = r.get("memory", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('compile_s', 0):.1f} | {r['flops']:.3g} | "
            f"{m.get('argument_size_in_bytes', 0)/1e9:.2f} | "
            f"{m.get('temp_size_in_bytes', 0)/1e9:.2f} |")
    if fail:
        lines.append("\nFailures:\n")
        for r in fail:
            lines.append(f"* {r['arch']} {r['shape']} {r['mesh']}: "
                         f"{r['error']}")
    return "\n".join(lines)


def roofline_section() -> str:
    recs = R.analyze_all()
    table = R.markdown_table(recs)
    doms = {}
    for r in recs:
        if "dominant" in r:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    notes = [
        "",
        f"Dominant-term census: {doms}.",
        "",
        "Per-cell one-line mitigations are in `results/roofline.json` "
        "(`mitigation` field); the three §Perf cells act on them.",
    ]
    return table + "\n" + "\n".join(notes)


HEADER = """# EXPERIMENTS

All numbers regenerate with:

```
PYTHONPATH=src python -m repro.launch.dryrun --all        # §Dry-run
PYTHONPATH=src python -m repro.launch.dryrun --roofline   # §Roofline inputs
PYTHONPATH=src python -m benchmarks.run                    # §Paper-validation
PYTHONPATH=src python -m repro.analysis.experiments_doc    # this file
```

Hardware model: the cost functions are pure in a `HardwareProfile`
descriptor (`repro.perfmodel.hardware`; see `docs/hardware_model.md`).
The fitted baseline is TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI — with registered GPU/NPU descriptors (TPU v4, A100,
H100, MI300X, L4, legacy-gpu) reached by cross-hardware transfer
(`benchmarks/run.py transfer_engine`). This container is CPU-only:
model quality numbers are real CPU executions; roofline terms derive
from compiled-HLO costs.
"""

DATASETS = """## §Datasets

* `inhouse` — 4,800 points: LLaMA-3.1-8B served at TP=4 on the v5e
  analytical simulator; grid 8 input sizes x 6 output sizes x 10 batch
  sizes x 10 noisy repetitions (the paper's ~4,800-point in-house set).
* `suite` — LLM-inference-bench analog: all 11 archs x 3 serving
  frameworks x (bb 1-64, ii/oo 128-2048) x 3 reps.
* `mismatch` — qwen3-0.6b on a `legacy-gpu` profile (105 TF/s, 1.6 TB/s):
  the RQ4 hardware-mismatch case.
* real-measurement path: `repro.bench.harness` + `examples/serve_demo.py`
  time the actual JAX engine (tiny configs on CPU; full configs on TPU).
"""


def paper_validation_section() -> str:
    b = _bench()
    out = ["## §Paper-validation (RQ1-RQ4)", ""]
    if "fig2" in b:
        out += [
            f"**Alg 2 fit quality (Fig 2)** — {b['fig2']['db_groups']} "
            f"(ii,oo) groups fitted in {b['fig2']['fit_db_s']:.2f}s "
            f"(batched LM); train median APE "
            f"{b['fig2']['train_median_ape']:.2f}% (noise floor ~4% at "
            f"sigma=0.05 lognormal).", ""]
    if "fig3" in b:
        out += [
            f"**Alg 3 extrapolation (Fig 3)** — params predicted for "
            f"{b['fig3']['held_groups']} fully held-out (ii,oo) groups: "
            f"median APE {b['fig3']['unseen_median_ape']:.2f}%.", ""]
    if "fig6_rq1" in b:
        out += ["**RQ1 (Figs 5-6): training-set composition**", "",
                "| experiment | median APE | p90 | n_train |",
                "|---|---|---|---|"]
        for k, v in b["fig6_rq1"].items():
            out.append(f"| {k} | {v['median']:.2f}% | {v['p90']:.1f}% | "
                       f"{v['n_train']} |")
        out += ["",
                "Matches the paper: broad balanced coverage (exp1) is "
                "best; dropping large batch sizes (exp3) hides the "
                "exponential saturation; sparse coverage (exp4) degrades "
                "further. Dense clusters (exp2) sit between exp1 and "
                "exp3/exp4, as in Fig 6.", ""]
    if "fig7_rq2" in b:
        c = b["fig7_rq2"]["comparison"]
        out += ["**RQ2 (Fig 7): ALA vs baselines**", "",
                "| method | median APE (random split) | median APE over "
                "SA subsets | train time |",
                "|---|---|---|---|"]
        sa = b["fig7_rq2"]["sa_median_by_method"]
        names = {"ALA": "ALA", "linear_regression": "linear_regression",
                 "vanilla_xgboost": "vanilla_xgboost",
                 "random_forest": "random_forest",
                 "gradient_boosting": "gradient_boosting"}
        for k in names:
            v = c.get(k, {})
            s = sa.get(k, {})
            out.append(f"| {k} | {v.get('median_ape', 0):.2f}% | "
                       f"{s.get('median', 0):.1f}% | "
                       f"{v.get('train_us', 0)/1e6:.2f}s |")
        out += ["",
                "On the *restricted training subsets* the SA explores "
                "(the paper's regime — benchmarking budgets never cover "
                "the space), ALA's analytical form dominates every ML "
                "baseline, mirroring Fig 7(a)-(b). On a dense random "
                "split (pure interpolation) a well-tuned GBT matches it "
                "— also visible in the paper's Fig 7 spread. ALA's extra "
                "train time is the multi-stage fit (Fig 7(c)-(d)).", ""]
    if "fig8_rq3" in b:
        out += ["**RQ3 (Fig 8): per-architecture generalization "
                "(suite dataset)**", "",
                "| arch | median APE | p90 |", "|---|---|---|"]
        for k, v in sorted(b["fig8_rq3"].items()):
            out.append(f"| {k} | {v['median']:.2f}% | {v['p90']:.1f}% |")
        out += ["",
                "The exponential model characterizes every family — "
                "dense, MoE (coupon-collector weight-read saturation), "
                "hybrid SSM (flat curves), enc-dec, VLM — with "
                "consistently low median errors, as the paper found "
                "across LLaMA/Mistral/Qwen.", ""]
    if "table1_rq4" in b:
        out += ["**RQ4 (Table I): uncertainty quantification**", "",
                "| dataset | predicted error | confidence | actual error |",
                "|---|---|---|---|"]
        for k, v in b["table1_rq4"].items():
            out.append(f"| {k} | {v['predicted_error']:.2f}% | "
                       f"{v['confidence']:.2f} | "
                       f"{v['actual_error']:.2f}% |")
        out += ["",
                "Reproduces the paper's Table I structure: in-distribution "
                "workloads get high confidence and well-matched error "
                "prediction; the different-model case keeps good error "
                "tracking at lower confidence; the hardware-mismatch case "
                "(different accelerator profile) *underestimates* the "
                "actual error and is flagged by the lowest confidence — "
                "the same failure signature as Qwen2-7B-on-PVC.", ""]
    if "perf_vmapped_fit" in b:
        p = b["perf_vmapped_fit"]
        out += [
            f"**Beyond-paper (modeling side)** — one vmapped-LM XLA call "
            f"fits {p['groups']} workload groups in "
            f"{p['batched_us']/1e3:.1f} ms vs {p['loop_us']/1e3:.1f} ms "
            f"for the scalar python-loop fit "
            f"({p['speedup']:.1f}x on 1 CPU core; the gap widens with "
            f"cores/accelerators since the batch is a single kernel).", ""]
    return "\n".join(out)


def perf_section() -> str:
    return "\n".join([
        "## §Perf — hillclimbing log",
        "",
        "Three cells chosen per the brief: worst roofline fraction & "
        "most collective-bound (llama4-maverick train_4k), decode cell "
        "most representative of the paper's technique (qwen2.5-32b "
        "decode_32k — decode throughput is exactly what ALA models), and "
        "the non-divisible-heads prefill pathology (llama3.2-3b "
        "prefill_32k).",
        "",
        perf_table(),
        "",
        "### Iteration log (hypothesis -> change -> measure -> verdict)",
        "",
        "0. *Instrumentation bug (negative result worth recording)*: the "
        "first HLO collective parser counted every line mentioning a "
        "collective — including fusions that merely *consume* one — "
        "inflating collective bytes ~10x and mislabeling nearly every "
        "cell collective-bound (original table preserved at "
        "`results/roofline_baseline.md`). All numbers here use the fixed "
        "parser (unit-tested in tests/test_dryrun_unit.py). Lesson: "
        "validate the profiler before optimizing against it.",
        "",
        "1. **qwen2.5-32b decode_32k** — *Hypothesis*: 2D (data x model) "
        "serving-weight sharding costs a full per-step weight all-gather "
        "(8 GB f32-lowered); TP-only weights (4.1 GB/dev bf16, fit HBM) "
        "remove it. *Change*: `serving_2d` auto-off when params fit. "
        "*Measured*: all-gather 8.0 GB -> 0.01 GB; collective term 4.1 -> "
        "1.2 ms. **Confirmed for the collective term; overall bound "
        "REFUTED on CPU-lowered accounting** — the memory term rose "
        "100 -> 131 ms because the CPU lowering converts the now-larger "
        "local bf16 weight shard to f32 before the dot (2x bytes). On "
        "TPU (native bf16 MXU) the same change is a projected win: "
        "4.1 GB weight reads = 5 ms vs 8 GB gathered traffic. Recorded "
        "as hardware-conditional.",
        "",
        "2. **llama3.2-3b prefill_32k** — *Hypothesis (from buggy "
        "parser)*: SP<->TP boundary thrash dominates (24 heads % 16 != "
        "0). *Change*: `cp_replicate_weights` context-parallel serving. "
        "*Measured*: collective term trimmed 1010 -> 993 ms, but the "
        "honest baseline was **memory-bound** (4.83 s), not collective-"
        "bound — hypothesis partially refuted; kept the change (it "
        "removes real resharding) and re-aimed at the memory term "
        "(iteration 5).",
        "",
        "3. **llama4-maverick train_4k** — *Hypothesis*: GSPMD cannot "
        "partition scatter-based MoE dispatch (computed indices cross "
        "shards): it replicates the (E, C, D) buffer and all-reduces it "
        "(130 GB/period measured). A shard_map EP formulation (local "
        "dispatch by construction + one (T_loc, D) psum) removes it. "
        "*Change*: `repro.distributed.ep_moe`, default-on when "
        "E % TP == 0. *Measured*: collective term 69.9 s -> 9.5 s, "
        "memory term 36.2 -> 14.1 s (replicated-buffer traffic gone); "
        "cell bound 69.9 -> 14.1 s (**x4.9**). **Confirmed.**",
        "",
        "4. **ZeRO-1 update gather** — *Hypothesis*: the Adam update "
        "all-gathers m-hat and v-hat separately across `data` (2x fp32 "
        "param bytes; ~180 GB/step for llama4). Fusing the delta and "
        "pinning it to the ZeRO layout gathers once. *Change*: "
        "`adamw_update(constrain_update=...)`. *Measured on llama4 "
        "train*: included in the 9.5 s collective figure above "
        "(~90 GB/step saved). **Confirmed.**",
        "",
        "5. **Chunked online-softmax attention** — *Hypothesis*: the "
        "dense jnp attention materializes (S x S) scores "
        "(~430 GB/layer/dev at 32k prefill), making every long-sequence "
        "cell memory-bound; a lax.scan online-softmax over 2k KV chunks "
        "(the jnp twin of the Pallas flash kernel) cuts the term "
        "~Sk/chunk-fold. *Change*: `_sdpa_chunked`, auto for seq >= 8k. "
        "*Measured*: llama3.2-3b prefill memory term 4.83 s -> see final "
        "table (order-of-magnitude drop); applies to all prefill/train "
        "cells. **Confirmed.**",
        "",
        "Stopping rule: further candidates (remat policy tuning, logits "
        "reduce-scatter, bf16 update gather) napkin-mathed under 5% of "
        "the dominant term for these cells.",
    ])


def main():
    doc = "\n\n".join([
        HEADER,
        DATASETS,
        paper_validation_section(),
        "## §Dry-run\n\n" + dryrun_section(),
        "## §Roofline\n\n"
        "Method: XLA cost_analysis counts while-loop bodies once, so "
        "per-period costs come from unrolled depth-1/2 compiles "
        "(`--unroll-periods`), extrapolated to full depth; inner "
        "recurrent scans (mamba/sLSTM/mLSTM) get closed-form "
        "corrections. Terms are per-chip seconds.\n\n" + roofline_section(),
        perf_section(),
    ])
    (REPO / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote {REPO / 'EXPERIMENTS.md'} ({len(doc)} chars)")


if __name__ == "__main__":
    main()
