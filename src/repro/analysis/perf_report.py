"""§Perf report: baseline vs hillclimbed policy for the three chosen
cells, from the A/B dry-run records."""
from __future__ import annotations

import json
import pathlib

from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, _coll_bytes,
                                     _load, extrapolate_cell)

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"

CELLS = [
    ("qwen2.5-32b", "decode_32k",
     "hillclimb #1: TP-only serving weights (no per-step weight gather)"),
    ("llama3.2-3b", "prefill_32k",
     "hillclimb #2: context-parallel prefill (heads % TP != 0)"),
    ("llama4-maverick-400b-a17b", "train_4k",
     "hillclimb #3: shard_map expert-parallel MoE dispatch"),
]


def _terms(arch, shape, tag):
    """Roofline terms for a record set (full + u1/u2 with given tag)."""
    full = _load(arch, shape, "16x16", tag)
    u1 = _load(arch, shape, "16x16", "u1" + tag)
    u2 = _load(arch, shape, "16x16", "u2" + tag)
    if not full or full.get("status") != "ok":
        return None
    from repro.configs import get_config
    cfg = get_config(arch)
    n_periods = cfg.n_periods
    if u1 and u2 and u1["status"] == u2["status"] == "ok":
        bf = max(u2["flops"] - u1["flops"], 0.0)
        bb = max(u2["bytes_accessed"] - u1["bytes_accessed"], 0.0)
        bc = max(_coll_bytes(u2) - _coll_bytes(u1), 0.0)
        flops = max(u1["flops"] - bf, 0.0) + n_periods * bf
        nbytes = max(u1["bytes_accessed"] - bb, 0.0) + n_periods * bb
        coll = max(_coll_bytes(u1) - bc, 0.0) + n_periods * bc
    else:
        flops, nbytes, coll = (full["flops"], full["bytes_accessed"],
                               _coll_bytes(full))
    if tag == "":
        # optimized records use chunked attention + other inner scans —
        # apply the same closed-form once-counted-body corrections as the
        # roofline table (baseline records predate iteration 5: dense
        # attention, fully counted by u1/u2).
        from repro.analysis.roofline import inner_scan_correction
        from repro.configs.shapes import get_shape
        corr = inner_scan_correction(cfg, get_shape(shape), 256)
        flops += corr["flops"]
        nbytes += corr["bytes"]
    return {"flops": flops, "bytes": nbytes, "coll": coll,
            "t_compute": flops / PEAK_FLOPS, "t_memory": nbytes / HBM_BW,
            "t_collective": coll / ICI_BW,
            "mem": full.get("memory", {})}


def obs_scorecard() -> str:
    """Serving-observability scorecard from the latest ``obs_engine``
    run (results/BENCH_obs.json, falling back to the smoke file), or
    "" when neither exists.  Rendering lives in ``repro.obs.export``;
    this is just the report glue."""
    for name in ("BENCH_obs.json", "BENCH_obs_smoke.json"):
        path = RESULTS / name
        if path.exists():
            break
    else:
        return ""
    from repro.obs.export import scorecard_markdown
    bench = json.loads(path.read_text())
    title = f"Serving observability scorecard ({name})"
    return scorecard_markdown(bench.get("meta", {}),
                              bench.get("per_tenant", {}),
                              bench.get("calibration"), title=title)


def report() -> str:
    lines = ["| cell | policy | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
             "dominant | bound (ms) |",
             "|---|---|---|---|---|---|---|"]
    summary = {}
    for arch, shape, desc in CELLS:
        for tag, name in (("__pbase", "baseline"), ("", "optimized")):
            t = _terms(arch, shape, tag)
            if t is None:
                lines.append(f"| {arch}/{shape} | {name} | (missing) | | | | |")
                continue
            terms = {"compute": t["t_compute"], "memory": t["t_memory"],
                     "collective": t["t_collective"]}
            dom = max(terms, key=terms.get)
            lines.append(
                f"| {arch}/{shape} | {name} | {t['t_compute']*1e3:.2f} | "
                f"{t['t_memory']*1e3:.2f} | {t['t_collective']*1e3:.2f} | "
                f"{dom} | {max(terms.values())*1e3:.2f} |")
            summary.setdefault(f"{arch}/{shape}", {})[name] = {
                **{k: v for k, v in t.items() if k != "mem"},
                "dominant": dom, "bound_s": max(terms.values())}
    (RESULTS / "perf_report.json").write_text(json.dumps(summary, indent=1))
    for cell, d in summary.items():
        if "baseline" in d and "optimized" in d:
            sp = d["baseline"]["bound_s"] / max(d["optimized"]["bound_s"],
                                                1e-12)
            lines.append(f"\n**{cell}**: step-bound "
                         f"{d['baseline']['bound_s']*1e3:.1f} ms -> "
                         f"{d['optimized']['bound_s']*1e3:.1f} ms "
                         f"(x{sp:.1f})")
    card = obs_scorecard()
    if card:
        lines.append("\n" + card)
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
