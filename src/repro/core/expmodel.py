"""Generalized exponential throughput model (paper Alg 1).

    thpt(bb) = c - a * exp(-b * bb)

a: initial-improvement magnitude; b: saturation rate; c: saturation
throughput (asymptote).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def exp_model(bb, a, b, c):
    """Vectorized Alg 1; works for numpy or jnp inputs."""
    xp = jnp if isinstance(bb, jnp.ndarray) else np
    return c - a * xp.exp(-b * xp.asarray(bb, dtype=jnp.float32
                                          if xp is jnp else np.float64))


def initial_params(bb: np.ndarray, thpt: np.ndarray):
    """Percentile-based initialization (paper Alg 2, lines 6-14)."""
    if len(np.unique(bb)) > 1:
        t10, t90 = np.percentile(thpt, [10, 90])
        b10, b90 = np.percentile(bb, [10, 90])
        b90 = max(b90, b10 + 1e-3)
        a0 = max(t90 - t10, 1e-5)
        b0 = 1.0 / max(b90 - b10, 1e-5)
        c0 = max(t90, 1e-5)
    else:
        a0, b0, c0 = 1.0, 0.001, 0.0
    return np.array([a0, b0, c0], np.float64)
