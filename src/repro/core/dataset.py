"""Columnar benchmark dataset (DataFrame-lite; pandas unavailable offline).

Columns are numpy arrays of equal length.  Canonical workload columns are
``ii, oo, bb, thpt`` plus arbitrary configuration columns (model, acc,
acc_count, back, prec, mode, ...) used by the Alg 4 registry.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np


class Dataset:
    def __init__(self, columns: Dict[str, np.ndarray]):
        n = {len(v) for v in columns.values()}
        assert len(n) <= 1, f"ragged columns: { {k: len(v) for k, v in columns.items()} }"
        self.cols = {k: np.asarray(v) for k, v in columns.items()}

    def __len__(self):
        return 0 if not self.cols else len(next(iter(self.cols.values())))

    def __getitem__(self, key):
        if isinstance(key, str):
            return self.cols[key]
        return Dataset({k: v[key] for k, v in self.cols.items()})

    def filter(self, **conds) -> "Dataset":
        mask = np.ones(len(self), bool)
        for k, v in conds.items():
            mask &= (self.cols[k] == v)
        return self[mask]

    def mask(self, mask: np.ndarray) -> "Dataset":
        return self[mask]

    def concat(self, other: "Dataset") -> "Dataset":
        """Row-wise concatenation of two schema-compatible datasets.

        Both sides must carry exactly the same columns — a mismatch
        raises ``ValueError`` naming the offending columns instead of
        silently dropping data (columns only in ``other``) or dying in a
        bare ``KeyError`` (columns only in ``self``).  Dtype promotion
        is deterministic: if either side of a column is string-like
        (``U``/``S``/``O`` kinds) both sides are cast to ``str`` before
        concatenating; purely numeric columns follow numpy's standard
        promotion (e.g. int64 + float64 -> float64).
        """
        missing = sorted(set(self.cols) - set(other.cols))
        extra = sorted(set(other.cols) - set(self.cols))
        if missing or extra:
            parts = []
            if missing:
                parts.append(f"columns {missing} missing from other")
            if extra:
                parts.append(f"columns {extra} only in other")
            raise ValueError("concat schema mismatch: " + "; ".join(parts))
        out = {}
        for k, a in self.cols.items():
            b = other.cols[k]
            if (a.dtype.kind in "USO") != (b.dtype.kind in "USO"):
                a, b = a.astype(str), b.astype(str)
            out[k] = np.concatenate([a, b])
        return Dataset(out)

    def unique_combos(self, keys: Sequence[str]) -> List[Tuple]:
        arr = np.stack([self.cols[k].astype(str) for k in keys], axis=1)
        return [tuple(r) for r in np.unique(arr, axis=0)]

    @property
    def workload(self):
        return (self.cols["ii"].astype(np.float64),
                self.cols["oo"].astype(np.float64),
                self.cols["bb"].astype(np.float64),
                self.cols["thpt"].astype(np.float64))

    # -- persistence ---------------------------------------------------------
    def save(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        meta = {k: str(v.dtype) for k, v in self.cols.items()}
        np.savez_compressed(path.with_suffix(".npz"),
                            **{k: v for k, v in self.cols.items()})
        path.with_suffix(".meta.json").write_text(json.dumps(meta))

    @classmethod
    def load(cls, path) -> "Dataset":
        path = pathlib.Path(path)
        data = np.load(path.with_suffix(".npz"), allow_pickle=False)
        return cls({k: data[k] for k in data.files})

    def to_csv(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        keys = list(self.cols)
        with open(path, "w") as f:
            f.write(",".join(keys) + "\n")
            for i in range(len(self)):
                f.write(",".join(str(self.cols[k][i]) for k in keys) + "\n")

    @classmethod
    def from_rows(cls, rows: Iterable[Dict],
                  require_finite: Tuple[str, ...] = ("ii", "oo", "bb",
                                                     "thpt")) -> "Dataset":
        rows = list(rows)
        if not rows:
            raise ValueError("from_rows needs at least one row (the "
                             "column schema comes from the first row)")
        keys = list(rows[0].keys())
        keyset = set(keys)
        for i, r in enumerate(rows):
            rk = set(r.keys())
            if rk != keyset:
                missing = sorted(keyset - rk)
                extra = sorted(rk - keyset)
                parts = []
                if missing:
                    parts.append(f"missing keys {missing}")
                if extra:
                    parts.append(f"unexpected keys {extra}")
                raise ValueError(f"from_rows: row {i} does not match the "
                                 f"row-0 schema: " + ", ".join(parts))
        cols = {k: np.asarray([r[k] for r in rows]) for k in keys}
        # a single NaN/inf workload value silently poisons every fit the
        # dataset feeds — refuse them at the door (opt out with
        # require_finite=None when building deliberately-corrupted data)
        for k in (require_finite or ()):
            v = cols.get(k)
            if v is None or v.dtype.kind not in "fiu":
                continue
            bad = ~np.isfinite(v.astype(np.float64))
            if bad.any():
                first = int(np.nonzero(bad)[0][0])
                raise ValueError(
                    f"from_rows: column {k!r} has {int(bad.sum())} "
                    f"non-finite value(s) (first at row {first}); drop or "
                    f"repair these rows, or pass require_finite=None to "
                    f"build a deliberately-corrupted dataset")
        return cls(cols)
