"""Baselines from the paper's Fig 7: Linear Regression, Vanilla XGBoost
(our GBT with stock hyperparameters), Random Forest, Gradient Boosting.

All regress thpt directly from raw (ii, oo, bb) — no analytical model.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.gbt import (GBTRegressor, LinearRegression,
                            RandomForestRegressor)


def _stack(ii, oo, bb) -> np.ndarray:
    return np.stack([np.asarray(ii, np.float64),
                     np.asarray(oo, np.float64),
                     np.asarray(bb, np.float64)], axis=1)


class BaselineModel:
    def __init__(self, name: str, factory: Callable):
        self.name = name
        self.factory = factory
        self.model = None

    def fit(self, ii, oo, bb, thpt):
        self.model = self.factory()
        self.model.fit(_stack(ii, oo, bb), np.asarray(thpt, np.float64))
        return self

    def predict(self, ii, oo, bb) -> np.ndarray:
        return self.model.predict(_stack(ii, oo, bb))


def make_baselines() -> Dict[str, BaselineModel]:
    return {
        "linear_regression": BaselineModel(
            "linear_regression", LinearRegression),
        "vanilla_xgboost": BaselineModel(
            "vanilla_xgboost",
            lambda: GBTRegressor(n_estimators=100, learning_rate=0.3,
                                 max_depth=6)),
        "random_forest": BaselineModel(
            "random_forest",
            lambda: RandomForestRegressor(n_estimators=60, max_depth=8)),
        "gradient_boosting": BaselineModel(
            "gradient_boosting",
            lambda: GBTRegressor(n_estimators=100, learning_rate=0.1,
                                 max_depth=3)),
    }
