"""ALA orchestrator: the paper's full pipeline as one object.

    fit          -> Alg 2 (exp database) + Alg 3 (param predictor)
    predict      -> Alg 5
    explore      -> Alg 6 (simulated annealing over training subsets)
    fit_error    -> Alg 7 (error predictor on SA logs)
    estimate     -> Alg 8 (predicted error + histogram-cosine confidence)
    estimate_batch -> Alg 7+8 over many query workloads in one shot
                      (jitted PackedForest + SubsetBank distance kernel)

``Registry``-level (Alg 4) training over hardware/software combinations
lives in repro.core.registry; this class operates within one combination.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import annealing
from repro.core.annealing import SAConfig, SALog, Subset, median_ape
from repro.core.database import ExpDatabase, build_exponential_database
from repro.core.error_predictor import predict_error, train_error_predictor
from repro.core.gbt import GBTRegressor, MultiOutputGBT
from repro.core.predictor import predict_throughput, train_param_predictor
from repro.core import uncertainty
from repro.core.uncertainty import (SubsetBank, bank_confidence,
                                    build_subset_bank)


@dataclasses.dataclass
class ALAConfig:
    gbt_kw: dict = dataclasses.field(default_factory=lambda: dict(
        n_estimators=150, learning_rate=0.08, max_depth=4))
    sa: SAConfig = dataclasses.field(default_factory=SAConfig)


class ALA:
    def __init__(self, cfg: Optional[ALAConfig] = None):
        self.cfg = cfg or ALAConfig()
        self.db: Optional[ExpDatabase] = None
        self.predictor: Optional[MultiOutputGBT] = None
        self.sa_log: Optional[SALog] = None
        self.error_model: Optional[GBTRegressor] = None
        self._train = None
        self._bank: Optional[SubsetBank] = None
        self._bank_subsets: Optional[int] = None
        self.timings: Dict[str, float] = {}

    # -- Alg 2 + Alg 3 -------------------------------------------------------
    def fit(self, ii, oo, bb, thpt) -> "ALA":
        t0 = time.perf_counter()
        self._train = (np.asarray(ii, np.float64), np.asarray(oo, np.float64),
                       np.asarray(bb, np.float64), np.asarray(thpt, np.float64))
        self._bank = None                      # new train -> stale bank
        self.db = build_exponential_database(*self._train)
        t1 = time.perf_counter()
        self.predictor = (train_param_predictor(self.db.training,
                                                **self.cfg.gbt_kw)
                          if self.db is not None and len(self.db.training) >= 4
                          else None)
        t2 = time.perf_counter()
        self.timings.update(fit_db_s=t1 - t0, fit_predictor_s=t2 - t1)
        return self

    # -- Alg 5 ----------------------------------------------------------------
    def predict(self, ii, oo, bb) -> np.ndarray:
        return predict_throughput(self.db, self.predictor, ii, oo, bb)

    def score(self, ii, oo, bb, thpt) -> float:
        return median_ape(np.asarray(thpt, np.float64),
                          self.predict(ii, oo, bb))

    # -- Alg 6 ----------------------------------------------------------------
    def explore(self, test, initial: Optional[Subset] = None,
                on_iter=None, n_chains: Optional[int] = None) -> SALog:
        """Alg 6.  ``n_chains > 1`` (argument or ``cfg.sa.n_chains``)
        routes through the batched K-chain engine with its shared
        evaluation cache; the default stays on the serial loop."""
        assert self._train is not None, "fit() first"
        t0 = time.perf_counter()
        k = self.cfg.sa.n_chains if n_chains is None else n_chains
        if k > 1:
            cfg = dataclasses.replace(self.cfg.sa, n_chains=k)
            self.sa_log = annealing.anneal_batched(
                self._train, test, cfg, initial=initial, on_iter=on_iter)
        else:
            self.sa_log = annealing.anneal(self._train, test, self.cfg.sa,
                                           initial=initial, on_iter=on_iter)
        self._bank = None                      # new log -> stale bank
        self.timings["explore_s"] = time.perf_counter() - t0
        return self.sa_log

    # -- Alg 7 ----------------------------------------------------------------
    def fit_error(self, **gbt_kw) -> GBTRegressor:
        assert self.sa_log is not None, "explore() first"
        t0 = time.perf_counter()
        self.error_model = train_error_predictor(self.sa_log, **gbt_kw)
        self.timings["fit_error_s"] = time.perf_counter() - t0
        return self.error_model

    # -- Alg 8 ----------------------------------------------------------------
    def bank(self, max_subsets: Optional[int] = None) -> SubsetBank:
        """The SA log materialized for batched Alg 8 (built lazily after
        ``explore()``, cached until the log changes).

        ``max_subsets=None`` reuses whatever bank is cached (building
        one over the trailing ``DEFAULT_MAX_SUBSETS`` window — the same
        cap the serial ``confidence`` applies — if none is); an explicit
        value rebuilds when the cached bank used a different window."""
        assert self.sa_log is not None, "explore() first"
        if self._bank is None or (max_subsets is not None
                                  and self._bank_subsets != max_subsets):
            self._bank_subsets = (uncertainty.DEFAULT_MAX_SUBSETS
                                  if max_subsets is None else max_subsets)
            self._bank = build_subset_bank(self._train, self.sa_log,
                                           max_subsets=self._bank_subsets)
        return self._bank

    def _fill_thpt(self, q) -> Tuple[np.ndarray, ...]:
        """Replace non-finite throughputs with ALA's own predictions —
        they only enter the confidence histogram when finite."""
        nii, noo, nbb, nthpt = (np.atleast_1d(np.asarray(v, np.float64))
                                for v in q)
        finite = np.isfinite(nthpt)
        if not finite.all():
            nthpt = nthpt.copy()
            nthpt[~finite] = self.predict(nii[~finite], noo[~finite],
                                          nbb[~finite])
        return nii, noo, nbb, nthpt

    def _signature(self, q) -> Subset:
        return {"ii": frozenset(np.unique(q[0]).tolist()),
                "oo": frozenset(np.unique(q[1]).tolist()),
                "bb": frozenset(np.unique(q[2]).tolist())}

    def estimate(self, new) -> Tuple[float, float]:
        """(predicted error %, confidence) for a new workload dataset.

        ``new`` is an (ii, oo, bb, thpt) tuple (thpt may be NaNs when
        unknown).  Runs the batch-of-one serial reference path; the
        batched JAX engine (``estimate_batch``) matches it to <= 1e-6.
        """
        err, _, conf = self.estimate_batch([new], backend="numpy")
        return float(err[0]), float(conf[0])

    def estimate_batch(self, queries: Sequence, backend: str = "jax"
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched Alg 7+8: (err, d_min, confidence) vectors, one entry
        per query workload.

        Each query is an (ii, oo, bb, thpt) tuple (ragged lengths fine;
        thpt may contain NaNs).  ``backend="jax"`` runs the whole batch
        through two jitted calls — encoded signatures through the
        ``PackedForest`` traversal and the fleet distance kernel over
        the ``SubsetBank``; ``backend="numpy"`` is the serial reference.
        Degenerate logs yield the (inf, 0.0) sentinel per query."""
        assert self.error_model is not None and self.sa_log is not None
        t0 = time.perf_counter()
        queries = [tuple(np.atleast_1d(np.asarray(v, np.float64))
                         for v in q) for q in queries]
        sigs = [self._signature(q) for q in queries]
        err = predict_error(self.error_model, sigs, self.sa_log.universes,
                            backend=backend) if sigs else np.zeros(0)
        filled = [self._fill_thpt(q) for q in queries]
        d_min, conf = bank_confidence(self.bank(), filled, backend=backend)
        self.timings["estimate_batch_s"] = time.perf_counter() - t0
        return np.asarray(err, np.float64), d_min, conf
