"""ALA orchestrator: the paper's full pipeline as one object.

    fit          -> Alg 2 (exp database) + Alg 3 (param predictor)
    predict      -> Alg 5
    explore      -> Alg 6 (simulated annealing over training subsets)
    fit_error    -> Alg 7 (error predictor on SA logs)
    estimate     -> Alg 8 (predicted error + histogram-cosine confidence)

``Registry``-level (Alg 4) training over hardware/software combinations
lives in repro.core.registry; this class operates within one combination.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import annealing
from repro.core.annealing import SAConfig, SALog, Subset, median_ape
from repro.core.database import ExpDatabase, build_exponential_database
from repro.core.error_predictor import (encode_subset, predict_error,
                                        train_error_predictor)
from repro.core.gbt import GBTRegressor, MultiOutputGBT
from repro.core.predictor import predict_throughput, train_param_predictor
from repro.core.uncertainty import confidence as _confidence


@dataclasses.dataclass
class ALAConfig:
    gbt_kw: dict = dataclasses.field(default_factory=lambda: dict(
        n_estimators=150, learning_rate=0.08, max_depth=4))
    sa: SAConfig = dataclasses.field(default_factory=SAConfig)


class ALA:
    def __init__(self, cfg: Optional[ALAConfig] = None):
        self.cfg = cfg or ALAConfig()
        self.db: Optional[ExpDatabase] = None
        self.predictor: Optional[MultiOutputGBT] = None
        self.sa_log: Optional[SALog] = None
        self.error_model: Optional[GBTRegressor] = None
        self._train = None
        self.timings: Dict[str, float] = {}

    # -- Alg 2 + Alg 3 -------------------------------------------------------
    def fit(self, ii, oo, bb, thpt) -> "ALA":
        t0 = time.perf_counter()
        self._train = (np.asarray(ii, np.float64), np.asarray(oo, np.float64),
                       np.asarray(bb, np.float64), np.asarray(thpt, np.float64))
        self.db = build_exponential_database(*self._train)
        t1 = time.perf_counter()
        self.predictor = (train_param_predictor(self.db.training,
                                                **self.cfg.gbt_kw)
                          if self.db is not None and len(self.db.training) >= 4
                          else None)
        t2 = time.perf_counter()
        self.timings.update(fit_db_s=t1 - t0, fit_predictor_s=t2 - t1)
        return self

    # -- Alg 5 ----------------------------------------------------------------
    def predict(self, ii, oo, bb) -> np.ndarray:
        return predict_throughput(self.db, self.predictor, ii, oo, bb)

    def score(self, ii, oo, bb, thpt) -> float:
        return median_ape(np.asarray(thpt, np.float64),
                          self.predict(ii, oo, bb))

    # -- Alg 6 ----------------------------------------------------------------
    def explore(self, test, initial: Optional[Subset] = None,
                on_iter=None, n_chains: Optional[int] = None) -> SALog:
        """Alg 6.  ``n_chains > 1`` (argument or ``cfg.sa.n_chains``)
        routes through the batched K-chain engine with its shared
        evaluation cache; the default stays on the serial loop."""
        assert self._train is not None, "fit() first"
        t0 = time.perf_counter()
        k = self.cfg.sa.n_chains if n_chains is None else n_chains
        if k > 1:
            cfg = dataclasses.replace(self.cfg.sa, n_chains=k)
            self.sa_log = annealing.anneal_batched(
                self._train, test, cfg, initial=initial, on_iter=on_iter)
        else:
            self.sa_log = annealing.anneal(self._train, test, self.cfg.sa,
                                           initial=initial, on_iter=on_iter)
        self.timings["explore_s"] = time.perf_counter() - t0
        return self.sa_log

    # -- Alg 7 ----------------------------------------------------------------
    def fit_error(self, **gbt_kw) -> GBTRegressor:
        assert self.sa_log is not None, "explore() first"
        t0 = time.perf_counter()
        self.error_model = train_error_predictor(self.sa_log, **gbt_kw)
        self.timings["fit_error_s"] = time.perf_counter() - t0
        return self.error_model

    # -- Alg 8 ----------------------------------------------------------------
    def estimate(self, new) -> Tuple[float, float]:
        """(predicted error %, confidence) for a new workload dataset.

        ``new`` is an (ii, oo, bb, thpt) tuple (thpt may be NaNs when
        unknown — it only enters the confidence histogram when finite)."""
        assert self.error_model is not None and self.sa_log is not None
        nii, noo, nbb, nthpt = (np.asarray(v, np.float64) for v in new)
        sig: Subset = {"ii": frozenset(np.unique(nii).tolist()),
                       "oo": frozenset(np.unique(noo).tolist()),
                       "bb": frozenset(np.unique(nbb).tolist())}
        err = float(predict_error(self.error_model, [sig],
                                  self.sa_log.universes)[0])
        finite = np.isfinite(nthpt)
        if not finite.all():
            # fill unknown thpt with ALA's own predictions for the histogram
            pred = self.predict(nii[~finite], noo[~finite], nbb[~finite])
            nthpt = nthpt.copy()
            nthpt[~finite] = pred
        _, conf = _confidence(self._train, self.sa_log,
                              (nii, noo, nbb, nthpt))
        return err, conf
