"""ALA orchestrator: the paper's full pipeline as one object.

    fit          -> Alg 2 (exp database) + Alg 3 (param predictor)
    predict      -> Alg 5
    explore      -> Alg 6 (simulated annealing over training subsets)
    fit_error    -> Alg 7 (error predictor on SA logs)
    estimate     -> Alg 8 (predicted error + histogram-cosine confidence)
    estimate_batch -> Alg 7+8 over many query workloads in one shot
                      (jitted PackedForest + SubsetBank distance kernel)

``Registry``-level (Alg 4) training over hardware/software combinations
lives in repro.core.registry; this class operates within one combination.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import annealing
from repro.core.annealing import SAConfig, SALog, Subset, median_ape
from repro.core.database import (ExpDatabase, build_exponential_database,
                                 update_exponential_database)
from repro.core.error_predictor import predict_error, train_error_predictor
from repro.core.gbt import GBTRegressor, MultiOutputGBT
from repro.core.predictor import predict_throughput, train_param_predictor
from repro.core import uncertainty
from repro.core.uncertainty import (SubsetBank, bank_confidence,
                                    build_subset_bank)


@dataclasses.dataclass
class ALAConfig:
    gbt_kw: dict = dataclasses.field(default_factory=lambda: dict(
        n_estimators=150, learning_rate=0.08, max_depth=4))
    sa: SAConfig = dataclasses.field(default_factory=SAConfig)


class ALA:
    def __init__(self, cfg: Optional[ALAConfig] = None):
        self.cfg = cfg or ALAConfig()
        self.db: Optional[ExpDatabase] = None
        self.predictor: Optional[MultiOutputGBT] = None
        self.sa_log: Optional[SALog] = None
        self.error_model: Optional[GBTRegressor] = None
        self._train = None
        self._bank: Optional[SubsetBank] = None
        self._bank_subsets: Optional[int] = None
        self.timings: Dict[str, float] = {}

    # -- Alg 2 + Alg 3 -------------------------------------------------------
    def fit(self, ii, oo, bb, thpt) -> "ALA":
        t0 = time.perf_counter()
        self._train = (np.asarray(ii, np.float64), np.asarray(oo, np.float64),
                       np.asarray(bb, np.float64), np.asarray(thpt, np.float64))
        self._bank = None                      # new train -> stale bank
        self.db = build_exponential_database(*self._train)
        t1 = time.perf_counter()
        self.predictor = (train_param_predictor(self.db.training,
                                                **self.cfg.gbt_kw)
                          if self.db is not None and len(self.db.training) >= 4
                          else None)
        t2 = time.perf_counter()
        self.timings.update(fit_db_s=t1 - t0, fit_predictor_s=t2 - t1)
        return self

    # -- Alg 5 ----------------------------------------------------------------
    def predict(self, ii, oo, bb) -> np.ndarray:
        return predict_throughput(self.db, self.predictor, ii, oo, bb)

    def score(self, ii, oo, bb, thpt) -> float:
        return median_ape(np.asarray(thpt, np.float64),
                          self.predict(ii, oo, bb))

    # -- Alg 6 ----------------------------------------------------------------
    def explore(self, test, initial: Optional[Subset] = None,
                on_iter=None, n_chains: Optional[int] = None) -> SALog:
        """Alg 6.  ``n_chains > 1`` (argument or ``cfg.sa.n_chains``)
        routes through the batched K-chain engine with its shared
        evaluation cache; the default stays on the serial loop."""
        assert self._train is not None, "fit() first"
        t0 = time.perf_counter()
        k = self.cfg.sa.n_chains if n_chains is None else n_chains
        if k > 1:
            cfg = dataclasses.replace(self.cfg.sa, n_chains=k)
            self.sa_log = annealing.anneal_batched(
                self._train, test, cfg, initial=initial, on_iter=on_iter)
        else:
            self.sa_log = annealing.anneal(self._train, test, self.cfg.sa,
                                           initial=initial, on_iter=on_iter)
        self._bank = None                      # new log -> stale bank
        self.timings["explore_s"] = time.perf_counter() - t0
        return self.sa_log

    # -- Alg 7 ----------------------------------------------------------------
    def fit_error(self, max_subsets: Optional[int] = None,
                  **gbt_kw) -> GBTRegressor:
        """Train the Alg 7 error predictor on the SA log.

        ``max_subsets`` trains on only the trailing window of the log —
        the online refit path uses the bank's window so the per-epoch
        cost stays bounded as merged logs grow across epochs."""
        assert self.sa_log is not None, "explore() first"
        t0 = time.perf_counter()
        log = self.sa_log
        if max_subsets is not None and len(log.subsets) > max_subsets:
            log = dataclasses.replace(log,
                                      subsets=log.subsets[-max_subsets:],
                                      errors=log.errors[-max_subsets:])
        self.error_model = train_error_predictor(log, **gbt_kw)
        self.timings["fit_error_s"] = time.perf_counter() - t0
        return self.error_model

    # -- Alg 8 ----------------------------------------------------------------
    def bank(self, max_subsets: Optional[int] = None) -> SubsetBank:
        """The SA log materialized for batched Alg 8 (built lazily after
        ``explore()``, cached until the log changes).

        ``max_subsets=None`` reuses whatever bank is cached (building
        one over the trailing ``DEFAULT_MAX_SUBSETS`` window — the same
        cap the serial ``confidence`` applies — if none is); an explicit
        value rebuilds when the cached bank used a different window."""
        assert self.sa_log is not None, "explore() first"
        if self._bank is None or (max_subsets is not None
                                  and self._bank_subsets != max_subsets):
            self._bank_subsets = (uncertainty.DEFAULT_MAX_SUBSETS
                                  if max_subsets is None else max_subsets)
            self._bank = build_subset_bank(self._train, self.sa_log,
                                           max_subsets=self._bank_subsets)
        return self._bank

    # -- online incremental refit --------------------------------------------
    def refit(self, train, test, n_iters: Optional[int] = None,
              n_chains: Optional[int] = None) -> SALog:
        """Incremental re-fit after the training data changed (typically
        rows appended by an online epoch — see ``repro.core.online``).

        When the new data is an append of the old (prefix-equal), every
        stage updates incrementally: the Alg 2 database re-solves only
        the delta-touched (ii, oo) groups
        (``update_exponential_database``), the SA chains warm start from
        the previous log's ``best_subset`` with a short budget
        (``n_iters``, default ``cfg.sa.n_iters``) and merge their
        proposals into the growing log, the Alg 7 error model retrains
        on the merged log, and the Alg 8 bank extends additively under
        the original fixed-bin contract (``uncertainty.extend_bank``).
        Non-appended data falls back to full rebuilds of the database
        and bank (the SA warm start still applies).
        """
        assert self.sa_log is not None, "fit() + explore() first"
        prev_train = self._train
        prev_log = self.sa_log
        prev_bank, prev_bank_subsets = self._bank, self._bank_subsets
        prev_best = prev_log.best_subset

        new_train = tuple(np.asarray(v, np.float64) for v in train)
        n_old = len(prev_train[0]) if prev_train is not None else -1
        appended = (prev_train is not None
                    and len(new_train[0]) >= n_old
                    and all(np.array_equal(p, c[:n_old])
                            for p, c in zip(prev_train, new_train)))
        if appended and self.db is not None:
            # Alg 2 incrementally: only delta-touched (ii, oo) groups
            # re-solve; untouched groups reuse their params verbatim
            t0 = time.perf_counter()
            self._train = new_train
            self._bank = None
            self.db = update_exponential_database(
                self.db, *new_train, n_delta=len(new_train[0]) - n_old)
            t1 = time.perf_counter()
            self.predictor = (train_param_predictor(self.db.training,
                                                    **self.cfg.gbt_kw)
                              if self.db is not None
                              and len(self.db.training) >= 4 else None)
            self.timings.update(fit_db_s=t1 - t0,
                                fit_predictor_s=time.perf_counter() - t1)
        else:
            self.fit(*train)
        t0 = time.perf_counter()
        cfg = self.cfg.sa
        k = cfg.n_chains if n_chains is None else n_chains
        cfg = dataclasses.replace(
            cfg, n_iters=cfg.n_iters if n_iters is None else n_iters,
            n_chains=k)
        if k > 1:
            new_log = annealing.anneal_batched(self._train, test, cfg,
                                               initial=prev_best)
        else:
            new_log = annealing.anneal(self._train, test, cfg,
                                       initial=prev_best)
        self.sa_log = annealing.merge_logs(prev_log, new_log)
        self.timings["refit_explore_s"] = time.perf_counter() - t0
        # trailing window keeps the per-epoch Alg 7 cost bounded as the
        # merged log grows (same window the bank reduces over)
        self.fit_error(max_subsets=prev_bank_subsets
                       or uncertainty.DEFAULT_MAX_SUBSETS)

        if prev_bank is not None and appended:
            t0 = time.perf_counter()
            self._bank_subsets = (prev_bank_subsets
                                  or uncertainty.DEFAULT_MAX_SUBSETS)
            self._bank = uncertainty.extend_bank(
                prev_bank, self._train, len(self._train[0]) - n_old,
                new_log.subsets, self.sa_log.universes,
                max_subsets=self._bank_subsets)
            self.timings["refit_bank_s"] = time.perf_counter() - t0
        # else: self.fit already cleared the bank -> lazy full rebuild
        return self.sa_log

    def _fill_thpt(self, q) -> Tuple[np.ndarray, ...]:
        """Replace non-finite throughputs with ALA's own predictions —
        they only enter the confidence histogram when finite."""
        nii, noo, nbb, nthpt = (np.atleast_1d(np.asarray(v, np.float64))
                                for v in q)
        finite = np.isfinite(nthpt)
        if not finite.all():
            nthpt = nthpt.copy()
            nthpt[~finite] = self.predict(nii[~finite], noo[~finite],
                                          nbb[~finite])
        return nii, noo, nbb, nthpt

    def _signature(self, q) -> Subset:
        return {"ii": frozenset(np.unique(q[0]).tolist()),
                "oo": frozenset(np.unique(q[1]).tolist()),
                "bb": frozenset(np.unique(q[2]).tolist())}

    def estimate(self, new, hw_dist: float = 0.0) -> Tuple[float, float]:
        """(predicted error %, confidence) for a new workload dataset.

        ``new`` is an (ii, oo, bb, thpt) tuple (thpt may be NaNs when
        unknown).  Runs the batch-of-one serial reference path; the
        batched JAX engine (``estimate_batch``) matches it to <= 1e-6.
        """
        err, _, conf = self.estimate_batch([new], backend="numpy",
                                           hw_dist=hw_dist)
        return float(err[0]), float(conf[0])

    def estimate_batch(self, queries: Sequence, backend: str = "jax",
                       hw_dist=0.0
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched Alg 7+8: (err, d_min, confidence) vectors, one entry
        per query workload.

        Each query is an (ii, oo, bb, thpt) tuple (ragged lengths fine;
        thpt may contain NaNs).  ``backend="jax"`` runs the whole batch
        through two jitted calls — encoded signatures through the
        ``PackedForest`` traversal and the fleet distance kernel over
        the ``SubsetBank``; ``backend="numpy"`` is the serial reference.
        Degenerate logs yield the (inf, 0.0) sentinel per query.

        ``hw_dist`` (scalar or per-query vector) is the descriptor
        distance of the hardware each query runs on from the hardware
        this fit was benchmarked on
        (``repro.perfmodel.hardware.hardware_distance``); it lowers the
        reported confidence for cross-hardware transfer while ``d_min``
        stays the pure workload distance."""
        assert self.error_model is not None and self.sa_log is not None
        t0 = time.perf_counter()
        queries = [tuple(np.atleast_1d(np.asarray(v, np.float64))
                         for v in q) for q in queries]
        sigs = [self._signature(q) for q in queries]
        err = predict_error(self.error_model, sigs, self.sa_log.universes,
                            backend=backend) if sigs else np.zeros(0)
        filled = [self._fill_thpt(q) for q in queries]
        d_min, conf = bank_confidence(self.bank(), filled, backend=backend,
                                      hw_dist=hw_dist)
        self.timings["estimate_batch_s"] = time.perf_counter() - t0
        return np.asarray(err, np.float64), d_min, conf
