"""Batched nonlinear least squares for the exponential model — pure JAX.

scipy is unavailable offline, so Alg 2's ``Optimize`` step is a
Levenberg–Marquardt solver written against jnp and *vmapped over
workload groups*: the hundreds of per-(ii,oo) fits execute as one XLA
call instead of a Python loop of scipy ``curve_fit``s — a beyond-paper
speedup measured in benchmarks/run.py.

Bounds (a, b >= 0; c >= 0) are enforced by projection after each LM step,
matching the paper's "bounded constraints" note.  Masked padding rows
make ragged groups rectangular.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_LM_ITERS = 60
_MU0 = 1e-2


def _residuals(theta, x, y, w):
    a, b, c = theta[0], theta[1], theta[2]
    pred = c - a * jnp.exp(-b * x)
    return (pred - y) * w


def _solve3(A, b):
    """Closed-form 3x3 solve (adjugate / Cramer).

    Elementwise arithmetic only, so — unlike ``jnp.linalg.solve``, whose
    batched LU kernel rounds differently for different batch sizes — the
    result for one system is bit-identical whatever else shares the
    vmapped batch.  That invariance is what lets the incremental
    database refit (``update_exponential_database``) reproduce the full
    fit exactly while solving only a subset of the groups.
    """
    c00 = A[1, 1] * A[2, 2] - A[1, 2] * A[2, 1]
    c01 = A[1, 2] * A[2, 0] - A[1, 0] * A[2, 2]
    c02 = A[1, 0] * A[2, 1] - A[1, 1] * A[2, 0]
    det = A[0, 0] * c00 + A[0, 1] * c01 + A[0, 2] * c02
    c10 = A[0, 2] * A[2, 1] - A[0, 1] * A[2, 2]
    c11 = A[0, 0] * A[2, 2] - A[0, 2] * A[2, 0]
    c12 = A[0, 1] * A[2, 0] - A[0, 0] * A[2, 1]
    c20 = A[0, 1] * A[1, 2] - A[0, 2] * A[1, 1]
    c21 = A[0, 2] * A[1, 0] - A[0, 0] * A[1, 2]
    c22 = A[0, 0] * A[1, 1] - A[0, 1] * A[1, 0]
    adj = jnp.array([[c00, c10, c20], [c01, c11, c21], [c02, c12, c22]])
    safe = jnp.where(det == 0, 1.0, det)
    return jnp.where(det == 0, jnp.zeros(3), (adj @ b) / safe)


def _lm_step(theta, mu, x, y, w):
    r = _residuals(theta, x, y, w)
    # analytic Jacobian of residuals wrt (a, b, c)
    a, b = theta[0], theta[1]
    e = jnp.exp(-b * x)
    J = jnp.stack([-e * w, a * x * e * w, jnp.ones_like(x) * w], axis=1)
    JtJ = J.T @ J
    Jtr = J.T @ r
    loss = jnp.sum(r * r)

    def solve(m):
        A = JtJ + m * jnp.eye(3, dtype=JtJ.dtype)
        return _solve3(A, -Jtr)

    delta = solve(mu)
    new_theta = theta + delta
    # projected bounds: a,b,c >= tiny (b also capped to avoid overflow)
    new_theta = jnp.stack([
        jnp.maximum(new_theta[0], 1e-8),
        jnp.clip(new_theta[1], 1e-8, 50.0),
        jnp.maximum(new_theta[2], 0.0)])
    new_loss = jnp.sum(_residuals(new_theta, x, y, w) ** 2)
    improved = new_loss < loss
    theta = jnp.where(improved, new_theta, theta)
    mu = jnp.where(improved, mu * 0.5, mu * 2.5)
    mu = jnp.clip(mu, 1e-10, 1e8)
    return theta, mu


@functools.partial(jax.jit, static_argnames=())
def _fit_one(theta0, x, y, w):
    def body(carry, _):
        theta, mu = carry
        theta, mu = _lm_step(theta, mu, x, y, w)
        return (theta, mu), None

    (theta, _), _ = jax.lax.scan(
        body, (theta0, jnp.asarray(_MU0, theta0.dtype)), None,
        length=_LM_ITERS)
    return theta


_fit_batch = jax.jit(jax.vmap(_fit_one))


def _pow2(n: int, lo: int = 1) -> int:
    """Next power of two >= n — the shape-bucketing the solvers use so
    growing online datasets reuse compiles instead of triggering a fresh
    XLA build every epoch."""
    return max(lo, 1 << max(int(n) - 1, 0).bit_length())


def fit_exponential_groups(groups, pad_to: int = 0):
    """Fit (a,b,c) for a list of (bb, thpt, theta0) ragged groups.

    Returns (G, 3) float64 array.  Groups are padded to the max length and
    solved in one vmapped LM call.

    Shapes are bucketed: the group dimension pads to the next power of
    two with all-zero dummy groups (bit-exact no-ops — the per-group
    solve is batch-invariant, see ``_solve3``) and the row dimension to
    the next power of two above ``max(group sizes, pad_to)``, so
    repeated fits over growing data hit the jit cache instead of
    recompiling.  ``pad_to`` additionally lets an incremental refit of a
    *subset* of groups (``update_exponential_database``) reproduce the
    full batch's row padding — and therefore its float32 reduction order
    — bit-for-bit.
    """
    if not groups:
        return np.zeros((0, 3))
    maxn = _pow2(max(max(len(g[0]) for g in groups), pad_to, 1))
    G = len(groups)
    Gp = _pow2(G, lo=2)     # lo=2: a batch of one fuses differently
    X = np.zeros((Gp, maxn), np.float32)
    Y = np.zeros((Gp, maxn), np.float32)
    W = np.zeros((Gp, maxn), np.float32)
    T0 = np.zeros((Gp, 3), np.float32)
    scale = np.zeros(G, np.float64)
    for i, (bb, thpt, theta0) in enumerate(groups):
        n = len(bb)
        # normalize thpt per group for conditioning; rescale after
        s = max(float(np.max(np.abs(thpt))), 1e-9)
        X[i, :n] = bb
        Y[i, :n] = np.asarray(thpt, np.float64) / s
        W[i, :n] = 1.0
        T0[i] = theta0 * np.array([1 / s, 1.0, 1 / s])
        scale[i] = s
    theta = np.asarray(_fit_batch(jnp.asarray(T0), jnp.asarray(X),
                                  jnp.asarray(Y), jnp.asarray(W)),
                       np.float64)[:G]
    theta[:, 0] *= scale
    theta[:, 2] *= scale
    return theta


def fit_exponential_masked(theta0, X, Y, W):
    """Fixed-shape batched LM: (G, maxn) rectangles with 0/1 row weights.

    The batched annealing engine calls this with the *same* (G, maxn)
    every evaluation — subset membership only flips weights — so the
    vmapped solver compiles exactly once per process, where the ragged
    ``fit_exponential_groups`` path recompiles for every new padded
    shape.  Zero-weight rows contribute nothing to the residuals (they
    are scaled by w inside the solver), and all-zero groups take no LM
    step (J = 0 => delta = 0), returning theta0 for the caller to mask.

    theta0: (G, 3); X/Y/W: (G, maxn).  Returns float64 (G, 3).

    Both dimensions bucket to powers of two (all-zero padding, exact
    no-ops) before the jitted solve, so SA evaluators over growing
    online datasets reuse the compiled kernel across epochs.
    """
    X = np.asarray(X, np.float64)
    Y = np.asarray(Y, np.float64)
    W = np.asarray(W, np.float64)
    G, maxn = X.shape
    s = np.maximum(np.max(np.abs(Y) * (W > 0), axis=1), 1e-9)
    T0 = np.asarray(theta0, np.float64) \
        * np.stack([1.0 / s, np.ones_like(s), 1.0 / s], axis=1)
    Gp, Mp = _pow2(G, lo=2), _pow2(maxn)
    T0p = np.zeros((Gp, 3), np.float32)
    Xp = np.zeros((Gp, Mp), np.float32)
    Yp = np.zeros((Gp, Mp), np.float32)
    Wp = np.zeros((Gp, Mp), np.float32)
    T0p[:G] = T0
    Xp[:G, :maxn] = X
    Yp[:G, :maxn] = Y / s[:, None]
    Wp[:G, :maxn] = W
    theta = np.asarray(_fit_batch(jnp.asarray(T0p), jnp.asarray(Xp),
                                  jnp.asarray(Yp), jnp.asarray(Wp)),
                       np.float64)[:G]
    theta[:, 0] *= s
    theta[:, 2] *= s
    return theta


def fit_exponential_numpy(bb, thpt, theta0, iters: int = 200):
    """Reference scalar LM in numpy (oracle for property tests)."""
    theta = np.asarray(theta0, np.float64).copy()
    mu = _MU0
    x = np.asarray(bb, np.float64)
    y = np.asarray(thpt, np.float64)
    s = max(float(np.max(np.abs(y))), 1e-9)
    y = y / s
    theta[0] /= s
    theta[2] /= s

    def resid(t):
        return (t[2] - t[0] * np.exp(-t[1] * x)) - y

    for _ in range(iters):
        r = resid(theta)
        e = np.exp(-theta[1] * x)
        J = np.stack([-e, theta[0] * x * e, np.ones_like(x)], axis=1)
        A = J.T @ J + mu * np.eye(3)
        delta = np.linalg.solve(A, -(J.T @ r))
        cand = theta + delta
        cand[0] = max(cand[0], 1e-8)
        cand[1] = min(max(cand[1], 1e-8), 50.0)
        cand[2] = max(cand[2], 0.0)
        if np.sum(resid(cand) ** 2) < np.sum(r ** 2):
            theta, mu = cand, mu * 0.5
        else:
            mu *= 2.5
        mu = float(np.clip(mu, 1e-10, 1e8))
    theta[0] *= s
    theta[2] *= s
    return theta
