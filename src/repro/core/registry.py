"""Per hardware/software-combination model registry (paper Alg 4).

One (ExpDatabase, parameter-predictor) pair per unique configuration
combination — e.g. (acc, acc_count, back, model, prec, mode).  The key
columns are configurable; combinations are discovered from the data.

Combination fits are independent, so ``fit`` runs them on a thread pool
(``n_workers``).  Results are collected per-combination and inserted in
sorted combo order, and each fit seeds its own RNG, so the registry is
deterministic regardless of worker count or completion order.

Fleet-scale uncertainty: ``fit_uncertainty`` runs the full Alg 6+7
pipeline per combination (its own train/eval split, SA log, error
predictor, ``SubsetBank``); ``estimate`` then answers Alg 8 for every
row of a dataset at once — rows group by combination and each group
dispatches as one batched query to its combination's bank.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.database import ExpDatabase, build_exponential_database
from repro.core.dataset import Dataset
from repro.core.gbt import MultiOutputGBT
from repro.core.predictor import predict_throughput, train_param_predictor

DEFAULT_KEYS = ("model", "acc", "acc_count", "back", "prec", "mode")


@dataclasses.dataclass
class ComboModel:
    db: Optional[ExpDatabase]
    predictor: Optional[MultiOutputGBT]
    # repro.core.ala.ALA after fit_uncertainty (imported lazily there —
    # plain Alg 4 use keeps registry free of the SA/uncertainty stack)
    ala: Optional[object] = None


class ModelRegistry:
    def __init__(self, keys: Sequence[str] = DEFAULT_KEYS,
                 n_workers: Optional[int] = None):
        self.keys = tuple(keys)
        self.combos: Dict[Tuple, ComboModel] = {}
        self.n_workers = n_workers

    @staticmethod
    def _fit_combo(args) -> ComboModel:
        workload, gbt_kw = args
        db = build_exponential_database(*workload)
        pred = (train_param_predictor(db.training, **gbt_kw)
                if db is not None and len(db.training) >= 4 else None)
        return ComboModel(db=db, predictor=pred)

    def fit(self, data: Dataset, **gbt_kw) -> "ModelRegistry":
        keys = [k for k in self.keys if k in data.cols]
        self._active_keys = tuple(keys)
        combos = sorted(data.unique_combos(keys))
        jobs = []
        for combo in combos:
            sub = data
            for k, v in zip(keys, combo):
                sub = sub.mask(sub[k].astype(str) == v)
            jobs.append((sub.workload, gbt_kw))
        workers = self.n_workers or min(8, max(1, (os.cpu_count() or 1)))
        if workers > 1 and len(jobs) > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                fitted = list(ex.map(self._fit_combo, jobs))
        else:
            fitted = [self._fit_combo(j) for j in jobs]
        # insertion in sorted combo order keeps iteration deterministic
        for combo, cm in zip(combos, fitted):
            self.combos[combo] = cm
        return self

    def _key_of(self, row: Dict) -> Tuple:
        return tuple(str(row[k]) for k in self._active_keys)

    def _combo_masks(self, data: Dataset):
        keys = self._active_keys
        arr = np.stack([data[k].astype(str) for k in keys], axis=1) \
            if keys else np.zeros((len(data), 0), str)
        for combo, cm in self.combos.items():
            mask = np.all(arr == np.asarray(combo), axis=1) if keys else \
                np.ones(len(data), bool)
            yield combo, cm, mask

    def predict(self, data: Dataset) -> np.ndarray:
        """Throughput prediction for every row (Alg 5 per combination)."""
        out = np.zeros(len(data), np.float64)
        ii, oo, bb, _ = data.workload
        for combo, cm, mask in self._combo_masks(data):
            if not mask.any():
                continue
            out[mask] = predict_throughput(cm.db, cm.predictor,
                                           ii[mask], oo[mask], bb[mask])
        return out

    # -- Alg 6+7 per combination, Alg 8 over whole datasets ------------------
    def fit_uncertainty(self, data: Dataset, test_frac: float = 0.3,
                        seed: int = 0, sa_cfg=None,
                        **gbt_kw) -> "ModelRegistry":
        """Run the uncertainty pipeline for every fitted combination.

        Each combination's rows split deterministically into an SA
        train/eval pair; the resulting ALA carries the SA log, the Alg 7
        error model, and the Alg 8 ``SubsetBank``.  Must follow
        ``fit``; combinations with too few rows to split are skipped
        (their rows estimate to the degenerate sentinel).
        """
        from repro.core.ala import ALA, ALAConfig

        assert self.combos, "fit() first"
        for ci, (combo, cm, mask) in enumerate(self._combo_masks(data)):
            sub = data.mask(mask)
            if len(sub) < 8:
                continue
            # combos iterate in sorted order, so index-seeded RNGs are
            # deterministic across processes (tuple hash is not)
            rng = np.random.default_rng(seed + 7919 * (ci + 1))
            te = rng.random(len(sub)) < test_frac
            if te.all() or (~te).sum() < 4 or te.sum() < 1:
                continue
            cfg = ALAConfig(gbt_kw=dict(gbt_kw) if gbt_kw else
                            ALAConfig().gbt_kw)
            if sa_cfg is not None:
                cfg.sa = sa_cfg
            ala = ALA(cfg).fit(*sub.mask(~te).workload)
            ala.explore(sub.mask(te).workload)
            ala.fit_error()
            ala.bank()
            self.combos[combo] = dataclasses.replace(cm, ala=ala)
        return self

    def estimate(self, data: Dataset, backend: str = "jax"
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched Alg 8 for every row: (err, d_min, confidence) arrays
        aligned to ``data``.

        Rows group by combination; each group is one query workload
        dispatched to that combination's ``SubsetBank`` through
        ``ALA.estimate_batch``.  Rows of unknown combinations — or of
        combinations without an uncertainty fit — get the explicit
        degenerate sentinel (nan, inf, 0.0).
        """
        n = len(data)
        err = np.full(n, np.nan)
        d_min = np.full(n, np.inf)
        conf = np.zeros(n)
        ii, oo, bb, thpt = data.workload
        for combo, cm, mask in self._combo_masks(data):
            if not mask.any() or getattr(cm, "ala", None) is None:
                continue
            q = (ii[mask], oo[mask], bb[mask], thpt[mask])
            e, d, c = cm.ala.estimate_batch([q], backend=backend)
            err[mask], d_min[mask], conf[mask] = e[0], d[0], c[0]
        return err, d_min, conf
