"""Per hardware/software-combination model registry (paper Alg 4).

One (ExpDatabase, parameter-predictor) pair per unique configuration
combination — e.g. (acc, acc_count, back, model, prec, mode).  The key
columns are configurable; combinations are discovered from the data.

Combination fits are independent, so ``fit`` runs them on a thread pool
(``n_workers``).  Results are collected per-combination and inserted in
sorted combo order, and each fit seeds its own RNG, so the registry is
deterministic regardless of worker count or completion order.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.database import ExpDatabase, build_exponential_database
from repro.core.dataset import Dataset
from repro.core.gbt import MultiOutputGBT
from repro.core.predictor import predict_throughput, train_param_predictor

DEFAULT_KEYS = ("model", "acc", "acc_count", "back", "prec", "mode")


@dataclasses.dataclass
class ComboModel:
    db: Optional[ExpDatabase]
    predictor: Optional[MultiOutputGBT]


class ModelRegistry:
    def __init__(self, keys: Sequence[str] = DEFAULT_KEYS,
                 n_workers: Optional[int] = None):
        self.keys = tuple(keys)
        self.combos: Dict[Tuple, ComboModel] = {}
        self.n_workers = n_workers

    @staticmethod
    def _fit_combo(args) -> ComboModel:
        workload, gbt_kw = args
        db = build_exponential_database(*workload)
        pred = (train_param_predictor(db.training, **gbt_kw)
                if db is not None and len(db.training) >= 4 else None)
        return ComboModel(db=db, predictor=pred)

    def fit(self, data: Dataset, **gbt_kw) -> "ModelRegistry":
        keys = [k for k in self.keys if k in data.cols]
        self._active_keys = tuple(keys)
        combos = sorted(data.unique_combos(keys))
        jobs = []
        for combo in combos:
            sub = data
            for k, v in zip(keys, combo):
                sub = sub.mask(sub[k].astype(str) == v)
            jobs.append((sub.workload, gbt_kw))
        workers = self.n_workers or min(8, max(1, (os.cpu_count() or 1)))
        if workers > 1 and len(jobs) > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                fitted = list(ex.map(self._fit_combo, jobs))
        else:
            fitted = [self._fit_combo(j) for j in jobs]
        # insertion in sorted combo order keeps iteration deterministic
        for combo, cm in zip(combos, fitted):
            self.combos[combo] = cm
        return self

    def _key_of(self, row: Dict) -> Tuple:
        return tuple(str(row[k]) for k in self._active_keys)

    def predict(self, data: Dataset) -> np.ndarray:
        """Throughput prediction for every row (Alg 5 per combination)."""
        keys = self._active_keys
        out = np.zeros(len(data), np.float64)
        arr = np.stack([data[k].astype(str) for k in keys], axis=1) \
            if keys else np.zeros((len(data), 0), str)
        ii, oo, bb, _ = data.workload
        for combo, cm in self.combos.items():
            mask = np.all(arr == np.asarray(combo), axis=1) if keys else \
                np.ones(len(data), bool)
            if not mask.any():
                continue
            out[mask] = predict_throughput(cm.db, cm.predictor,
                                           ii[mask], oo[mask], bb[mask])
        return out
