"""Per hardware/software-combination model registry (paper Alg 4).

One (ExpDatabase, parameter-predictor) pair per unique configuration
combination — e.g. (acc, acc_count, back, model, prec, mode).  The key
columns are configurable; combinations are discovered from the data.

Combination fits are independent, so ``fit`` runs them on a thread pool
(``n_workers``).  Results are collected per-combination and inserted in
sorted combo order, and each fit seeds its own RNG, so the registry is
deterministic regardless of worker count or completion order.

Fleet-scale uncertainty: ``fit_uncertainty`` runs the full Alg 6+7
pipeline per combination (its own train/eval split, SA log, error
predictor, ``SubsetBank``); ``estimate`` then answers Alg 8 for every
row of a dataset at once — rows group by combination and each group
dispatches as one batched query to its combination's bank.
"""
from __future__ import annotations

import dataclasses
import os
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.database import (ExpDatabase, build_exponential_database,
                                 update_exponential_database)
from repro.core.dataset import Dataset
from repro.core.gbt import MultiOutputGBT
from repro.core.predictor import predict_throughput, train_param_predictor

DEFAULT_KEYS = ("model", "acc", "acc_count", "back", "prec", "mode")


@dataclasses.dataclass
class ComboModel:
    db: Optional[ExpDatabase]
    predictor: Optional[MultiOutputGBT]
    # repro.core.ala.ALA after fit_uncertainty (imported lazily there —
    # plain Alg 4 use keeps registry free of the SA/uncertainty stack)
    ala: Optional[object] = None


class ModelRegistry:
    def __init__(self, keys: Sequence[str] = DEFAULT_KEYS,
                 n_workers: Optional[int] = None):
        self.keys = tuple(keys)
        self.combos: Dict[Tuple, ComboModel] = {}
        self.n_workers = n_workers

    @staticmethod
    def _fit_combo(args) -> ComboModel:
        workload, gbt_kw = args
        db = build_exponential_database(*workload)
        pred = (train_param_predictor(db.training, **gbt_kw)
                if db is not None and len(db.training) >= 4 else None)
        return ComboModel(db=db, predictor=pred)

    def _fit_combos(self, data: Dataset, combos, keys, gbt_kw) -> None:
        jobs = []
        for combo in combos:
            sub = data
            for k, v in zip(keys, combo):
                sub = sub.mask(sub[k].astype(str) == v)
            if len(sub) == 0:
                raise ValueError(f"no rows for combination {combo!r} in "
                                 "the given dataset")
            jobs.append((sub.workload, gbt_kw))
        workers = self.n_workers or min(8, max(1, (os.cpu_count() or 1)))
        if workers > 1 and len(jobs) > 1:
            with ThreadPoolExecutor(max_workers=workers) as ex:
                fitted = list(ex.map(self._fit_combo, jobs))
        else:
            fitted = [self._fit_combo(j) for j in jobs]
        # insertion in sorted combo order keeps iteration deterministic
        for combo, cm in zip(combos, fitted):
            self.combos[combo] = cm

    def fit(self, data: Dataset, **gbt_kw) -> "ModelRegistry":
        """Full Alg 4 fit.  Always starts from a clean slate: any state
        from a previous ``fit`` — including combinations absent from the
        new data and their stale ``ala`` uncertainty fits — is dropped,
        so ``predict``/``estimate`` never silently serve models trained
        on data this registry no longer represents.  Use ``refit`` to
        update a subset of combinations in place."""
        self.combos = {}
        keys = [k for k in self.keys if k in data.cols]
        self._active_keys = tuple(keys)
        self._fit_combos(data, sorted(data.unique_combos(keys)), keys,
                         gbt_kw)
        return self

    def refit(self, data: Dataset, combos: Optional[Sequence[Tuple]] = None,
              **gbt_kw) -> "ModelRegistry":
        """Incremental Alg 4: (re)fit only the given combinations,
        leaving every other fitted combination untouched.

        ``data`` must contain the *full* accumulated rows for each
        target combination (an exponential fit is not additive, so a
        changed combination rebuilds from all of its rows — the
        incrementality is across combinations).  ``combos=None`` targets
        every combination present in ``data``.  A refitted combination's
        ``ala`` uncertainty fit is dropped — its data changed, so the
        old SA log / error model / bank no longer describe it; callers
        running the online pipeline re-attach a fresh one via
        ``attach_ala`` (see ``repro.core.online.OnlineALA``).
        """
        keys = [k for k in self.keys if k in data.cols]
        if self.combos and tuple(keys) != self._active_keys:
            raise ValueError(f"refit key columns {tuple(keys)} != the "
                             f"fitted registry's {self._active_keys}")
        self._active_keys = tuple(keys)
        present = sorted(data.unique_combos(keys))
        if combos is None:
            targets = present
        else:
            targets = sorted(tuple(str(v) for v in c) for c in combos)
            present_set = set(present)
            unknown = [c for c in targets if c not in present_set]
            if unknown:
                raise ValueError(f"refit: no rows in data for "
                                 f"combinations {unknown}")
        self._fit_combos(data, targets, keys, gbt_kw)
        return self

    def update_combo(self, combo: Tuple, workload, n_delta: int,
                     **gbt_kw) -> None:
        """Append-only incremental update of one fitted combination.

        ``workload`` is the combination's *full* (ii, oo, bb, thpt) with
        its last ``n_delta`` rows newly appended.  Only the (ii, oo)
        groups the delta touches re-solve (``update_exponential_database``
        — untouched group params are reused verbatim); the Alg 3
        predictor retrains on the updated training table.  The stale
        ``ala`` is dropped, same contract as ``refit``."""
        combo = tuple(str(v) for v in combo)
        cm = self.combos.get(combo)
        if cm is None:
            raise KeyError(f"unknown combination {combo!r}; "
                           "fit()/refit() it first")
        db = update_exponential_database(cm.db, *workload, n_delta=n_delta)
        pred = (train_param_predictor(db.training, **gbt_kw)
                if db is not None and len(db.training) >= 4 else None)
        self.combos[combo] = ComboModel(db=db, predictor=pred)

    def attach_ala(self, combo: Tuple, ala) -> None:
        """Bind an uncertainty fit to an already-fitted combination so
        ``estimate`` serves it (the online engine's re-attachment hook)."""
        combo = tuple(str(v) for v in combo)
        cm = self.combos.get(combo)
        if cm is None:
            raise KeyError(f"unknown combination {combo!r}; "
                           "fit()/refit() it first")
        self.combos[combo] = dataclasses.replace(cm, ala=ala)

    def _key_of(self, row: Dict) -> Tuple:
        return tuple(str(row[k]) for k in self._active_keys)

    def _combo_masks(self, data: Dataset):
        keys = self._active_keys
        arr = np.stack([data[k].astype(str) for k in keys], axis=1) \
            if keys else np.zeros((len(data), 0), str)
        for combo, cm in self.combos.items():
            mask = np.all(arr == np.asarray(combo), axis=1) if keys else \
                np.ones(len(data), bool)
            yield combo, cm, mask

    def predict(self, data: Dataset, transfer: bool = False,
                scale_fn=None) -> np.ndarray:
        """Throughput prediction for every row (Alg 5 per combination).

        ``transfer=True`` extends coverage to rows of *unfitted* hardware
        (paper RQ4): a row whose combination differs from a fitted one
        only in the hardware key borrows that donor's predictor.
        ``scale_fn(query_combo, donor_combo, ii, oo, bb)`` optionally
        rescales the donor prediction — the analytic roofline ratio from
        ``repro.perfmodel.simulator.throughput_batch`` is the intended
        scaler (hardware-agnostic analytical transfer); without it the
        donor prediction is served raw."""
        out = np.zeros(len(data), np.float64)
        ii, oo, bb, _ = data.workload
        for combo, cm, mask in self._combo_masks(data):
            if not mask.any():
                continue
            out[mask] = predict_throughput(cm.db, cm.predictor,
                                           ii[mask], oo[mask], bb[mask])
        if transfer:
            for combo, donor, mask in self._transfer_groups(data):
                cm = self.combos[donor]
                pred = predict_throughput(cm.db, cm.predictor,
                                          ii[mask], oo[mask], bb[mask])
                if scale_fn is not None:
                    pred = pred * scale_fn(combo, donor,
                                           ii[mask], oo[mask], bb[mask])
                out[mask] = pred
        return out

    # -- cross-hardware transfer (paper RQ4) ---------------------------------
    def _hw_key_index(self, key: str = "acc") -> Optional[int]:
        keys = getattr(self, "_active_keys", ())
        return keys.index(key) if key in keys else None

    def donor_for(self, combo: Tuple, need_ala: bool = False,
                  hw_key: str = "acc") -> Optional[Tuple]:
        """The fitted combination this (unfitted) one can borrow from: a
        combination matching on every key column *except* the hardware
        key, nearest by descriptor distance when several qualify.
        Returns None when the registry has no hardware key column or no
        candidate."""
        hi = self._hw_key_index(hw_key)
        if hi is None:
            return None
        combo = tuple(str(v) for v in combo)
        rest = combo[:hi] + combo[hi + 1:]
        best, best_d = None, np.inf
        for cand, cm in self.combos.items():
            if cand[:hi] + cand[hi + 1:] != rest or cand[hi] == combo[hi]:
                continue
            if need_ala and getattr(cm, "ala", None) is None:
                continue
            d = _hardware_distance(combo[hi], cand[hi])
            if d < best_d:
                best, best_d = cand, d
        return best

    def _transfer_groups(self, data: Dataset, need_ala: bool = False):
        """(query_combo, donor_combo, row_mask) for every combination in
        ``data`` that is not fitted (or lacks an uncertainty fit, with
        ``need_ala``) but has a transfer donor."""
        keys = getattr(self, "_active_keys", ())
        if not keys:
            return
        arr = np.stack([data[k].astype(str) for k in keys], axis=1)
        for combo in sorted(map(tuple, np.unique(arr, axis=0))):
            cm = self.combos.get(combo)
            if cm is not None and not (need_ala
                                       and getattr(cm, "ala", None) is None):
                continue
            donor = self.donor_for(combo, need_ala=need_ala)
            if donor is None:
                continue
            yield combo, donor, np.all(arr == np.asarray(combo), axis=1)

    # -- Alg 6+7 per combination, Alg 8 over whole datasets ------------------
    def fit_uncertainty(self, data: Dataset, test_frac: float = 0.3,
                        seed: int = 0, sa_cfg=None,
                        **gbt_kw) -> "ModelRegistry":
        """Run the uncertainty pipeline for every fitted combination.

        Each combination's rows split deterministically into an SA
        train/eval pair; the resulting ALA carries the SA log, the Alg 7
        error model, and the Alg 8 ``SubsetBank``.  Must follow
        ``fit``; combinations with too few rows to split are skipped
        (their rows estimate to the degenerate sentinel).
        """
        from repro.core.ala import ALA, ALAConfig

        assert self.combos, "fit() first"
        for ci, (combo, cm, mask) in enumerate(self._combo_masks(data)):
            sub = data.mask(mask)
            if len(sub) < 8:
                continue
            # combos iterate in sorted order, so index-seeded RNGs are
            # deterministic across processes (tuple hash is not)
            rng = np.random.default_rng(seed + 7919 * (ci + 1))
            te = rng.random(len(sub)) < test_frac
            if te.all() or (~te).sum() < 4 or te.sum() < 1:
                continue
            cfg = ALAConfig(gbt_kw=dict(gbt_kw) if gbt_kw else
                            ALAConfig().gbt_kw)
            if sa_cfg is not None:
                cfg.sa = sa_cfg
            ala = ALA(cfg).fit(*sub.mask(~te).workload)
            ala.explore(sub.mask(te).workload)
            ala.fit_error()
            ala.bank()
            self.combos[combo] = dataclasses.replace(cm, ala=ala)
        return self

    def estimate(self, data: Dataset, backend: str = "jax",
                 transfer: bool = False
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched Alg 8 for every row: (err, d_min, confidence) arrays
        aligned to ``data``.

        Rows group by combination; each group is one query workload
        dispatched to that combination's ``SubsetBank`` through
        ``ALA.estimate_batch``.  Rows of unknown combinations — or of
        combinations without an uncertainty fit — get the explicit
        degenerate sentinel (nan, inf, 0.0).

        ``transfer=True``: rows of unfitted hardware are answered by
        their transfer donor (``donor_for``) with the hardware-descriptor
        distance folded into the confidence — strictly below what the
        donor reports for the same workload on its own hardware, and
        the (inf, 0.0) sentinel when the hardware is unknown to
        ``repro.perfmodel.hardware.PROFILES``.
        """
        n = len(data)
        err = np.full(n, np.nan)
        d_min = np.full(n, np.inf)
        conf = np.zeros(n)
        ii, oo, bb, thpt = data.workload
        for combo, cm, mask in self._combo_masks(data):
            if not mask.any() or getattr(cm, "ala", None) is None:
                continue
            q = (ii[mask], oo[mask], bb[mask], thpt[mask])
            e, d, c = cm.ala.estimate_batch([q], backend=backend)
            err[mask], d_min[mask], conf[mask] = e[0], d[0], c[0]
        if transfer:
            hi = self._hw_key_index()
            for combo, donor, mask in self._transfer_groups(data,
                                                            need_ala=True):
                hw_d = _hardware_distance(combo[hi], donor[hi])
                if not np.isfinite(hw_d):
                    continue        # unknown hardware keeps the sentinel
                q = (ii[mask], oo[mask], bb[mask], thpt[mask])
                ala = self.combos[donor].ala
                e, d, c = ala.estimate_batch([q], backend=backend,
                                             hw_dist=hw_d)
                err[mask], d_min[mask], conf[mask] = e[0], d[0], c[0]
        return err, d_min, conf


def _hardware_distance(a: str, b: str) -> float:
    """Descriptor distance between two hardware names; inf when either
    is not a registered profile (transfer to unknown hardware must read
    as zero-confidence, never as a silent same-hardware answer)."""
    from repro.perfmodel.hardware import PROFILES, hardware_distance
    if a not in PROFILES or b not in PROFILES:
        return float("inf")
    return hardware_distance(a, b)
