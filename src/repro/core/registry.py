"""Per hardware/software-combination model registry (paper Alg 4).

One (ExpDatabase, parameter-predictor) pair per unique configuration
combination — e.g. (acc, acc_count, back, model, prec, mode).  The key
columns are configurable; combinations are discovered from the data.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.database import ExpDatabase, build_exponential_database
from repro.core.dataset import Dataset
from repro.core.gbt import MultiOutputGBT
from repro.core.predictor import predict_throughput, train_param_predictor

DEFAULT_KEYS = ("model", "acc", "acc_count", "back", "prec", "mode")


@dataclasses.dataclass
class ComboModel:
    db: Optional[ExpDatabase]
    predictor: Optional[MultiOutputGBT]


class ModelRegistry:
    def __init__(self, keys: Sequence[str] = DEFAULT_KEYS):
        self.keys = tuple(keys)
        self.combos: Dict[Tuple, ComboModel] = {}

    def fit(self, data: Dataset, **gbt_kw) -> "ModelRegistry":
        keys = [k for k in self.keys if k in data.cols]
        self._active_keys = tuple(keys)
        for combo in data.unique_combos(keys):
            sub = data
            for k, v in zip(keys, combo):
                sub = sub.mask(sub[k].astype(str) == v)
            ii, oo, bb, thpt = sub.workload
            db = build_exponential_database(ii, oo, bb, thpt)
            pred = (train_param_predictor(db.training, **gbt_kw)
                    if db is not None and len(db.training) >= 4 else None)
            self.combos[combo] = ComboModel(db=db, predictor=pred)
        return self

    def _key_of(self, row: Dict) -> Tuple:
        return tuple(str(row[k]) for k in self._active_keys)

    def predict(self, data: Dataset) -> np.ndarray:
        """Throughput prediction for every row (Alg 5 per combination)."""
        keys = self._active_keys
        out = np.zeros(len(data), np.float64)
        arr = np.stack([data[k].astype(str) for k in keys], axis=1) \
            if keys else np.zeros((len(data), 0), str)
        ii, oo, bb, _ = data.workload
        for combo, cm in self.combos.items():
            mask = np.all(arr == np.asarray(combo), axis=1) if keys else \
                np.ones(len(data), bool)
            if not mask.any():
                continue
            out[mask] = predict_throughput(cm.db, cm.predictor,
                                           ii[mask], oo[mask], bb[mask])
        return out
