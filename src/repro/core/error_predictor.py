"""Error predictor over SA logs (paper Alg 7).

Each logged subset is encoded as a binary membership vector over the
universal value sets (unique_ii | unique_bb | unique_oo, in the paper's
order); an XGBoost-style GBT regresses the observed median-APE.

``predict_error(..., backend="jax")`` routes a whole batch of encoded
signatures through the jitted ``PackedForest`` vmap/gather traversal in
one call — the path ``ALA.estimate_batch`` uses; the default numpy
backend stays the serial reference (same trees, same leaves, identical
up to float summation order).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.annealing import SALog, Subset
from repro.core.gbt import GBTRegressor


def encode_subset(subset: Subset, universes: Dict[str, np.ndarray]) -> np.ndarray:
    parts = []
    for dim in ("ii", "bb", "oo"):          # paper's Alg 7 ordering
        u = universes[dim]
        s = subset[dim]
        parts.append(np.isin(u, list(s)).astype(np.float64))
    return np.concatenate(parts)


def encode_subsets(subsets: List[Subset],
                   universes: Dict[str, np.ndarray]) -> np.ndarray:
    """(S, D) stacked membership matrix — the batched encoder."""
    return np.stack([encode_subset(s, universes) for s in subsets])


def train_error_predictor(log: SALog, **gbt_kw) -> GBTRegressor:
    X = encode_subsets(log.subsets, log.universes)
    y = np.asarray(log.errors, np.float64)
    kw = dict(n_estimators=200, learning_rate=0.05, max_depth=4, n_bins=4)
    kw.update(gbt_kw)
    model = GBTRegressor(**kw)
    model.fit(X, y)
    return model


def predict_error(model: GBTRegressor, subsets: List[Subset],
                  universes: Dict[str, np.ndarray],
                  backend: str = "numpy") -> np.ndarray:
    X = encode_subsets(subsets, universes)
    return model.predict(X, backend=backend)
