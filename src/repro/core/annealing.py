"""Simulated annealing over training-subset space (paper Alg 6).

State = which unique values of (ii, oo, bb) are included in the training
subset.  EvaluateSubset trains the full ALA pipeline (Alg 2 + Alg 3) on
the filtered rows and scores median percentage error on a held-out
evaluation set.  Every iteration logs (subset, error) — the raw material
for the error predictor (Alg 7) and the uncertainty metric (Alg 8).

Two engines share the ``SALog`` contract:

  * ``anneal``          — the original serial loop: one chain, one full
    pipeline train per iteration (re-groups, re-pads, and recompiles the
    LM solver whenever the padded shape changes).
  * ``anneal_batched``  — K parallel chains over a shared
    ``_BatchedEvaluator``: subset membership becomes 0/1 weights on
    fixed (G, maxn) group rectangles, the exponential fits run through
    one pre-compiled masked LM solve, the per-subset GBTs grow jointly
    via ``fit_packed_forest``, and a fingerprint-keyed cache dedupes
    re-proposed subsets across all chains.  See
    docs/annealing_engine.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.database import (build_exponential_database,
                                 build_group_structure)
from repro.core.predictor import predict_throughput, train_param_predictor

Subset = Dict[str, frozenset]


@dataclasses.dataclass
class SAConfig:
    n_iters: int = 150
    temperature: float = 10.0
    cooling: float = 0.97
    min_keep: int = 2           # never drop a dim below this many values
    seed: int = 0
    # GBT size during SA evaluations (smaller = faster exploration)
    gbt_kw: dict = dataclasses.field(default_factory=lambda: dict(
        n_estimators=60, learning_rate=0.15, max_depth=4))
    # batched engine (anneal_batched): parallel chains + shared cache
    n_chains: int = 1
    use_cache: bool = True


@dataclasses.dataclass
class SALog:
    subsets: List[Subset]
    errors: List[float]
    universes: Dict[str, np.ndarray]
    best_subset: Subset
    best_error: float

    def subset_masks(self, ii, oo, bb) -> np.ndarray:
        """(S, n) row masks of every logged subset over the given rows
        (vectorized; the raw material for Alg 8's ``SubsetBank``)."""
        return batch_subset_masks(ii, oo, bb, self.subsets, self.universes)


def merge_logs(old: SALog, new: SALog) -> SALog:
    """Append ``new``'s proposals to ``old``'s — one growing log across
    online data epochs.

    Universes take the per-dimension union (appended data can introduce
    new unique values; old subsets stay valid as partial selections of
    the wider universe).  ``best_subset``/``best_error`` come from
    ``new``: errors from different epochs are measured against different
    evaluation sets, so only the freshest epoch's optimum is the state a
    warm start should chain from.  The merged subset/error lists feed
    the Alg 7 error predictor and the Alg 8 bank window as usual.
    """
    universes = {k: np.unique(np.concatenate(
        [np.asarray(old.universes[k], np.float64),
         np.asarray(new.universes[k], np.float64)]))
        for k in new.universes}
    return SALog(subsets=list(old.subsets) + list(new.subsets),
                 errors=list(old.errors) + list(new.errors),
                 universes=universes,
                 best_subset=dict(new.best_subset),
                 best_error=float(new.best_error))


def median_ape(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Median absolute percentage error (the paper's headline metric)."""
    denom = np.maximum(np.abs(y_true), 1e-9)
    return float(np.median(np.abs(y_pred - y_true) / denom) * 100.0)


def subset_mask(ii, oo, bb, subset: Subset) -> np.ndarray:
    m = np.isin(ii, list(subset["ii"]))
    m &= np.isin(oo, list(subset["oo"]))
    m &= np.isin(bb, list(subset["bb"]))
    return m


def batch_subset_masks(ii, oo, bb, subsets: Sequence[Subset],
                       universes: Optional[Dict[str, np.ndarray]] = None
                       ) -> np.ndarray:
    """(S, n) row masks for many subsets in one vectorized pass.

    Rows are coded into each dimension's universe once; each subset then
    contributes three membership bit-vectors, and the mask is a gather +
    logical-and — no per-subset ``np.isin`` over the rows.  Equals
    ``np.stack([subset_mask(ii, oo, bb, s) for s in subsets])``.
    """
    cols = {"ii": np.asarray(ii), "oo": np.asarray(oo),
            "bb": np.asarray(bb)}
    if universes is None:
        universes = {k: np.unique(v) for k, v in cols.items()}
    n = len(cols["ii"])
    out = np.ones((len(subsets), n), bool)
    for dim, col in cols.items():
        u = np.asarray(universes[dim])
        code = np.searchsorted(u, col)
        code_ok = (code < len(u))
        codec = np.minimum(code, len(u) - 1)
        in_universe = code_ok & (u[codec] == col)
        member = np.zeros((len(subsets), len(u)), bool)
        for si, s in enumerate(subsets):
            member[si] = np.isin(u, list(s[dim]))
        out &= member[:, codec] & in_universe[None, :]
    return out


def evaluate_subset(train, test, subset: Subset,
                    gbt_kw: Optional[dict] = None) -> float:
    """Train ALA on the subset rows; median APE on the eval rows."""
    ii, oo, bb, thpt = train
    tii, too, tbb, tthpt = test
    m = subset_mask(ii, oo, bb, subset)
    if m.sum() < 4:
        return 100.0
    db = build_exponential_database(ii[m], oo[m], bb[m], thpt[m])
    if db is None:
        return 100.0
    pred = None
    if len(db.training) >= 4:
        pred = train_param_predictor(db.training, **(gbt_kw or {}))
    yhat = predict_throughput(db, pred, tii, too, tbb)
    return median_ape(tthpt, yhat)


def _modify(subset: Subset, universes, rng, min_keep: int) -> Subset:
    """Randomly add or delete one value from one of the (ii,oo,bb) dims."""
    new = {k: set(v) for k, v in subset.items()}
    for _ in range(10):  # retry until a legal move is found
        dim = rng.choice(("ii", "oo", "bb"))
        cur = new[dim]
        universe = set(universes[dim].tolist())
        missing = sorted(universe - cur)
        can_add = bool(missing)
        can_del = len(cur) > min_keep
        if not (can_add or can_del):
            continue
        if can_add and (not can_del or rng.random() < 0.5):
            cur.add(missing[rng.integers(len(missing))])
        else:
            cur.remove(sorted(cur)[rng.integers(len(cur))])
        break
    return {k: frozenset(v) for k, v in new.items()}


def anneal(train, test, cfg: SAConfig,
           initial: Optional[Subset] = None,
           on_iter: Optional[Callable[[int, float], None]] = None) -> SALog:
    """Alg 6. ``train``/``test`` are (ii, oo, bb, thpt) tuples."""
    ii, oo, bb, _ = train
    rng = np.random.default_rng(cfg.seed)
    universes = {"ii": np.unique(ii), "oo": np.unique(oo),
                 "bb": np.unique(bb)}
    if initial is None:
        # start from a random half of each universe
        initial = _sample_initial(universes, rng, cfg.min_keep)
    best = dict(initial)
    e_best = evaluate_subset(train, test, best, cfg.gbt_kw)
    tau = cfg.temperature
    subsets, errors = [dict(best)], [e_best]
    # anchor: log the full-coverage subset so the error predictor is
    # calibrated for near-complete signatures (Alg 8 queries often are)
    full = {k: frozenset(u.tolist()) for k, u in universes.items()}
    subsets.append(full)
    errors.append(evaluate_subset(train, test, full, cfg.gbt_kw))
    for it in range(cfg.n_iters):
        tau *= cfg.cooling
        cand = _modify(best, universes, rng, cfg.min_keep)
        e_cand = evaluate_subset(train, test, cand, cfg.gbt_kw)
        accept = (e_cand < e_best or
                  rng.random() < np.exp((e_best - e_cand)
                                        / max(tau, 1e-9)))
        if accept:
            best, e_best = cand, e_cand
        subsets.append(dict(cand))
        errors.append(e_cand)
        if on_iter is not None:
            on_iter(it, e_cand)
    return SALog(subsets=subsets, errors=errors, universes=universes,
                 best_subset=best, best_error=e_best)


# ---------------------------------------------------------------------------
# Batched engine: K chains over a fixed-shape evaluator + shared cache
# ---------------------------------------------------------------------------

def subset_fingerprint(subset: Subset) -> Tuple:
    """Hashable identity of a subset — the eval-cache key."""
    return (subset["ii"], subset["oo"], subset["bb"])


class _BatchedEvaluator:
    """Evaluates batches of training subsets against one (train, test)
    split with every shape fixed up front.

    Construction precomputes the (ii, oo) group rectangles
    (``GroupStructure``), the engineered Alg 3 features for both the
    group keys and the test rows, and the test-row -> group mapping.  A
    subset evaluation is then: membership bit-vectors -> 0/1 row
    weights -> one pre-compiled masked LM solve for *all* candidates ->
    one jointly-grown packed GBT forest -> one vectorized Alg 5
    prediction pass.  Numerics follow ``evaluate_subset`` exactly
    (same group order, same init, same GBT math) up to float padding
    noise.
    """

    def __init__(self, train, test, gbt_kw: Optional[dict] = None,
                 n_slots: int = 4, predict_backend: str = "jax"):
        from repro.core.features import engineer

        ii, oo, bb, thpt = (np.asarray(v, np.float64) for v in train)
        self.universes = {"ii": np.unique(ii), "oo": np.unique(oo),
                          "bb": np.unique(bb)}
        self.gs = build_group_structure(ii, oo, bb, thpt)
        self.n_slots = max(1, n_slots)
        self.predict_backend = predict_backend
        g_keys = self.gs.keys
        self.g_ii_code = np.searchsorted(self.universes["ii"], g_keys[:, 0])
        self.g_oo_code = np.searchsorted(self.universes["oo"], g_keys[:, 1])
        self.Xtrain = engineer(g_keys[:, 0], g_keys[:, 1])       # (G, 7)

        tii, too, tbb, tthpt = (np.asarray(v, np.float64) for v in test)
        self.t_bb, self.t_thpt = tbb, tthpt
        keymap = {(float(a), float(b)): g
                  for g, (a, b) in enumerate(g_keys)}
        self.t_group = np.asarray(
            [keymap.get((float(a), float(b)), -1)
             for a, b in zip(tii, too)], np.int64)
        self.Xtest = engineer(tii, too)                           # (m, 7)
        self.t_ii, self.t_oo = tii, too

        kw = dict(n_estimators=150, learning_rate=0.08, max_depth=4,
                  n_bins=64)
        kw.update(gbt_kw or {})
        kw.setdefault("min_child_weight", 1.0)
        kw.setdefault("reg_lambda", 1.0)
        # fit_packed_forest has no row/column sampling; those options
        # (and seed, which only matters when sampling) drop to a
        # per-candidate MultiOutputGBT fallback with identical semantics
        self.sample_kw = {k: kw.pop(k)
                          for k in ("subsample", "colsample", "seed")
                          if k in kw}
        self._joint_gbt = (self.sample_kw.get("subsample", 1.0) >= 1.0
                           and self.sample_kw.get("colsample", 1.0) >= 1.0)
        self.gbt_kw = kw

    # -- helpers -------------------------------------------------------------
    def _member(self, subsets: Sequence[Subset], dim: str) -> np.ndarray:
        u = self.universes[dim]
        out = np.zeros((len(subsets), len(u)), bool)
        for c, s in enumerate(subsets):
            out[c] = np.isin(u, list(s[dim]))
        return out

    def _theta0(self, W: np.ndarray, n_bb: np.ndarray) -> np.ndarray:
        """Vectorized ``initial_params`` over (C, G) masked rectangles."""
        C, G, maxn = W.shape
        dead = W.sum(axis=2) <= 0
        Xn = np.where(W > 0, self.gs.bb[None], np.nan)
        Yn = np.where(W > 0, self.gs.thpt[None], np.nan)
        # dead groups would make nanpercentile warn on all-NaN slices
        Xn[dead] = 0.0
        Yn[dead] = 0.0
        t10, t90 = np.nanpercentile(Yn, [10, 90], axis=2)
        b10, b90 = np.nanpercentile(Xn, [10, 90], axis=2)
        b90 = np.maximum(b90, b10 + 1e-3)
        theta0 = np.stack([np.maximum(t90 - t10, 1e-5),
                           1.0 / np.maximum(b90 - b10, 1e-5),
                           np.maximum(t90, 1e-5)], axis=2)
        theta0[(n_bb <= 1) | dead] = (1.0, 0.001, 0.0)
        return theta0

    # -- the batch evaluation ------------------------------------------------
    def evaluate_batch(self, subsets: Sequence[Subset]) -> np.ndarray:
        from repro.core.fit import fit_exponential_masked
        from repro.core.gbt import fit_packed_forest

        C = len(subsets)
        if C == 0:
            return np.zeros(0)
        gs = self.gs
        G, maxn = gs.bb.shape
        m_ii = self._member(subsets, "ii")
        m_oo = self._member(subsets, "oo")
        m_bb = self._member(subsets, "bb")
        selected = (m_ii[:, self.g_ii_code]
                    & m_oo[:, self.g_oo_code])                  # (C, G)
        W = (gs.row_w[None] * m_bb[:, gs.bb_codes]
             * selected[:, :, None])                            # (C, G, maxn)
        rows_total = W.sum(axis=(1, 2))
        n_bb = (gs.bb_present[None] & m_bb[:, None, :]).sum(axis=2)
        theta0 = self._theta0(W, n_bb)

        # one fixed-shape LM solve for every candidate (padded to n_slots)
        S = max(self.n_slots, C)
        T0 = np.zeros((S, G, 3))
        Xp = np.zeros((S, G, maxn))
        Yp = np.zeros((S, G, maxn))
        Wp = np.zeros((S, G, maxn))
        T0[:C] = theta0
        Xp[:C] = np.broadcast_to(gs.bb[None], (C, G, maxn))
        Yp[:C] = np.broadcast_to(gs.thpt[None], (C, G, maxn))
        Wp[:C] = W
        theta = fit_exponential_masked(
            T0.reshape(S * G, 3), Xp.reshape(S * G, maxn),
            Yp.reshape(S * G, maxn),
            Wp.reshape(S * G, maxn)).reshape(S, G, 3)[:C]

        fitted = (selected & (W.sum(axis=2) >= 1)
                  & np.isfinite(theta).all(axis=2))             # (C, G)
        n_fitted = fitted.sum(axis=1)

        # Alg 3 targets: (a, log b, c) for fitted groups, 0 elsewhere
        Y = np.where(fitted[:, :, None], np.nan_to_num(theta), 0.0)
        Y[:, :, 1] = np.where(fitted,
                              np.log(np.maximum(Y[:, :, 1], 1e-10)), 0.0)
        with_model = n_fitted >= 4
        model_rows = np.nonzero(with_model)[0]
        params = None
        if len(model_rows):
            if self._joint_gbt:
                Xb = np.broadcast_to(self.Xtrain[None],
                                     (len(model_rows),) + self.Xtrain.shape)
                forest = fit_packed_forest(
                    Xb, Y[model_rows],
                    fitted[model_rows].astype(np.float64), **self.gbt_kw)
                params = self._predict_params(forest, len(model_rows))
            else:
                params = self._predict_params_sampled(Y, fitted, model_rows)

        # -- Alg 5, vectorized over candidates and test rows ----------------
        tg = np.maximum(self.t_group, 0)
        hit = (self.t_group >= 0)[None, :] & fitted[:, tg]      # (C, m)
        a = theta[:, tg, 0]
        b = theta[:, tg, 1]
        cc = theta[:, tg, 2]
        analytic = cc - a * np.exp(-b * self.t_bb[None, :])
        preds = np.where(hit, analytic, 0.0)

        if params is not None:
            ml = (params[:, :, 2]
                  - params[:, :, 0] * np.exp(-params[:, :, 1]
                                             * self.t_bb[None, :]))
            for j, c in enumerate(model_rows):
                miss = ~hit[c]
                preds[c, miss] = ml[j, miss]
        for c in np.nonzero(~with_model)[0]:
            miss = ~hit[c]
            if miss.any():
                preds[c, miss] = self._nearest_fallback(
                    theta[c], fitted[c], miss)

        errors = np.array([median_ape(self.t_thpt, preds[c])
                           for c in range(C)])
        errors[rows_total < 4] = 100.0
        errors[n_fitted == 0] = 100.0
        return errors

    def _predict_params(self, forest, n_active: int) -> np.ndarray:
        """Packed-forest Alg 3 inference -> (n_active, m, 3) (a, b, c).

        Forests are padded to ``n_slots`` candidates so the jit'd
        traversal compiles for a single shape per process."""
        S = max(self.n_slots, n_active)
        if n_active < S:
            import dataclasses as _dc
            pad = [(0, S - n_active)] + [(0, 0)] * 3
            forest = _dc.replace(
                forest,
                feature=np.pad(forest.feature, pad, constant_values=-1),
                threshold=np.pad(forest.threshold, pad),
                left=np.pad(forest.left, pad),
                right=np.pad(forest.right, pad),
                value=np.pad(forest.value, pad),
                base=np.pad(forest.base, [(0, S - n_active), (0, 0)]),
                bin_edges=np.pad(forest.bin_edges,
                                 [(0, S - n_active), (0, 0), (0, 0)]),
                n_nodes=np.pad(forest.n_nodes, pad[:3]))
        X = np.broadcast_to(self.Xtest[None], (S,) + self.Xtest.shape)
        params = forest.predict(X, backend=self.predict_backend)[:n_active]
        return self._postprocess_params(params.copy())

    def _predict_params_sampled(self, Y, fitted, model_rows) -> np.ndarray:
        """Fallback when gbt_kw requests row/column sampling: train one
        MultiOutputGBT per candidate (exactly the serial Alg 3 path)."""
        from repro.core.gbt import MultiOutputGBT

        out = np.empty((len(model_rows), len(self.t_bb), 3))
        for j, c in enumerate(model_rows):
            rows = fitted[c]
            model = MultiOutputGBT(3, **self.gbt_kw, **self.sample_kw)
            model.fit(self.Xtrain[rows], Y[c, rows])
            out[j] = model.predict(self.Xtest)
        return self._postprocess_params(out)

    @staticmethod
    def _postprocess_params(params: np.ndarray) -> np.ndarray:
        """Alg 3 target transforms inverted: b back from log space,
        positivity clamps on a and c (mirrors ``predict_params``)."""
        params[:, :, 1] = np.exp(params[:, :, 1])
        params[:, :, 0] = np.maximum(params[:, :, 0], 0.0)
        params[:, :, 2] = np.maximum(params[:, :, 2], 0.0)
        return params

    def _nearest_fallback(self, theta_c, fitted_c, miss) -> np.ndarray:
        """Legacy no-ML path: nearest fitted (ii, oo) in log1p distance."""
        sel = np.nonzero(fitted_c)[0]
        if not len(sel):
            return np.zeros(int(miss.sum()))
        keys = self.gs.keys[sel]
        d = (np.abs(np.log1p(keys[:, 0])[None, :]
                    - np.log1p(self.t_ii[miss])[:, None])
             + np.abs(np.log1p(keys[:, 1])[None, :]
                      - np.log1p(self.t_oo[miss])[:, None]))
        th = theta_c[sel[d.argmin(axis=1)]]
        return th[:, 2] - th[:, 0] * np.exp(-th[:, 1] * self.t_bb[miss])

    def evaluate(self, subset: Subset) -> float:
        return float(self.evaluate_batch([subset])[0])


def _sample_initial(universes, rng, min_keep: int) -> Subset:
    out = {}
    for k, u in universes.items():
        k_n = max(min_keep, len(u) // 2)
        out[k] = frozenset(rng.choice(u, size=k_n, replace=False).tolist())
    return out


def anneal_batched(train, test, cfg: SAConfig,
                   initial: Optional[Subset] = None,
                   on_iter: Optional[Callable[[int, float], None]] = None,
                   evaluator: Optional[_BatchedEvaluator] = None) -> SALog:
    """Alg 6 with K parallel chains sharing one evaluation cache.

    ``cfg.n_iters`` counts *per-chain* steps, so one run proposes
    ``n_chains * n_iters`` subsets.  Each iteration every chain proposes
    a move; proposals not in the cache are evaluated together in one
    ``_BatchedEvaluator.evaluate_batch`` call.  ``best`` is the global
    minimum over every evaluation (the serial engine reports its final
    chain state instead).  The returned ``SALog`` is drop-in for
    Alg 7/8.
    """
    K = max(1, cfg.n_chains)
    rng = np.random.default_rng(cfg.seed)
    ev = evaluator or _BatchedEvaluator(train, test, cfg.gbt_kw,
                                        n_slots=K + 1)
    universes = ev.universes
    chain_rngs = [np.random.default_rng(cfg.seed + 7919 * (c + 1))
                  for c in range(K)]

    states: List[Subset] = []
    for c in range(K):
        if c == 0 and initial is not None:
            states.append(dict(initial))
        else:
            states.append(_sample_initial(universes,
                                          rng if c == 0 else chain_rngs[c],
                                          cfg.min_keep))
    full = {k: frozenset(u.tolist()) for k, u in universes.items()}

    cache: Dict[Tuple, float] = {}
    subsets: List[Subset] = []
    errors: List[float] = []

    def eval_all(cands: Sequence[Subset]) -> List[float]:
        fps = [subset_fingerprint(s) for s in cands]
        todo, order = [], {}
        for f, s in zip(fps, cands):
            if f not in cache and f not in order:
                order[f] = len(todo)
                todo.append(s)
        if todo:
            fresh = ev.evaluate_batch(todo)
            for f, i in order.items():
                cache[f] = float(fresh[i])
        out = [cache[f] for f in fps]
        if not cfg.use_cache:
            # keep only within-batch dedup; forget across iterations
            cache.clear()
        return out

    # chain initial states + the full-coverage anchor (Alg 8 calibration)
    e_states = eval_all(states)
    e_full = eval_all([full])[0]
    for s, e in zip(states, e_states):
        subsets.append(dict(s))
        errors.append(e)
    subsets.append(dict(full))
    errors.append(e_full)

    tau = cfg.temperature
    for it in range(cfg.n_iters):
        tau *= cfg.cooling
        cands = [_modify(states[c], universes, chain_rngs[c], cfg.min_keep)
                 for c in range(K)]
        e_cands = eval_all(cands)
        for c in range(K):
            accept = (e_cands[c] < e_states[c] or
                      chain_rngs[c].random() < np.exp(
                          (e_states[c] - e_cands[c]) / max(tau, 1e-9)))
            if accept:
                states[c], e_states[c] = cands[c], e_cands[c]
            subsets.append(dict(cands[c]))
            errors.append(e_cands[c])
        if on_iter is not None:
            on_iter(it, min(e_cands))

    best_i = int(np.argmin(errors))
    return SALog(subsets=subsets, errors=errors, universes=universes,
                 best_subset=dict(subsets[best_i]),
                 best_error=float(errors[best_i]))
