"""Simulated annealing over training-subset space (paper Alg 6).

State = which unique values of (ii, oo, bb) are included in the training
subset.  EvaluateSubset trains the full ALA pipeline (Alg 2 + Alg 3) on
the filtered rows and scores median percentage error on a held-out
evaluation set.  Every iteration logs (subset, error) — the raw material
for the error predictor (Alg 7) and the uncertainty metric (Alg 8).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.database import build_exponential_database
from repro.core.predictor import predict_throughput, train_param_predictor

Subset = Dict[str, frozenset]


@dataclasses.dataclass
class SAConfig:
    n_iters: int = 150
    temperature: float = 10.0
    cooling: float = 0.97
    min_keep: int = 2           # never drop a dim below this many values
    seed: int = 0
    # GBT size during SA evaluations (smaller = faster exploration)
    gbt_kw: dict = dataclasses.field(default_factory=lambda: dict(
        n_estimators=60, learning_rate=0.15, max_depth=4))


@dataclasses.dataclass
class SALog:
    subsets: List[Subset]
    errors: List[float]
    universes: Dict[str, np.ndarray]
    best_subset: Subset
    best_error: float


def median_ape(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    """Median absolute percentage error (the paper's headline metric)."""
    denom = np.maximum(np.abs(y_true), 1e-9)
    return float(np.median(np.abs(y_pred - y_true) / denom) * 100.0)


def subset_mask(ii, oo, bb, subset: Subset) -> np.ndarray:
    m = np.isin(ii, list(subset["ii"]))
    m &= np.isin(oo, list(subset["oo"]))
    m &= np.isin(bb, list(subset["bb"]))
    return m


def evaluate_subset(train, test, subset: Subset,
                    gbt_kw: Optional[dict] = None) -> float:
    """Train ALA on the subset rows; median APE on the eval rows."""
    ii, oo, bb, thpt = train
    tii, too, tbb, tthpt = test
    m = subset_mask(ii, oo, bb, subset)
    if m.sum() < 4:
        return 100.0
    db = build_exponential_database(ii[m], oo[m], bb[m], thpt[m])
    if db is None:
        return 100.0
    pred = None
    if len(db.training) >= 4:
        pred = train_param_predictor(db.training, **(gbt_kw or {}))
    yhat = predict_throughput(db, pred, tii, too, tbb)
    return median_ape(tthpt, yhat)


def _modify(subset: Subset, universes, rng, min_keep: int) -> Subset:
    """Randomly add or delete one value from one of the (ii,oo,bb) dims."""
    new = {k: set(v) for k, v in subset.items()}
    for _ in range(10):  # retry until a legal move is found
        dim = rng.choice(("ii", "oo", "bb"))
        cur = new[dim]
        universe = set(universes[dim].tolist())
        missing = sorted(universe - cur)
        can_add = bool(missing)
        can_del = len(cur) > min_keep
        if not (can_add or can_del):
            continue
        if can_add and (not can_del or rng.random() < 0.5):
            cur.add(missing[rng.integers(len(missing))])
        else:
            cur.remove(sorted(cur)[rng.integers(len(cur))])
        break
    return {k: frozenset(v) for k, v in new.items()}


def anneal(train, test, cfg: SAConfig,
           initial: Optional[Subset] = None,
           on_iter: Optional[Callable[[int, float], None]] = None) -> SALog:
    """Alg 6. ``train``/``test`` are (ii, oo, bb, thpt) tuples."""
    ii, oo, bb, _ = train
    rng = np.random.default_rng(cfg.seed)
    universes = {"ii": np.unique(ii), "oo": np.unique(oo),
                 "bb": np.unique(bb)}
    if initial is None:
        # start from a random half of each universe
        initial = {}
        for k, u in universes.items():
            k_n = max(cfg.min_keep, len(u) // 2)
            initial[k] = frozenset(
                rng.choice(u, size=k_n, replace=False).tolist())
    best = dict(initial)
    e_best = evaluate_subset(train, test, best, cfg.gbt_kw)
    tau = cfg.temperature
    subsets, errors = [dict(best)], [e_best]
    # anchor: log the full-coverage subset so the error predictor is
    # calibrated for near-complete signatures (Alg 8 queries often are)
    full = {k: frozenset(u.tolist()) for k, u in universes.items()}
    subsets.append(full)
    errors.append(evaluate_subset(train, test, full, cfg.gbt_kw))
    for it in range(cfg.n_iters):
        tau *= cfg.cooling
        cand = _modify(best, universes, rng, cfg.min_keep)
        e_cand = evaluate_subset(train, test, cand, cfg.gbt_kw)
        accept = (e_cand < e_best or
                  rng.random() < np.exp((e_best - e_cand)
                                        / max(tau, 1e-9)))
        if accept:
            best, e_best = cand, e_cand
        subsets.append(dict(cand))
        errors.append(e_cand)
        if on_iter is not None:
            on_iter(it, e_cand)
    return SALog(subsets=subsets, errors=errors, universes=universes,
                 best_subset=best, best_error=e_best)
