"""Feature engineering for the parameter predictor (paper Alg 3)."""
from __future__ import annotations

import numpy as np

FEATURE_NAMES = ("ii", "oo", "log_ii", "log_oo", "log_bb",
                 "ii_oo_ratio", "ii_ii_ratio")


def engineer(ii: np.ndarray, oo: np.ndarray) -> np.ndarray:
    """(n,) x2 -> (n, 7) feature matrix, exactly the paper's transforms."""
    ii = np.asarray(ii, np.float64)
    oo = np.asarray(oo, np.float64)
    log_ii = np.log1p(ii)
    log_oo = np.log1p(oo)
    log_bb = np.log1p(ii / np.maximum(oo, 1e-12))
    ii_oo_ratio = ii / (oo + 1.0)
    ii_ii_ratio = ii / (ii + 1.0)
    return np.stack([ii, oo, log_ii, log_oo, log_bb,
                     ii_oo_ratio, ii_ii_ratio], axis=1)
