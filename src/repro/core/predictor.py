"""Parameter predictor (paper Alg 3) + throughput prediction (Alg 5)."""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.database import ExpDatabase
from repro.core.expmodel import exp_model
from repro.core.features import engineer
from repro.core.gbt import MultiOutputGBT


def train_param_predictor(training: np.ndarray,
                          **gbt_kw) -> Optional[MultiOutputGBT]:
    """Alg 3: engineered (ii, oo) features -> (a, b, c) multi-output GBT.

    b is learned in log space (it spans decades and is positivity
    constrained) — a practical necessity the paper leaves implicit.
    """
    if training is None or len(training) == 0:
        return None
    X = engineer(training[:, 0], training[:, 1])
    Y = training[:, 2:5].copy()
    Y[:, 1] = np.log(np.maximum(Y[:, 1], 1e-10))
    kw = dict(n_estimators=150, learning_rate=0.08, max_depth=4, n_bins=64)
    kw.update(gbt_kw)
    model = MultiOutputGBT(3, **kw)
    model.fit(X, Y)
    return model


def predict_params(model: MultiOutputGBT, ii, oo) -> np.ndarray:
    ii = np.atleast_1d(np.asarray(ii, np.float64))
    oo = np.atleast_1d(np.asarray(oo, np.float64))
    Y = model.predict(engineer(ii, oo))
    Y = Y.copy()
    Y[:, 1] = np.exp(Y[:, 1])
    Y[:, 0] = np.maximum(Y[:, 0], 0.0)
    Y[:, 2] = np.maximum(Y[:, 2], 0.0)
    return Y


def predict_throughput(db: Optional[ExpDatabase],
                       model: Optional[MultiOutputGBT],
                       ii, oo, bb) -> np.ndarray:
    """Alg 5: DB hit -> analytical params; miss -> ML-predicted params."""
    ii = np.atleast_1d(np.asarray(ii, np.float64))
    oo = np.atleast_1d(np.asarray(oo, np.float64))
    bb = np.atleast_1d(np.asarray(bb, np.float64))
    out = np.empty(len(ii), np.float64)
    miss = np.ones(len(ii), bool)
    if db is not None:
        for i in range(len(ii)):
            th = db.lookup(ii[i], oo[i])
            if th is not None:
                out[i] = exp_model(bb[i], *th)
                miss[i] = False
    if miss.any():
        if model is None:
            # no ML model: fall back to nearest DB entry by (ii,oo) distance
            if db is None or not len(db.params):
                out[miss] = 0.0
            else:
                keys = np.asarray(list(db.params.keys()))
                vals = np.asarray(list(db.params.values()))
                for i in np.where(miss)[0]:
                    d = np.abs(np.log1p(keys[:, 0]) - np.log1p(ii[i])) \
                        + np.abs(np.log1p(keys[:, 1]) - np.log1p(oo[i]))
                    th = vals[d.argmin()]
                    out[i] = exp_model(bb[i], *th)
        else:
            th = predict_params(model, ii[miss], oo[miss])
            out[miss] = exp_model(bb[miss], th[:, 0], th[:, 1], th[:, 2])
    return out
