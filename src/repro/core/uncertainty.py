"""Uncertainty quantification (paper Alg 8) — serial and batched engines.

Confidence c = 1 / (1 + d_min), where d_min is the minimum over logged SA
subsets of the average per-feature *histogram cosine distance* between the
new workload's (ii, oo, bb, thpt) distribution and the subset's rows.
Workload features are histogrammed in log space (they span decades);
throughput in linear space.

Two paths share the metric:

  * ``confidence``      — the original serial loop.  Bin edges are
    recomputed from the union range of every (query, subset) pair, and
    each pair re-histograms both row sets.  O(S) pipeline passes per
    query; fine for one-off estimates.
  * ``SubsetBank``      — the fleet-scale engine.  Built once per SA
    log: subset row-masks materialize in one vectorized pass, bin edges
    are fixed from the training rows, and every subset's per-feature
    histograms precompute into an (S, 4, B) array.  Queries then run
    through one jitted JAX kernel (bucketize -> segment-sum histograms
    -> normalized dot products) that emits the full
    (n_queries x n_subsets) cosine-distance matrix in a single call.
    ``bank_distances(..., backend="numpy")`` is the serial float64
    reference for the same fixed-bin contract; the JAX path matches it
    to <= 1e-6.  See docs/uncertainty_engine.md.

Degenerate logs (every subset selects < 2 training rows) surface
explicitly in both paths: d_min = inf, confidence = 0.0 — never the
misleading mid-scale fallback of pretending d_min = 1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.annealing import SALog, Subset, batch_subset_masks, subset_mask
from repro.core.fit import _pow2 as _pad_pow2

N_HIST_BINS = 16
FEATS = ("ii", "oo", "bb", "thpt")
MIN_SUBSET_ROWS = 2
# both engines reduce d_min over the same trailing window of the SA log
# by default, and it bounds bank memory on long multi-chain runs
DEFAULT_MAX_SUBSETS = 200
# weight of the hardware-descriptor distance when a fit is queried on
# hardware it was not benchmarked on: the effective Alg 8 distance is
# d_eff = d_min + HW_DIST_WEIGHT * d_hw before the 1/(1+d) squash, so
# any d_hw > 0 strictly lowers confidence on identical workloads (see
# repro.perfmodel.hardware.hardware_distance for the d_hw scale)
HW_DIST_WEIGHT = 1.0


def _feature_bins(ref: Dict[str, np.ndarray],
                  new: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    bins = {}
    for f in FEATS:
        allv = np.concatenate([ref[f], new[f]]).astype(np.float64)
        if f == "thpt":
            lo, hi = float(allv.min()), float(allv.max())
            hi = hi if hi > lo else lo + 1.0
            bins[f] = np.linspace(lo, hi, N_HIST_BINS + 1)
        else:
            lo = max(float(allv.min()), 1e-9)
            hi = max(float(allv.max()), lo * (1 + 1e-9))
            bins[f] = np.geomspace(lo, hi * (1 + 1e-9), N_HIST_BINS + 1)
    return bins


def _hist(vals: np.ndarray, edges: np.ndarray) -> np.ndarray:
    h, _ = np.histogram(np.asarray(vals, np.float64), bins=edges)
    h = h.astype(np.float64)
    s = h.sum()
    return h / s if s > 0 else h


def _cosine_distance(u: np.ndarray, v: np.ndarray) -> float:
    nu, nv = np.linalg.norm(u), np.linalg.norm(v)
    if nu == 0 or nv == 0:
        return 1.0
    return float(1.0 - np.dot(u, v) / (nu * nv))


def workload_distance(ref_rows: Dict[str, np.ndarray],
                      new_rows: Dict[str, np.ndarray]) -> float:
    """Average per-feature histogram cosine distance between two row sets."""
    bins = _feature_bins(ref_rows, new_rows)
    ds = []
    for f in FEATS:
        ds.append(_cosine_distance(_hist(ref_rows[f], bins[f]),
                                   _hist(new_rows[f], bins[f])))
    return float(np.mean(ds))


def confidence(train, log: SALog, new,
               max_subsets: int = DEFAULT_MAX_SUBSETS,
               hw_dist: float = 0.0) -> Tuple[float, float]:
    """Alg 8 lines 4-6: (d_min, confidence) for a new workload.

    ``train``/``new`` are (ii, oo, bb, thpt) tuples; logged subsets are
    materialized as row-sets of the training data they selected.
    Subsets selecting fewer than ``MIN_SUBSET_ROWS`` rows carry no
    distributional signal and are skipped; when *every* subset is
    skipped the log is degenerate and the result is the explicit
    sentinel ``(inf, 0.0)`` — same contract as the batched path.
    """
    ii, oo, bb, thpt = train
    nii, noo, nbb, nthpt = new
    new_rows = {"ii": nii, "oo": noo, "bb": nbb, "thpt": nthpt}
    subsets = log.subsets[-max_subsets:]
    d_min = np.inf
    for s in subsets:
        m = subset_mask(ii, oo, bb, s)
        if m.sum() < MIN_SUBSET_ROWS:
            continue
        ref_rows = {"ii": ii[m], "oo": oo[m], "bb": bb[m], "thpt": thpt[m]}
        d = workload_distance(ref_rows, new_rows)
        d_min = min(d_min, d)
    return float(d_min), confidence_from_dmin(d_min, hw_dist)


def confidence_from_dmin(d_min: float, hw_dist: float = 0.0) -> float:
    """1 / (1 + d_min + HW_DIST_WEIGHT * hw_dist), with the degenerate
    d_min = inf mapping to 0.0.  ``hw_dist`` is the hardware-descriptor
    distance between the queried hardware and the hardware the fit was
    benchmarked on (0 for same-hardware queries)."""
    if not np.isfinite(d_min):
        return 0.0
    return float(1.0 / (1.0 + d_min + HW_DIST_WEIGHT * hw_dist))


# ---------------------------------------------------------------------------
# SubsetBank: fixed-shape histograms + the batched distance kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SubsetBank:
    """Precomputed fixed-shape state for batched Alg 8 queries.

    Built once per (train, SALog) pair.  The *fixed-bin contract*: bin
    edges derive from the training rows only (log-space for ii/oo/bb,
    linear for thpt), values outside the range clip into the boundary
    bins, and bin *assignment* compares float32 values against float32
    edges — identically in the serial numpy reference and the jitted
    kernel, so both paths count the exact same histograms and differ
    only by float32-vs-float64 rounding in the cosine arithmetic.
    """
    inner_edges: np.ndarray     # (4, B-1) float32 bucketize edges
    hist: np.ndarray            # (S, 4, B) float64 subset count histograms
    unit: np.ndarray            # (S, 4, B) float32 L2-normalized histograms
    valid: np.ndarray           # (S,) bool — >= MIN_SUBSET_ROWS rows selected
    masks: np.ndarray           # (S, n) bool training-row masks
    subsets: List[Subset]
    universes: Dict[str, np.ndarray]
    n_bins: int

    @property
    def n_subsets(self) -> int:
        return len(self.subsets)


def _bank_edges(train, n_bins: int) -> np.ndarray:
    """(4, B-1) float32 inner edges: geomspace for ii/oo/bb, linspace
    for thpt, ranges from the (finite) training rows.

    The two boundary bins are *reserved for out-of-range values*: the
    training range [lo, hi] splits into the B-2 core bins, the first
    inner edge sits at lo (side="right" keeps v == lo in the core) and
    the last one ulp above hi.  Training rows therefore never occupy
    bins 0 / B-1, so a query far outside the range concentrates in a
    bin no valid subset has mass in and reads as distant — out-of-range
    mass is flagged, not silently merged with the training extremes.
    """
    cols = dict(zip(FEATS, (np.asarray(v, np.float64) for v in train)))
    inner = np.empty((len(FEATS), n_bins - 1), np.float32)
    for fi, f in enumerate(FEATS):
        v = cols[f][np.isfinite(cols[f])]
        if f == "thpt":
            lo = float(v.min()) if len(v) else 0.0
            hi = float(v.max()) if len(v) else 1.0
            hi = hi if hi > lo else lo + 1.0
            core = np.linspace(lo, hi, n_bins - 1)[1:-1]
        else:
            lo = max(float(v.min()), 1e-9) if len(v) else 1e-9
            hi = max(float(v.max()), lo * (1 + 1e-9)) if len(v) else 1.0
            core = np.geomspace(lo, hi, n_bins - 1)[1:-1]
        lo32, hi32 = np.float32(lo), np.float32(hi)
        edges = np.concatenate(
            [[lo32], core.astype(np.float32),
             [np.nextafter(hi32, np.float32(np.inf))]])
        # float32 rounding of near-equal float64 edges must stay sorted
        inner[fi] = np.maximum.accumulate(edges)
    return inner


def _bucketize(vals: np.ndarray, inner_f32: np.ndarray) -> np.ndarray:
    """Fixed-bin assignment (float32 compare, clipping out-of-range
    values into the boundary bins).  Identical semantics to the kernel's
    jnp.searchsorted."""
    return np.searchsorted(inner_f32,
                           np.asarray(vals, np.float32), side="right") \
        .astype(np.int32)


def _count_hist(vals: np.ndarray, inner_f32: np.ndarray,
                n_bins: int, weights: Optional[np.ndarray] = None
                ) -> np.ndarray:
    """Float64 count histogram of the finite values (fixed bins)."""
    vals = np.asarray(vals, np.float64)
    finite = np.isfinite(vals)
    w = finite.astype(np.float64) if weights is None \
        else finite * np.asarray(weights, np.float64)
    bins = _bucketize(np.where(finite, vals, 0.0), inner_f32)
    return np.bincount(bins, w, minlength=n_bins).astype(np.float64)


def _finalize_bank(inner, hist, masks, subsets, universes,
                   n_bins: int) -> SubsetBank:
    """L2-normalize + validity flags — shared bank assembly tail."""
    nrm = np.linalg.norm(hist, axis=2, keepdims=True)
    unit = (hist / np.maximum(nrm, 1e-30)).astype(np.float32)
    valid = masks.sum(axis=1) >= MIN_SUBSET_ROWS
    return SubsetBank(inner_edges=inner, hist=hist, unit=unit, valid=valid,
                      masks=masks, subsets=subsets,
                      universes={k: np.asarray(v)
                                 for k, v in universes.items()},
                      n_bins=n_bins)


def _onehot_bins(cols, inner: np.ndarray, n_bins: int) -> np.ndarray:
    """(4, n, B) one-hot bin assignment of the rows under fixed edges
    (non-finite values carry no mass)."""
    n = len(cols[0])
    out = np.zeros((len(FEATS), n, n_bins), np.float64)
    for fi, col in enumerate(cols):
        finite = np.isfinite(col)
        bins = _bucketize(np.where(finite, col, 0.0), inner[fi])
        out[fi, np.arange(n)[finite], bins[finite]] = 1.0
    return out


def build_subset_bank(train, log: SALog,
                      max_subsets: Optional[int] = DEFAULT_MAX_SUBSETS,
                      n_bins: int = N_HIST_BINS,
                      inner_edges: Optional[np.ndarray] = None) -> SubsetBank:
    """Materialize the SA log into fixed-shape arrays, once.

    Row masks come from one vectorized membership pass
    (``batch_subset_masks``); per-subset histograms are a single
    (S, n) @ (n, B) matmul per feature (exact integer counts in
    float64).  ``inner_edges`` overrides the training-derived bin edges
    — the hook ``extend_bank`` parity checks use, and the way an online
    refit can pin the original fixed-bin contract across data epochs.
    """
    ii, oo, bb, thpt = (np.asarray(v, np.float64) for v in train)
    subsets = list(log.subsets[-max_subsets:] if max_subsets
                   else log.subsets)
    masks = batch_subset_masks(ii, oo, bb, subsets, log.universes)
    inner = (_bank_edges((ii, oo, bb, thpt), n_bins)
             if inner_edges is None else np.asarray(inner_edges, np.float32))

    cols = (ii, oo, bb, thpt)
    onehot = _onehot_bins(cols, inner, n_bins)
    masks_f = masks.astype(np.float64)
    hist = np.einsum("sn,fnb->sfb", masks_f, onehot)
    return _finalize_bank(inner, hist, masks, subsets, log.universes,
                          n_bins)


def extend_bank(bank: SubsetBank, train, n_delta: int,
                new_subsets: Sequence[Subset],
                universes: Dict[str, np.ndarray],
                max_subsets: Optional[int] = DEFAULT_MAX_SUBSETS
                ) -> SubsetBank:
    """Incrementally grow a bank after rows were *appended* to the
    training data and new subsets were logged (one online refit epoch).

    ``train`` is the full concatenated (ii, oo, bb, thpt); its last
    ``n_delta`` rows are the appended delta (the prefix must be the rows
    the bank was built on — callers verify; ``ALA.refit`` does).  Counts
    are additive under the fixed-bin contract, so instead of
    re-histogramming every subset over every row this

      1. extends the existing subsets' masks/histograms by only the
         delta rows:  ``hist += masks(delta) @ onehot(delta)``  —
         O(S_old x n_delta);
      2. builds the new subsets' masks/histograms over the full rows —
         O(S_new x n);
      3. applies the trailing ``max_subsets`` window.

    Bin edges are *kept* from the original bank (that is what makes the
    update additive): delta rows outside the original training range
    clip into the reserved boundary bins and read as distant — exactly
    the drift signal the online engine watches.  The result is bit-equal
    to ``build_subset_bank`` on the concatenated data + merged log with
    ``inner_edges=bank.inner_edges``.
    """
    ii, oo, bb, thpt = (np.asarray(v, np.float64) for v in train)
    n = len(ii)
    n_old = n - int(n_delta)
    if n_old != bank.masks.shape[1]:
        raise ValueError(f"extend_bank: bank covers {bank.masks.shape[1]} "
                         f"rows but train has {n} with n_delta={n_delta}")
    cols = (ii, oo, bb, thpt)

    # 1. old subsets gain only the delta rows' mass
    if n_delta > 0:
        d_masks = batch_subset_masks(ii[n_old:], oo[n_old:], bb[n_old:],
                                     bank.subsets, universes)
        d_onehot = _onehot_bins(tuple(c[n_old:] for c in cols),
                                bank.inner_edges, bank.n_bins)
        hist_old = bank.hist + np.einsum("sn,fnb->sfb",
                                         d_masks.astype(np.float64),
                                         d_onehot)
        masks_old = np.concatenate([bank.masks, d_masks], axis=1)
    else:
        hist_old, masks_old = bank.hist.copy(), bank.masks

    # 2. new subsets over the full rows
    new_subsets = list(new_subsets)
    if new_subsets:
        n_masks = batch_subset_masks(ii, oo, bb, new_subsets, universes)
        onehot = _onehot_bins(cols, bank.inner_edges, bank.n_bins)
        hist_new = np.einsum("sn,fnb->sfb", n_masks.astype(np.float64),
                             onehot)
        hist = np.concatenate([hist_old, hist_new], axis=0)
        masks = np.concatenate([masks_old, n_masks], axis=0)
    else:
        hist, masks = hist_old, masks_old
    subsets = list(bank.subsets) + new_subsets

    # 3. trailing window — same cap semantics as build_subset_bank
    if max_subsets and len(subsets) > max_subsets:
        subsets = subsets[-max_subsets:]
        hist = hist[-max_subsets:]
        masks = masks[-max_subsets:]
    return _finalize_bank(bank.inner_edges, hist, masks, subsets,
                          universes, bank.n_bins)


def _make_bank_kernel():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def kernel(q_vals, q_valid, inner_edges, s_unit):
        """(Q, F, L) padded query values + (Q, F, L) validity masks +
        (F, B-1) edges + (S, F, B) unit subset histograms
        -> (Q, S) mean per-feature cosine distances.

        bucketize (searchsorted) -> segment-sum count histograms ->
        L2-normalize -> one einsum of normalized dot products.
        """
        Q, F, L = q_vals.shape
        B = s_unit.shape[-1]
        bins = jnp.stack(
            [jnp.searchsorted(inner_edges[f], q_vals[:, f, :], side="right")
             for f in range(F)], axis=1)                       # (Q, F, L)
        flat = ((jnp.arange(Q)[:, None, None] * F
                 + jnp.arange(F)[None, :, None]) * B + bins)
        counts = jax.ops.segment_sum(
            q_valid.astype(jnp.float32).ravel(), flat.ravel(),
            num_segments=Q * F * B).reshape(Q, F, B)
        nrm = jnp.sqrt((counts * counts).sum(axis=-1, keepdims=True))
        unit = counts / jnp.maximum(nrm, 1e-30)
        sim = jnp.einsum("qfb,sfb->qsf", unit, s_unit)
        return (1.0 - sim).mean(axis=-1)                       # (Q, S)

    return kernel


class _LazyBankKernel:
    """Defer jax import/compile until the jax backend is first used."""

    def __init__(self):
        self._fn = None

    def __call__(self, *args):
        if self._fn is None:
            self._fn = _make_bank_kernel()
        return self._fn(*args)


_bank_kernel = _LazyBankKernel()


def _pack_queries(queries: Sequence) -> Tuple[np.ndarray, np.ndarray]:
    """Ragged (ii, oo, bb, thpt) query tuples -> fixed (Qp, 4, Lp)
    float32 values + validity masks, padded to powers of two so the
    jitted kernel compiles O(log Q * log L) shapes per process."""
    lens = [len(np.atleast_1d(q[0])) for q in queries]
    Lp = _pad_pow2(max(lens, default=1), 8)
    Qp = _pad_pow2(len(queries), 4)
    vals = np.zeros((Qp, len(FEATS), Lp), np.float32)
    valid = np.zeros((Qp, len(FEATS), Lp), bool)
    for qi, q in enumerate(queries):
        for fi in range(len(FEATS)):
            col = np.atleast_1d(np.asarray(q[fi], np.float64))
            finite = np.isfinite(col)
            vals[qi, fi, :len(col)] = np.where(finite, col, 0.0)
            valid[qi, fi, :len(col)] = finite
    return vals, valid


def bank_distances(bank: SubsetBank, queries: Sequence,
                   backend: str = "jax") -> np.ndarray:
    """Full (n_queries, n_subsets) cosine-distance matrix.

    ``backend="jax"`` runs the jitted kernel in one call;
    ``backend="numpy"`` is the serial float64 reference (loops every
    (query, subset) pair) that the kernel must match to <= 1e-6.
    Invalid subsets (< MIN_SUBSET_ROWS rows) still get columns — mask
    with ``bank.valid`` before reducing (``bank_confidence`` does).
    """
    Q, S = len(queries), bank.n_subsets
    if Q == 0:
        return np.zeros((0, S))
    if backend == "jax":
        vals, valid = _pack_queries(queries)
        # pad the subset dim so banks growing across online epochs reuse
        # the compiled kernel; per-(query, subset) dots are independent,
        # so the padding columns are exact and sliced away
        Sp = _pad_pow2(S, 8)
        unit = (np.pad(bank.unit, [(0, Sp - S), (0, 0), (0, 0)])
                if Sp != S else bank.unit)
        D = np.asarray(_bank_kernel(vals, valid, bank.inner_edges,
                                    unit), np.float64)
        return D[:Q, :S]
    D = np.empty((Q, S), np.float64)
    for qi, q in enumerate(queries):
        qh = np.stack([_count_hist(np.atleast_1d(q[fi]), bank.inner_edges[fi],
                                   bank.n_bins)
                       for fi in range(len(FEATS))])           # (4, B)
        for si in range(S):
            D[qi, si] = np.mean([_cosine_distance(qh[fi], bank.hist[si, fi])
                                 for fi in range(len(FEATS))])
    return D


def bank_confidence(bank: SubsetBank, queries: Sequence,
                    backend: str = "jax", hw_dist=0.0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """(d_min, confidence) vectors over queries; degenerate banks (no
    valid subset) yield the explicit (inf, 0.0) sentinel per query.

    ``hw_dist`` (scalar or per-query vector) is the hardware-descriptor
    distance of the queried hardware from the benchmarked hardware; the
    reported ``d_min`` stays the pure workload distance while the
    confidence squashes ``d_min + HW_DIST_WEIGHT * hw_dist``."""
    D = bank_distances(bank, queries, backend=backend)
    return dmin_confidence(D, bank.valid, hw_dist=hw_dist)


def dmin_confidence(D: np.ndarray, valid: np.ndarray, hw_dist=0.0
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Reduce a (Q, S) distance matrix over the valid subsets."""
    Q = D.shape[0]
    Dv = D[:, np.asarray(valid, bool)]
    if Dv.shape[1] == 0:
        d_min = np.full(Q, np.inf)
    else:
        d_min = Dv.min(axis=1)
    d_eff = d_min + HW_DIST_WEIGHT * np.asarray(hw_dist, np.float64)
    conf = np.where(np.isfinite(d_eff), 1.0 / (1.0 + d_eff), 0.0)
    return d_min, conf
