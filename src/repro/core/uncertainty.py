"""Uncertainty quantification (paper Alg 8).

Confidence c = 1 / (1 + d_min), where d_min is the minimum over logged SA
subsets of the average per-feature *histogram cosine distance* between the
new workload's (ii, oo, bb, thpt) distribution and the subset's rows.
Workload features are histogrammed in log space (they span decades);
throughput in linear space over the union range.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.annealing import SALog, subset_mask

N_HIST_BINS = 16
FEATS = ("ii", "oo", "bb", "thpt")


def _feature_bins(ref: Dict[str, np.ndarray],
                  new: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    bins = {}
    for f in FEATS:
        allv = np.concatenate([ref[f], new[f]]).astype(np.float64)
        if f == "thpt":
            lo, hi = float(allv.min()), float(allv.max())
            hi = hi if hi > lo else lo + 1.0
            bins[f] = np.linspace(lo, hi, N_HIST_BINS + 1)
        else:
            lo = max(float(allv.min()), 1e-9)
            hi = max(float(allv.max()), lo * (1 + 1e-9))
            bins[f] = np.geomspace(lo, hi * (1 + 1e-9), N_HIST_BINS + 1)
    return bins


def _hist(vals: np.ndarray, edges: np.ndarray) -> np.ndarray:
    h, _ = np.histogram(np.asarray(vals, np.float64), bins=edges)
    h = h.astype(np.float64)
    s = h.sum()
    return h / s if s > 0 else h


def _cosine_distance(u: np.ndarray, v: np.ndarray) -> float:
    nu, nv = np.linalg.norm(u), np.linalg.norm(v)
    if nu == 0 or nv == 0:
        return 1.0
    return float(1.0 - np.dot(u, v) / (nu * nv))


def workload_distance(ref_rows: Dict[str, np.ndarray],
                      new_rows: Dict[str, np.ndarray]) -> float:
    """Average per-feature histogram cosine distance between two row sets."""
    bins = _feature_bins(ref_rows, new_rows)
    ds = []
    for f in FEATS:
        ds.append(_cosine_distance(_hist(ref_rows[f], bins[f]),
                                   _hist(new_rows[f], bins[f])))
    return float(np.mean(ds))


def confidence(train, log: SALog, new,
               max_subsets: int = 200) -> Tuple[float, float]:
    """Alg 8 lines 4-6: (d_min, confidence) for a new workload.

    ``train``/``new`` are (ii, oo, bb, thpt) tuples; logged subsets are
    materialized as row-sets of the training data they selected.
    """
    ii, oo, bb, thpt = train
    nii, noo, nbb, nthpt = new
    new_rows = {"ii": nii, "oo": noo, "bb": nbb, "thpt": nthpt}
    subsets = log.subsets[-max_subsets:]
    d_min = np.inf
    for s in subsets:
        m = subset_mask(ii, oo, bb, s)
        if m.sum() < 2:
            continue
        ref_rows = {"ii": ii[m], "oo": oo[m], "bb": bb[m], "thpt": thpt[m]}
        d = workload_distance(ref_rows, new_rows)
        d_min = min(d_min, d)
    if not np.isfinite(d_min):
        d_min = 1.0
    return float(d_min), float(1.0 / (1.0 + d_min))
