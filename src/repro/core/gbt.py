"""Histogram gradient-boosted trees, from scratch (XGBoost stand-in).

Second-order boosting in the XGBoost sense [Chen & Guestrin, KDD'16]:
quantile-binned features, per-node gradient/hessian histograms, gain
  0.5 * (GL^2/(HL+l) + GR^2/(HR+l) - G^2/(H+l))
shrinkage, row subsampling, and hessian-weighted leaves.  Level-wise
growth, fully vectorized over nodes with ``np.add.at`` histograms; the
Pallas ``gbt_hist`` kernel provides the TPU path for the same histogram
build (``use_kernel=True`` routes through it in interpret/jnp form).

This is the learning component of ALA (paper Alg 3/7) and of the RF/GB
baselines (Fig 7).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Tree:
    feature: np.ndarray      # (n_nodes,) int32, -1 for leaf
    threshold: np.ndarray    # (n_nodes,) int32 bin id: go left if bin <= thr
    left: np.ndarray         # (n_nodes,) int32
    right: np.ndarray        # (n_nodes,) int32
    value: np.ndarray        # (n_nodes,) float32 leaf values

    def predict_bins(self, bins: np.ndarray) -> np.ndarray:
        node = np.zeros(bins.shape[0], dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            f = self.feature[node[active]]
            thr = self.threshold[node[active]]
            go_left = bins[active, f] <= thr
            nxt = np.where(go_left, self.left[node[active]],
                           self.right[node[active]])
            node[active] = nxt
            active = self.feature[node] >= 0
        return self.value[node]


class GBTRegressor:
    """Squared-error histogram GBT (see module docstring)."""

    def __init__(self, n_estimators: int = 200, learning_rate: float = 0.1,
                 max_depth: int = 4, n_bins: int = 64,
                 min_child_weight: float = 1.0, reg_lambda: float = 1.0,
                 subsample: float = 1.0, colsample: float = 1.0,
                 seed: int = 0, use_kernel: bool = False):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.n_bins = n_bins
        self.min_child_weight = min_child_weight
        self.reg_lambda = reg_lambda
        self.subsample = subsample
        self.colsample = colsample
        self.seed = seed
        self.use_kernel = use_kernel
        self.trees_: List[_Tree] = []
        self.base_: float = 0.0
        self.bin_edges_: Optional[np.ndarray] = None

    # -- binning -------------------------------------------------------------
    def _fit_bins(self, X: np.ndarray) -> np.ndarray:
        n, f = X.shape
        qs = np.linspace(0, 1, self.n_bins + 1)[1:-1]
        edges = np.quantile(X, qs, axis=0).T        # (f, n_bins-1)
        # dedupe per-feature edges to keep monotonicity
        self.bin_edges_ = edges
        return self._transform_bins(X)

    def _transform_bins(self, X: np.ndarray) -> np.ndarray:
        bins = np.empty(X.shape, dtype=np.int32)
        for j in range(X.shape[1]):
            bins[:, j] = np.searchsorted(self.bin_edges_[j], X[:, j],
                                         side="right")
        return bins

    # -- histogram -----------------------------------------------------------
    def _histograms(self, bins, grad, hess, node_id, n_nodes):
        """(n_nodes, f, n_bins, 2) gradient/hessian histograms."""
        n, f = bins.shape
        if self.use_kernel and n_nodes == 1:
            from repro.kernels.gbt_hist import ops as gh_ops
            h = np.asarray(gh_ops.build_histograms(
                bins, grad.astype(np.float32), hess.astype(np.float32),
                n_bins=self.n_bins, force="interpret"))
            return h[None]
        hist = np.zeros((n_nodes, f, self.n_bins, 2), np.float64)
        fidx = np.broadcast_to(np.arange(f)[None, :], bins.shape)
        nidx = np.broadcast_to(node_id[:, None], bins.shape)
        np.add.at(hist, (nidx, fidx, bins, 0),
                  np.broadcast_to(grad[:, None], bins.shape))
        np.add.at(hist, (nidx, fidx, bins, 1),
                  np.broadcast_to(hess[:, None], bins.shape))
        return hist

    # -- single tree ----------------------------------------------------------
    def _grow_tree(self, bins, grad, hess, rng) -> _Tree:
        n, f = bins.shape
        feat_mask = np.ones(f, bool)
        if self.colsample < 1.0:
            k = max(1, int(round(self.colsample * f)))
            feat_mask[:] = False
            feat_mask[rng.choice(f, size=k, replace=False)] = True

        max_nodes = 2 ** (self.max_depth + 1) - 1
        feature = np.full(max_nodes, -1, np.int32)
        threshold = np.zeros(max_nodes, np.int32)
        left = np.zeros(max_nodes, np.int32)
        right = np.zeros(max_nodes, np.int32)
        value = np.zeros(max_nodes, np.float32)
        node_of_row = np.zeros(n, np.int32)   # index into current level list
        # current level: list of node ids; rows hold level-local index
        level_nodes = [0]
        next_free = 1
        lam = self.reg_lambda

        for depth in range(self.max_depth + 1):
            n_level = len(level_nodes)
            if n_level == 0:
                break
            hist = self._histograms(bins, grad, hess, node_of_row, n_level)
            G = hist[..., 0].sum(axis=2)      # (n_level, f) totals per feat
            H = hist[..., 1].sum(axis=2)
            Gtot, Htot = G[:, 0], H[:, 0]
            leaf_val = -Gtot / (Htot + lam)

            if depth == self.max_depth:
                for li, nid in enumerate(level_nodes):
                    value[nid] = leaf_val[li]
                break

            GL = np.cumsum(hist[..., 0], axis=2)   # (n_level, f, n_bins)
            HL = np.cumsum(hist[..., 1], axis=2)
            GR = Gtot[:, None, None] - GL
            HR = Htot[:, None, None] - HL
            gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                          - (Gtot ** 2 / (Htot + lam))[:, None, None])
            ok = (HL >= self.min_child_weight) & (HR >= self.min_child_weight)
            ok &= feat_mask[None, :, None]
            ok[..., -1] = False                     # right side must be non-empty
            gain = np.where(ok, gain, -np.inf)
            flat = gain.reshape(n_level, -1)
            best = flat.argmax(axis=1)
            best_gain = flat[np.arange(n_level), best]
            best_f = (best // self.n_bins).astype(np.int32)
            best_b = (best % self.n_bins).astype(np.int32)

            new_level = []
            remap = np.full(n_level, -1, np.int32)  # level idx -> keeps rows
            child_base = {}
            for li, nid in enumerate(level_nodes):
                if not np.isfinite(best_gain[li]) or best_gain[li] <= 1e-12:
                    value[nid] = leaf_val[li]
                    continue
                feature[nid] = best_f[li]
                threshold[nid] = best_b[li]
                left[nid] = next_free
                right[nid] = next_free + 1
                child_base[li] = len(new_level)
                new_level.extend([next_free, next_free + 1])
                next_free += 2

            if not new_level:
                break
            # reassign rows to level-local indices of the next level
            new_node_of_row = np.full(len(node_of_row), -1, np.int32)
            for li in child_base:
                rows = node_of_row == li
                go_left = bins[rows, best_f[li]] <= best_b[li]
                new_node_of_row[rows] = child_base[li] + (~go_left)
            keep = new_node_of_row >= 0
            bins, grad, hess = bins[keep], grad[keep], hess[keep]
            node_of_row = new_node_of_row[keep]
            level_nodes = new_level

        return _Tree(feature=feature[:next_free],
                     threshold=threshold[:next_free],
                     left=left[:next_free], right=right[:next_free],
                     value=value[:next_free])

    # -- public API -------------------------------------------------------------
    def fit(self, X: np.ndarray, y: np.ndarray) -> "GBTRegressor":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        assert X.ndim == 2 and y.shape == (X.shape[0],)
        rng = np.random.default_rng(self.seed)
        bins = self._fit_bins(X)
        self.base_ = float(y.mean()) if len(y) else 0.0
        pred = np.full_like(y, self.base_)
        self.trees_ = []
        for t in range(self.n_estimators):
            grad = pred - y
            hess = np.ones_like(y)
            if self.subsample < 1.0:
                take = rng.random(len(y)) < self.subsample
                if take.sum() < 2:
                    take[:] = True
            else:
                take = slice(None)
            tree = self._grow_tree(bins[take], grad[take], hess[take], rng)
            self.trees_.append(tree)
            pred += self.learning_rate * tree.predict_bins(bins)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float64)
        bins = self._transform_bins(X)
        out = np.full(X.shape[0], self.base_, np.float64)
        for tree in self.trees_:
            out += self.learning_rate * tree.predict_bins(bins)
        return out


class MultiOutputGBT:
    """One GBTRegressor per target column (paper: MultiOutputRegressor)."""

    def __init__(self, n_outputs: int, **kw):
        seed = kw.pop("seed", 0)
        self.models = [GBTRegressor(seed=seed + i, **kw)
                       for i in range(n_outputs)]

    def fit(self, X, Y):
        Y = np.asarray(Y)
        for i, m in enumerate(self.models):
            m.fit(X, Y[:, i])
        return self

    def predict(self, X):
        return np.stack([m.predict(X) for m in self.models], axis=1)


class RandomForestRegressor:
    """Bagged depth-unlimited-ish trees (baseline #3 in Fig 7)."""

    def __init__(self, n_estimators: int = 100, max_depth: int = 8,
                 n_bins: int = 64, subsample: float = 0.8,
                 colsample: float = 0.8, seed: int = 0):
        self.kw = dict(n_estimators=1, learning_rate=1.0,
                       max_depth=max_depth, n_bins=n_bins,
                       min_child_weight=1.0, reg_lambda=1e-6)
        self.n_estimators = n_estimators
        self.subsample = subsample
        self.colsample = colsample
        self.seed = seed
        self.members_: List[GBTRegressor] = []

    def fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        n = len(y)
        self.members_ = []
        for i in range(self.n_estimators):
            idx = rng.integers(0, n, size=n)      # bootstrap
            m = GBTRegressor(seed=self.seed + i, subsample=1.0,
                             colsample=self.colsample, **self.kw)
            m.fit(X[idx], y[idx])
            self.members_.append(m)
        return self

    def predict(self, X):
        return np.mean([m.predict(X) for m in self.members_], axis=0)


class LinearRegression:
    """Ordinary least squares via normal equations (baseline #1)."""

    def fit(self, X, y):
        X = np.asarray(X, np.float64)
        Xb = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        self.coef_, *_ = np.linalg.lstsq(Xb, np.asarray(y, np.float64),
                                         rcond=None)
        return self

    def predict(self, X):
        X = np.asarray(X, np.float64)
        Xb = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        return Xb @ self.coef_
