"""Histogram gradient-boosted trees, from scratch (XGBoost stand-in).

Second-order boosting in the XGBoost sense [Chen & Guestrin, KDD'16]:
quantile-binned features, per-node gradient/hessian histograms, gain
  0.5 * (GL^2/(HL+l) + GR^2/(HR+l) - G^2/(H+l))
shrinkage, row subsampling, and hessian-weighted leaves.  Level-wise
growth, fully vectorized over nodes; the Pallas ``gbt_hist`` kernel
provides the TPU path for the same histogram build (``use_kernel=True``
routes every level's build through it instead of the host scatter-add).

Two training paths produce identical trees:

  * ``GBTRegressor.fit`` — the original single-model path (supports
    row/column subsampling).
  * ``fit_packed_forest`` — a *batched* trainer that grows the forests
    of many (candidate, output) problems in lockstep, vectorizing the
    histogram/gain/split math across all of them.  Excluded rows carry
    zero gradient/hessian weight, which leaves every sum bitwise
    unchanged, so the trees match the per-model path exactly.

Fitted trees flatten into ``PackedForest`` arrays and predict through a
jit'd ``jax.vmap`` gather traversal (``backend="jax"``) — the inference
path the batched annealing engine uses.

This is the learning component of ALA (paper Alg 3/7) and of the RF/GB
baselines (Fig 7).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Tree:
    feature: np.ndarray      # (n_nodes,) int32, -1 for leaf
    threshold: np.ndarray    # (n_nodes,) int32 bin id: go left if bin <= thr
    left: np.ndarray         # (n_nodes,) int32
    right: np.ndarray        # (n_nodes,) int32
    value: np.ndarray        # (n_nodes,) float32 leaf values

    def predict_bins(self, bins: np.ndarray) -> np.ndarray:
        node = np.zeros(bins.shape[0], dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            f = self.feature[node[active]]
            thr = self.threshold[node[active]]
            go_left = bins[active, f] <= thr
            nxt = np.where(go_left, self.left[node[active]],
                           self.right[node[active]])
            node[active] = nxt
            active = self.feature[node] >= 0
        return self.value[node]


class GBTRegressor:
    """Squared-error histogram GBT (see module docstring)."""

    def __init__(self, n_estimators: int = 200, learning_rate: float = 0.1,
                 max_depth: int = 4, n_bins: int = 64,
                 min_child_weight: float = 1.0, reg_lambda: float = 1.0,
                 subsample: float = 1.0, colsample: float = 1.0,
                 seed: int = 0, use_kernel: bool = False):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.n_bins = n_bins
        self.min_child_weight = min_child_weight
        self.reg_lambda = reg_lambda
        self.subsample = subsample
        self.colsample = colsample
        self.seed = seed
        self.use_kernel = use_kernel
        self.trees_: List[_Tree] = []
        self.base_: float = 0.0
        self.bin_edges_: Optional[np.ndarray] = None

    # -- binning -------------------------------------------------------------
    def _fit_bins(self, X: np.ndarray) -> np.ndarray:
        n, f = X.shape
        qs = np.linspace(0, 1, self.n_bins + 1)[1:-1]
        edges = np.quantile(X, qs, axis=0).T        # (f, n_bins-1)
        # dedupe per-feature edges to keep monotonicity
        self.bin_edges_ = edges
        return self._transform_bins(X)

    def _transform_bins(self, X: np.ndarray) -> np.ndarray:
        bins = np.empty(X.shape, dtype=np.int32)
        for j in range(X.shape[1]):
            bins[:, j] = np.searchsorted(self.bin_edges_[j], X[:, j],
                                         side="right")
        return bins

    # -- histogram -----------------------------------------------------------
    def _histograms(self, bins, grad, hess, node_id, n_nodes):
        """(n_nodes, f, n_bins, 2) gradient/hessian histograms."""
        n, f = bins.shape
        if self.use_kernel:
            return kernel_histograms(bins, grad, hess, node_id, n_nodes,
                                     self.n_bins)
        hist = np.zeros((n_nodes, f, self.n_bins, 2), np.float64)
        fidx = np.broadcast_to(np.arange(f)[None, :], bins.shape)
        nidx = np.broadcast_to(node_id[:, None], bins.shape)
        np.add.at(hist, (nidx, fidx, bins, 0),
                  np.broadcast_to(grad[:, None], bins.shape))
        np.add.at(hist, (nidx, fidx, bins, 1),
                  np.broadcast_to(hess[:, None], bins.shape))
        return hist

    # -- single tree ----------------------------------------------------------
    def _grow_tree(self, bins, grad, hess, rng) -> _Tree:
        n, f = bins.shape
        feat_mask = np.ones(f, bool)
        if self.colsample < 1.0:
            k = max(1, int(round(self.colsample * f)))
            feat_mask[:] = False
            feat_mask[rng.choice(f, size=k, replace=False)] = True

        max_nodes = 2 ** (self.max_depth + 1) - 1
        feature = np.full(max_nodes, -1, np.int32)
        threshold = np.zeros(max_nodes, np.int32)
        left = np.zeros(max_nodes, np.int32)
        right = np.zeros(max_nodes, np.int32)
        value = np.zeros(max_nodes, np.float32)
        node_of_row = np.zeros(n, np.int32)   # index into current level list
        # current level: list of node ids; rows hold level-local index
        level_nodes = [0]
        next_free = 1
        lam = self.reg_lambda

        for depth in range(self.max_depth + 1):
            n_level = len(level_nodes)
            if n_level == 0:
                break
            hist = self._histograms(bins, grad, hess, node_of_row, n_level)
            G = hist[..., 0].sum(axis=2)      # (n_level, f) totals per feat
            H = hist[..., 1].sum(axis=2)
            Gtot, Htot = G[:, 0], H[:, 0]
            leaf_val = -Gtot / (Htot + lam)

            if depth == self.max_depth:
                for li, nid in enumerate(level_nodes):
                    value[nid] = leaf_val[li]
                break

            GL = np.cumsum(hist[..., 0], axis=2)   # (n_level, f, n_bins)
            HL = np.cumsum(hist[..., 1], axis=2)
            GR = Gtot[:, None, None] - GL
            HR = Htot[:, None, None] - HL
            gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                          - (Gtot ** 2 / (Htot + lam))[:, None, None])
            ok = (HL >= self.min_child_weight) & (HR >= self.min_child_weight)
            ok &= feat_mask[None, :, None]
            ok[..., -1] = False                     # right side must be non-empty
            gain = np.where(ok, gain, -np.inf)
            flat = gain.reshape(n_level, -1)
            best = flat.argmax(axis=1)
            best_gain = flat[np.arange(n_level), best]
            best_f = (best // self.n_bins).astype(np.int32)
            best_b = (best % self.n_bins).astype(np.int32)

            new_level = []
            remap = np.full(n_level, -1, np.int32)  # level idx -> keeps rows
            child_base = {}
            for li, nid in enumerate(level_nodes):
                if not np.isfinite(best_gain[li]) or best_gain[li] <= 1e-12:
                    value[nid] = leaf_val[li]
                    continue
                feature[nid] = best_f[li]
                threshold[nid] = best_b[li]
                left[nid] = next_free
                right[nid] = next_free + 1
                child_base[li] = len(new_level)
                new_level.extend([next_free, next_free + 1])
                next_free += 2

            if not new_level:
                break
            # reassign rows to level-local indices of the next level
            new_node_of_row = np.full(len(node_of_row), -1, np.int32)
            for li in child_base:
                rows = node_of_row == li
                go_left = bins[rows, best_f[li]] <= best_b[li]
                new_node_of_row[rows] = child_base[li] + (~go_left)
            keep = new_node_of_row >= 0
            bins, grad, hess = bins[keep], grad[keep], hess[keep]
            node_of_row = new_node_of_row[keep]
            level_nodes = new_level

        return _Tree(feature=feature[:next_free],
                     threshold=threshold[:next_free],
                     left=left[:next_free], right=right[:next_free],
                     value=value[:next_free])

    # -- public API -------------------------------------------------------------
    def fit(self, X: np.ndarray, y: np.ndarray) -> "GBTRegressor":
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        assert X.ndim == 2 and y.shape == (X.shape[0],)
        rng = np.random.default_rng(self.seed)
        bins = self._fit_bins(X)
        self.base_ = float(y.mean()) if len(y) else 0.0
        pred = np.full_like(y, self.base_)
        self.trees_ = []
        self._packed = None
        for t in range(self.n_estimators):
            grad = pred - y
            hess = np.ones_like(y)
            if self.subsample < 1.0:
                take = rng.random(len(y)) < self.subsample
                if take.sum() < 2:
                    take[:] = True
            else:
                take = slice(None)
            tree = self._grow_tree(bins[take], grad[take], hess[take], rng)
            self.trees_.append(tree)
            pred += self.learning_rate * tree.predict_bins(bins)
        return self

    def predict(self, X: np.ndarray, backend: str = "numpy") -> np.ndarray:
        """Predict; ``backend="jax"`` flattens the forest once and runs the
        jit'd vmap/gather traversal (``PackedForest``)."""
        X = np.asarray(X, np.float64)
        if backend == "jax":
            packed = getattr(self, "_packed", None)
            if packed is None:
                packed = self._packed = pack_models([[self]])
            return packed.predict(X[None], backend="jax")[0, :, 0]
        bins = self._transform_bins(X)
        out = np.full(X.shape[0], self.base_, np.float64)
        for tree in self.trees_:
            out += self.learning_rate * tree.predict_bins(bins)
        return out


class MultiOutputGBT:
    """One GBTRegressor per target column (paper: MultiOutputRegressor).

    When no row/column subsampling is configured, ``fit`` grows all
    output forests jointly through ``fit_packed_forest`` (identical
    trees, one pass of vectorized level-wise growth instead of
    ``n_outputs`` sequential fits).
    """

    def __init__(self, n_outputs: int, **kw):
        seed = kw.pop("seed", 0)
        self.models = [GBTRegressor(seed=seed + i, **kw)
                       for i in range(n_outputs)]

    def fit(self, X, Y, joint: Optional[bool] = None):
        Y = np.asarray(Y)
        can_joint = all(m.subsample >= 1.0 and m.colsample >= 1.0
                        for m in self.models)
        if joint is None:
            joint = can_joint
        if not (joint and can_joint and len(self.models)):
            for i, m in enumerate(self.models):
                m.fit(X, Y[:, i])
            return self
        m0 = self.models[0]
        forest = fit_packed_forest(
            np.asarray(X, np.float64)[None], Y[None],
            n_estimators=m0.n_estimators, learning_rate=m0.learning_rate,
            max_depth=m0.max_depth, n_bins=m0.n_bins,
            min_child_weight=m0.min_child_weight, reg_lambda=m0.reg_lambda,
            use_kernel=m0.use_kernel)
        for o, m in enumerate(self.models):
            m.base_ = float(forest.base[0, o])
            m.bin_edges_ = forest.bin_edges[0].copy()
            m.trees_ = [
                _Tree(feature=forest.feature[0, o, t, :nn].copy(),
                      threshold=forest.threshold[0, o, t, :nn].copy(),
                      left=forest.left[0, o, t, :nn].copy(),
                      right=forest.right[0, o, t, :nn].copy(),
                      value=forest.value[0, o, t, :nn].copy())
                for t, nn in enumerate(forest.n_nodes[0, o])]
            m._packed = None
        return self

    def predict(self, X):
        return np.stack([m.predict(X) for m in self.models], axis=1)


class RandomForestRegressor:
    """Bagged depth-unlimited-ish trees (baseline #3 in Fig 7)."""

    def __init__(self, n_estimators: int = 100, max_depth: int = 8,
                 n_bins: int = 64, subsample: float = 0.8,
                 colsample: float = 0.8, seed: int = 0):
        self.kw = dict(n_estimators=1, learning_rate=1.0,
                       max_depth=max_depth, n_bins=n_bins,
                       min_child_weight=1.0, reg_lambda=1e-6)
        self.n_estimators = n_estimators
        self.subsample = subsample
        self.colsample = colsample
        self.seed = seed
        self.members_: List[GBTRegressor] = []

    def fit(self, X, y):
        rng = np.random.default_rng(self.seed)
        n = len(y)
        self.members_ = []
        for i in range(self.n_estimators):
            idx = rng.integers(0, n, size=n)      # bootstrap
            m = GBTRegressor(seed=self.seed + i, subsample=1.0,
                             colsample=self.colsample, **self.kw)
            m.fit(X[idx], y[idx])
            self.members_.append(m)
        return self

    def predict(self, X):
        return np.mean([m.predict(X) for m in self.members_], axis=0)


# ---------------------------------------------------------------------------
# Packed forests: flattened tree arrays + batched training / jit inference
# ---------------------------------------------------------------------------

def kernel_histograms(bins, grad, hess, node_id, n_nodes, n_bins,
                      force: Optional[str] = None):
    """Per-node histograms through the Pallas ``gbt_hist`` op.

    Node separation happens inside the op (`build_node_histograms`) via
    zero-masked weights — a zero-weight row adds exactly 0.0 to every
    bin, which keeps the sums identical to the scatter-add path — so one
    XLA call covers the whole tree level.  Dispatch (kernel on TPU, jnp
    oracle elsewhere) lives in ``kernels.gbt_hist.ops``.
    """
    from repro.kernels.gbt_hist import ops as gh_ops
    n = bins.shape[0]
    # level-wise growth compacts rows, so n varies per (tree, level);
    # pad to the next power of two with zero weights to bound the jit'd
    # op to O(log n) compiled shapes instead of one per level
    n_pad = max(64, 1 << int(np.ceil(np.log2(max(n, 1)))))
    pad = n_pad - n
    bins32 = np.zeros((n_pad, bins.shape[1]), np.int32)
    bins32[:n] = bins
    g32 = np.zeros(n_pad, np.float32)
    g32[:n] = grad
    h32 = np.zeros(n_pad, np.float32)
    h32[:n] = hess
    nid = np.zeros(n_pad, np.int32)
    nid[:n] = node_id
    h = gh_ops.build_node_histograms(
        bins32, g32, h32, nid, n_nodes=n_nodes, n_bins=n_bins, force=force)
    return np.asarray(h, np.float64)


@dataclasses.dataclass
class PackedForest:
    """Fitted GBT forests flattened to arrays, batched over a grid of
    ``(C candidates, O outputs)`` independent models.

    ``feature[c, o, t, n] < 0`` marks node ``n`` of tree ``t`` as a leaf;
    internal nodes route rows left when ``bin <= threshold``.  This is
    the jit-compatible inference form: prediction is a fixed-depth
    gather traversal vmapped over trees, outputs, and candidates.
    """
    feature: np.ndarray     # (C, O, T, N) int32, -1 for leaf
    threshold: np.ndarray   # (C, O, T, N) int32 bin ids
    left: np.ndarray        # (C, O, T, N) int32
    right: np.ndarray       # (C, O, T, N) int32
    value: np.ndarray       # (C, O, T, N) float32 leaf values
    base: np.ndarray        # (C, O) float64
    bin_edges: np.ndarray   # (C, f, n_bins - 1) float64
    n_nodes: np.ndarray     # (C, O, T) int32 used-node counts
    learning_rate: float
    max_depth: int

    def transform_bins(self, X: np.ndarray) -> np.ndarray:
        """X: (C, m, f) raw features -> (C, m, f) int32 bin ids."""
        C, m, f = X.shape
        bins = np.empty((C, m, f), np.int32)
        for c in range(C):
            for j in range(f):
                bins[c, :, j] = np.searchsorted(self.bin_edges[c, j],
                                                X[c, :, j], side="right")
        return bins

    def predict(self, X: np.ndarray, backend: str = "jax") -> np.ndarray:
        """X: (C, m, f) -> (C, m, O) predictions.

        The jax path pads the row dimension to a power of two before the
        jitted traversal (per-row gathers, so padding is exact) — query
        counts that grow over online epochs reuse the compiled kernel."""
        bins = self.transform_bins(np.asarray(X, np.float64))
        if backend == "jax":
            from repro.core.fit import _pow2
            m = bins.shape[1]
            mp = _pow2(m, lo=8)
            if mp != m:
                bins = np.pad(bins, [(0, 0), (0, mp - m), (0, 0)])
            leaf = np.asarray(_forest_apply_jax(
                self.feature, self.threshold, self.left, self.right,
                self.value, bins, self.max_depth), np.float64)[..., :m]
        else:
            leaf = self._apply_numpy(bins)
        out = self.base[:, :, None] + self.learning_rate * leaf.sum(axis=2)
        return np.moveaxis(out, 1, 2)        # (C, m, O)

    def _apply_numpy(self, bins: np.ndarray) -> np.ndarray:
        """(C, O, T, m) leaf values via vectorized numpy traversal."""
        C, O, T, N = self.feature.shape
        m = bins.shape[1]
        out = np.empty((C, O, T, m), np.float64)
        for c in range(C):
            rows = bins[c]                                # (m, f)
            for o in range(O):
                nd = np.zeros((T, m), np.int64)
                ft = self.feature[c, o].astype(np.int64)  # (T, N)
                th = self.threshold[c, o]
                lf = self.left[c, o].astype(np.int64)
                rt = self.right[c, o].astype(np.int64)
                for _ in range(self.max_depth + 1):
                    f_ = np.take_along_axis(ft, nd, 1)
                    isleaf = f_ < 0
                    rb = rows[np.arange(m)[None, :], np.maximum(f_, 0)]
                    go_left = rb <= np.take_along_axis(th, nd, 1)
                    nxt = np.where(go_left, np.take_along_axis(lf, nd, 1),
                                   np.take_along_axis(rt, nd, 1))
                    nd = np.where(isleaf, nd, nxt)
                out[c, o] = np.take_along_axis(
                    self.value[c, o].astype(np.float64), nd, 1)
        return out


def _forest_apply_jax(feature, threshold, left, right, value, bins,
                      max_depth: int):
    """Jit'd leaf lookup: (C, O, T, N) forests x (C, m, f) bins ->
    (C, O, T, m) leaf values.  vmap over candidates/outputs/trees; the
    traversal is ``max_depth + 1`` gather steps (leaves are absorbing)."""
    import jax

    return _forest_apply_jit(feature, threshold, left, right, value,
                             jax.numpy.asarray(bins), max_depth)


def _make_forest_apply():
    import functools

    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit, static_argnames=("max_depth",))
    def apply(feature, threshold, left, right, value, bins, max_depth):
        def one_tree(ft, th, lf, rt, vl, rows):
            nd = jnp.zeros(rows.shape[0], jnp.int32)
            for _ in range(max_depth + 1):
                f_ = ft[nd]
                isleaf = f_ < 0
                rb = jnp.take_along_axis(
                    rows, jnp.maximum(f_, 0)[:, None], axis=1)[:, 0]
                nxt = jnp.where(rb <= th[nd], lf[nd], rt[nd])
                nd = jnp.where(isleaf, nd, nxt)
            return vl[nd]

        over_t = jax.vmap(one_tree, in_axes=(0, 0, 0, 0, 0, None))
        over_o = jax.vmap(over_t, in_axes=(0, 0, 0, 0, 0, None))
        over_c = jax.vmap(over_o, in_axes=(0, 0, 0, 0, 0, 0))
        return over_c(feature, threshold, left, right, value, bins)

    return apply


class _LazyForestApply:
    """Defer jax import/compile until the jax backend is first used."""

    def __init__(self):
        self._fn = None

    def __call__(self, *args):
        if self._fn is None:
            self._fn = _make_forest_apply()
        return self._fn(*args)


_forest_apply_jit = _LazyForestApply()


def pack_models(models: List[List[GBTRegressor]]) -> PackedForest:
    """Flatten a (C, O) grid of fitted GBTRegressors into a PackedForest."""
    C, O = len(models), len(models[0])
    T = max(len(m.trees_) for row in models for m in row)
    N = max([1] + [len(t.feature) for row in models for m in row
                   for t in m.trees_])
    m0 = models[0][0]
    shape = (C, O, T, N)
    feature = np.full(shape, -1, np.int32)
    threshold = np.zeros(shape, np.int32)
    left = np.zeros(shape, np.int32)
    right = np.zeros(shape, np.int32)
    value = np.zeros(shape, np.float32)
    n_nodes = np.ones((C, O, T), np.int32)
    base = np.zeros((C, O), np.float64)
    edges = np.stack([row[0].bin_edges_ for row in models])
    for c, row in enumerate(models):
        for o, m in enumerate(row):
            base[c, o] = m.base_
            for t, tree in enumerate(m.trees_):
                nn = len(tree.feature)
                n_nodes[c, o, t] = nn
                feature[c, o, t, :nn] = tree.feature
                threshold[c, o, t, :nn] = tree.threshold
                left[c, o, t, :nn] = tree.left
                right[c, o, t, :nn] = tree.right
                value[c, o, t, :nn] = tree.value
    return PackedForest(feature=feature, threshold=threshold, left=left,
                        right=right, value=value, base=base,
                        bin_edges=edges, n_nodes=n_nodes,
                        learning_rate=m0.learning_rate,
                        max_depth=m0.max_depth)


def _joint_histograms(bins, grad, hess, node, nlvl, n_bins,
                      use_kernel=False):
    """(L, n, f) bins + (L, n) grad/hess + (L, n) level-local node ids ->
    (L, nlvl, f, n_bins) gradient and hessian histograms (bincount)."""
    L, n, f = bins.shape
    if use_kernel:
        hg = np.empty((L, nlvl, f, n_bins), np.float64)
        hh = np.empty((L, nlvl, f, n_bins), np.float64)
        for li in range(L):
            h = kernel_histograms(bins[li], grad[li], hess[li], node[li],
                                  nlvl, n_bins)
            hg[li] = h[..., 0]
            hh[li] = h[..., 1]
        return hg, hh
    size = L * nlvl * f * n_bins
    l_off = (np.arange(L, dtype=np.int64)
             * (nlvl * f * n_bins))[:, None, None]
    flat = ((node[:, :, None].astype(np.int64) * f
             + np.arange(f, dtype=np.int64)) * n_bins + bins + l_off)
    flat = flat.ravel()
    gw = np.broadcast_to(grad[:, :, None], (L, n, f)).ravel()
    hw = np.broadcast_to(hess[:, :, None], (L, n, f)).ravel()
    hist_g = np.bincount(flat, gw, minlength=size) \
        .reshape(L, nlvl, f, n_bins)
    hist_h = np.bincount(flat, hw, minlength=size) \
        .reshape(L, nlvl, f, n_bins)
    return hist_g, hist_h


def fit_packed_forest(X, Y, W=None, n_estimators: int = 100,
                      learning_rate: float = 0.1, max_depth: int = 4,
                      n_bins: int = 64, min_child_weight: float = 1.0,
                      reg_lambda: float = 1.0,
                      use_kernel: bool = False) -> PackedForest:
    """Grow GBT forests for a batch of problems in one vectorized pass.

    X: (C, n, f) features, Y: (C, n, O) targets, W: (C, n) 0/1 row
    weights (None = all rows).  All C x O forests grow level-by-level in
    lockstep; rows excluded by W (or parked at a finished leaf) keep
    zero gradient/hessian so every histogram sum matches the per-model
    ``GBTRegressor.fit`` bitwise.  Returns a ``PackedForest``.
    """
    X = np.asarray(X, np.float64)
    Y = np.asarray(Y, np.float64)
    assert X.ndim == 3 and Y.ndim == 3 and Y.shape[:2] == X.shape[:2]
    C, n, f = X.shape
    O = Y.shape[2]
    W = np.ones((C, n), np.float64) if W is None \
        else np.asarray(W, np.float64)
    L = C * O
    lam = reg_lambda

    # -- per-candidate quantile binning (masked rows excluded) --------------
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    Xm = np.where(W[:, :, None] > 0, X, np.nan)
    edges = np.moveaxis(np.nanquantile(Xm, qs, axis=1), 0, -1)  # (C, f, E)
    bins_c = np.empty((C, n, f), np.int32)
    for c in range(C):
        for j in range(f):
            bins_c[c, :, j] = np.searchsorted(edges[c, j], X[c, :, j],
                                              side="right")
    bins = np.repeat(bins_c, O, axis=0)                       # (L, n, f)
    yT = np.moveaxis(Y, 2, 1).reshape(L, n)                   # l = c*O + o
    Wl = np.repeat(W, O, axis=0)
    # mean over the *compacted* included rows: np.mean sums pairwise, so
    # a padded weighted sum can differ in the last ulp and flip a split
    base = np.array([yT[l, Wl[l] > 0].mean() if (Wl[l] > 0).any() else 0.0
                     for l in range(L)])
    pred = np.broadcast_to(base[:, None], (L, n)).copy()

    N = 2 ** (max_depth + 1) - 1
    F = np.full((L, n_estimators, N), -1, np.int32)
    TH = np.zeros((L, n_estimators, N), np.int32)
    LE = np.zeros((L, n_estimators, N), np.int32)
    RI = np.zeros((L, n_estimators, N), np.int32)
    V = np.zeros((L, n_estimators, N), np.float32)
    NN = np.ones((L, n_estimators), np.int32)

    for t in range(n_estimators):
        F_t, TH_t, LE_t, RI_t, V_t = (a[:, t] for a in (F, TH, LE, RI, V))
        alive = Wl > 0
        grad = (pred - yT) * alive
        hess = Wl * alive
        node = np.zeros((L, n), np.int64)
        gid = np.zeros((L, 1), np.int64)
        valid = np.ones((L, 1), bool)
        next_free = np.ones(L, np.int64)

        for depth in range(max_depth + 1):
            nlvl = gid.shape[1]
            hist_g, hist_h = _joint_histograms(bins, grad, hess, node,
                                               nlvl, n_bins, use_kernel)
            Gtot = hist_g.sum(axis=-1)[..., 0]        # (L, nlvl)
            Htot = hist_h.sum(axis=-1)[..., 0]
            leaf_val = -Gtot / (Htot + lam)
            if depth == max_depth:
                li, lj = np.nonzero(valid)
                V_t[li, gid[li, lj]] = leaf_val[li, lj]
                break
            GL = np.cumsum(hist_g, axis=-1)
            HL = np.cumsum(hist_h, axis=-1)
            GR = Gtot[..., None, None] - GL
            HR = Htot[..., None, None] - HL
            gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                          - (Gtot ** 2 / (Htot + lam))[..., None, None])
            ok = (HL >= min_child_weight) & (HR >= min_child_weight)
            ok[..., -1] = False
            gain = np.where(ok, gain, -np.inf)
            flat = gain.reshape(L, nlvl, f * n_bins)
            best = flat.argmax(axis=-1)
            best_gain = np.take_along_axis(flat, best[..., None],
                                           axis=-1)[..., 0]
            best_f = (best // n_bins).astype(np.int64)
            best_b = (best % n_bins).astype(np.int64)
            split = valid & np.isfinite(best_gain) & (best_gain > 1e-12)

            li, lj = np.nonzero(valid & ~split)
            V_t[li, gid[li, lj]] = leaf_val[li, lj]
            if not split.any():
                break
            k = np.cumsum(split, axis=1)
            n_new = 2 * k[:, -1]
            base_local = 2 * (k - 1)                  # child level index
            si, sj = np.nonzero(split)
            sg = gid[si, sj]
            F_t[si, sg] = best_f[si, sj].astype(np.int32)
            TH_t[si, sg] = best_b[si, sj].astype(np.int32)
            LE_t[si, sg] = (next_free[si] + base_local[si, sj]) \
                .astype(np.int32)
            RI_t[si, sg] = (next_free[si] + base_local[si, sj] + 1) \
                .astype(np.int32)
            new_nlvl = int(n_new.max())
            gid = next_free[:, None] + np.arange(new_nlvl)[None, :]
            valid = np.arange(new_nlvl)[None, :] < n_new[:, None]
            next_free = next_free + n_new

            rsplit = np.take_along_axis(split, node, axis=1)
            bf = np.take_along_axis(best_f, node, axis=1)
            bthr = np.take_along_axis(best_b, node, axis=1)
            rowbin = np.take_along_axis(bins, np.maximum(bf, 0)[..., None],
                                        axis=2)[..., 0]
            go_right = rowbin > bthr
            nbase = np.take_along_axis(base_local, node, axis=1)
            node = np.where(rsplit, nbase + go_right, 0)
            alive &= rsplit
            grad *= alive
            hess *= alive

        NN[:, t] = np.minimum(next_free, N).astype(np.int32)

        # boosting update on the training rows (fixed-depth traversal)
        nd = np.zeros((L, n), np.int64)
        ftl = F_t.astype(np.int64)
        lfl = LE_t.astype(np.int64)
        rtl = RI_t.astype(np.int64)
        for _ in range(max_depth + 1):
            f_ = np.take_along_axis(ftl, nd, axis=1)
            isleaf = f_ < 0
            rb = np.take_along_axis(bins, np.maximum(f_, 0)[..., None],
                                    axis=2)[..., 0]
            go_left = rb <= np.take_along_axis(TH_t, nd, axis=1)
            nxt = np.where(go_left, np.take_along_axis(lfl, nd, axis=1),
                           np.take_along_axis(rtl, nd, axis=1))
            nd = np.where(isleaf, nd, nxt)
        # lr * float32 leaves, matching GBTRegressor.fit's dtype exactly
        pred = pred + learning_rate * np.take_along_axis(V_t, nd, axis=1)

    def grid(a):
        return a.reshape(C, O, *a.shape[1:])

    return PackedForest(feature=grid(F), threshold=grid(TH), left=grid(LE),
                        right=grid(RI), value=grid(V), base=grid(base),
                        bin_edges=edges, n_nodes=grid(NN),
                        learning_rate=learning_rate, max_depth=max_depth)


class LinearRegression:
    """Ordinary least squares via normal equations (baseline #1)."""

    def fit(self, X, y):
        X = np.asarray(X, np.float64)
        Xb = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        self.coef_, *_ = np.linalg.lstsq(Xb, np.asarray(y, np.float64),
                                         rcond=None)
        return self

    def predict(self, X):
        X = np.asarray(X, np.float64)
        Xb = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        return Xb @ self.coef_
