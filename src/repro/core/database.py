"""Exponential parameter database (paper Alg 2).

For every unique (ii, oo) pair in a benchmark sub-dataset, fit the
exponential model parameters and store them in P (lookup) and T (training
rows for the parameter predictor).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.expmodel import exp_model, initial_params
from repro.core.fit import fit_exponential_groups


@dataclasses.dataclass
class ExpDatabase:
    params: Dict[Tuple[float, float], np.ndarray]  # (ii,oo) -> (a,b,c)
    training: np.ndarray                            # (n, 5): ii,oo,a,b,c

    def lookup(self, ii: float, oo: float) -> Optional[np.ndarray]:
        return self.params.get((float(ii), float(oo)))

    def __len__(self):
        return len(self.params)


def build_exponential_database(ii, oo, bb, thpt,
                               min_points: int = 1) -> Optional[ExpDatabase]:
    """Alg 2: group by unique (ii, oo), percentile-init, batched LM fit."""
    ii = np.asarray(ii, np.float64)
    oo = np.asarray(oo, np.float64)
    bb = np.asarray(bb, np.float64)
    thpt = np.asarray(thpt, np.float64)

    keys = np.stack([ii, oo], axis=1)
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    groups = []
    kept = []
    for g in range(len(uniq)):
        rows = inv == g
        if rows.sum() < min_points:
            continue
        gb, gt = bb[rows], thpt[rows]
        theta0 = initial_params(gb, gt)
        groups.append((gb, gt, theta0))
        kept.append(g)
    if not groups:
        return None
    theta = fit_exponential_groups(groups)
    # "optimization successful" filter: finite params + sane fit
    params: Dict[Tuple[float, float], np.ndarray] = {}
    training = []
    for (g, th) in zip(kept, theta):
        if not np.all(np.isfinite(th)):
            continue
        key = (float(uniq[g, 0]), float(uniq[g, 1]))
        params[key] = th
        training.append([key[0], key[1], th[0], th[1], th[2]])
    if not training:
        return None
    return ExpDatabase(params=params, training=np.asarray(training))


def update_exponential_database(prev: Optional[ExpDatabase],
                                ii, oo, bb, thpt, n_delta: int,
                                min_points: int = 1
                                ) -> Optional[ExpDatabase]:
    """Incremental Alg 2 after ``n_delta`` rows were *appended*.

    The vmapped LM fit is per-group independent (zero-weight padding
    rows contribute exact zeros), so only the (ii, oo) groups the delta
    touches need a refit — over their full rows, since an LM solve is
    not additive — and every untouched group's params are reused as-is.
    Output ordering (params insertion, training rows) follows the same
    lexicographic ``np.unique`` order as ``build_exponential_database``,
    so downstream predictor training sees identically-ordered input.
    ``prev=None`` (or a non-appended history) falls back to the full
    build.
    """
    if prev is None or n_delta >= len(np.atleast_1d(ii)):
        return build_exponential_database(ii, oo, bb, thpt,
                                          min_points=min_points)
    ii = np.asarray(ii, np.float64)
    oo = np.asarray(oo, np.float64)
    bb = np.asarray(bb, np.float64)
    thpt = np.asarray(thpt, np.float64)
    n_old = len(ii) - int(n_delta)
    touched = {(float(a), float(b))
               for a, b in zip(ii[n_old:], oo[n_old:])}

    keys = np.stack([ii, oo], axis=1)
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    counts = np.bincount(inv, minlength=len(uniq))
    groups, kept = [], []
    for g in range(len(uniq)):
        key = (float(uniq[g, 0]), float(uniq[g, 1]))
        if key not in touched:
            continue
        rows = inv == g
        if rows.sum() < min_points:
            continue
        gb, gt = bb[rows], thpt[rows]
        groups.append((gb, gt, initial_params(gb, gt)))
        kept.append(g)
    # pad to the full batch's max group size — zero-weight rows keep the
    # float32 reduction order of the full build, so the subset solve is
    # bit-identical to the fit a from-scratch build would produce
    # (fit_exponential_groups also pads the group dim to >= 2: a batch
    # of one fuses differently under XLA)
    theta_new = (fit_exponential_groups(groups,
                                        pad_to=int(counts.max()))[:len(kept)]
                 if groups else np.zeros((0, 3)))
    refit = {kept[j]: theta_new[j] for j in range(len(kept))}

    params: Dict[Tuple[float, float], np.ndarray] = {}
    training = []
    for g in range(len(uniq)):
        key = (float(uniq[g, 0]), float(uniq[g, 1]))
        if key in touched:
            th = refit.get(g)
            if th is None or not np.all(np.isfinite(th)):
                continue              # same drop rules as the full build
        else:
            th = prev.params.get(key)
            if th is None:            # previously dropped; rows unchanged
                continue
        params[key] = th
        training.append([key[0], key[1], th[0], th[1], th[2]])
    if not training:
        return None
    return ExpDatabase(params=params, training=np.asarray(training))


@dataclasses.dataclass
class GroupStructure:
    """Precomputed (ii, oo) group rectangles for repeated masked fits.

    Alg 2 groups rows by unique (ii, oo); when the same benchmark data is
    re-fit under many training subsets (Alg 6), the groups never change —
    only which rows are *included*.  Padding every group to ``maxn`` rows
    once lets each subset evaluation run as a fixed-shape weighted fit
    (`fit_exponential_masked`) instead of re-grouping and re-padding.
    """
    keys: np.ndarray        # (G, 2) unique (ii, oo), lexicographic
    bb: np.ndarray          # (G, maxn) padded batch sizes
    thpt: np.ndarray        # (G, maxn) padded throughputs
    row_w: np.ndarray       # (G, maxn) 1.0 for real rows, 0.0 for padding
    bb_codes: np.ndarray    # (G, maxn) int32 index into bb_universe
    bb_universe: np.ndarray  # (U,) sorted unique batch sizes
    bb_present: np.ndarray  # (G, U) bool: bb value occurs in group rows

    def __len__(self):
        return len(self.keys)


def build_group_structure(ii, oo, bb, thpt) -> GroupStructure:
    """Group rows by unique (ii, oo) and pad to rectangles (see above)."""
    ii = np.asarray(ii, np.float64)
    oo = np.asarray(oo, np.float64)
    bb = np.asarray(bb, np.float64)
    thpt = np.asarray(thpt, np.float64)
    keys = np.stack([ii, oo], axis=1)
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    G = len(uniq)
    counts = np.bincount(inv, minlength=G)
    maxn = int(counts.max()) if G else 0
    bb_u = np.unique(bb)
    order = np.argsort(inv, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)])
    bb_p = np.zeros((G, maxn), np.float64)
    th_p = np.zeros((G, maxn), np.float64)
    w_p = np.zeros((G, maxn), np.float64)
    code_p = np.zeros((G, maxn), np.int32)
    present = np.zeros((G, len(bb_u)), bool)
    codes = np.searchsorted(bb_u, bb)
    for g in range(G):
        rows = order[starts[g]:starts[g + 1]]
        n = len(rows)
        bb_p[g, :n] = bb[rows]
        th_p[g, :n] = thpt[rows]
        w_p[g, :n] = 1.0
        code_p[g, :n] = codes[rows]
        present[g, codes[rows]] = True
    return GroupStructure(keys=uniq, bb=bb_p, thpt=th_p, row_w=w_p,
                          bb_codes=code_p, bb_universe=bb_u,
                          bb_present=present)


def db_predict(db: ExpDatabase, ii: float, oo: float, bb) -> Optional[np.ndarray]:
    th = db.lookup(ii, oo)
    if th is None:
        return None
    return exp_model(np.asarray(bb, np.float64), *th)
