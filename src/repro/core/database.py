"""Exponential parameter database (paper Alg 2).

For every unique (ii, oo) pair in a benchmark sub-dataset, fit the
exponential model parameters and store them in P (lookup) and T (training
rows for the parameter predictor).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.expmodel import exp_model, initial_params
from repro.core.fit import fit_exponential_groups


@dataclasses.dataclass
class ExpDatabase:
    params: Dict[Tuple[float, float], np.ndarray]  # (ii,oo) -> (a,b,c)
    training: np.ndarray                            # (n, 5): ii,oo,a,b,c

    def lookup(self, ii: float, oo: float) -> Optional[np.ndarray]:
        return self.params.get((float(ii), float(oo)))

    def __len__(self):
        return len(self.params)


def build_exponential_database(ii, oo, bb, thpt,
                               min_points: int = 1) -> Optional[ExpDatabase]:
    """Alg 2: group by unique (ii, oo), percentile-init, batched LM fit."""
    ii = np.asarray(ii, np.float64)
    oo = np.asarray(oo, np.float64)
    bb = np.asarray(bb, np.float64)
    thpt = np.asarray(thpt, np.float64)

    keys = np.stack([ii, oo], axis=1)
    uniq, inv = np.unique(keys, axis=0, return_inverse=True)
    groups = []
    kept = []
    for g in range(len(uniq)):
        rows = inv == g
        if rows.sum() < min_points:
            continue
        gb, gt = bb[rows], thpt[rows]
        theta0 = initial_params(gb, gt)
        groups.append((gb, gt, theta0))
        kept.append(g)
    if not groups:
        return None
    theta = fit_exponential_groups(groups)
    # "optimization successful" filter: finite params + sane fit
    params: Dict[Tuple[float, float], np.ndarray] = {}
    training = []
    for (g, th) in zip(kept, theta):
        if not np.all(np.isfinite(th)):
            continue
        key = (float(uniq[g, 0]), float(uniq[g, 1]))
        params[key] = th
        training.append([key[0], key[1], th[0], th[1], th[2]])
    if not training:
        return None
    return ExpDatabase(params=params, training=np.asarray(training))


def db_predict(db: ExpDatabase, ii: float, oo: float, bb) -> Optional[np.ndarray]:
    th = db.lookup(ii, oo)
    if th is None:
        return None
    return exp_model(np.asarray(bb, np.float64), *th)
