"""Online incremental-refit engine: streaming ``Dataset`` deltas -> ALA.

The paper's framework assumes the benchmark database *grows*: parameters
are estimated for benchmarked workloads, then extended to unobserved
configurations — and the serving adapter
(``repro.serving.adapter.windows_to_dataset``) produces exactly such
growth, one steady-state window batch per simulated epoch.  ``OnlineALA``
closes the loop:

    trace epoch -> windows -> Dataset delta -> ingest() ->
        per-combination append -> drift check -> incremental refit ->
        autoscaler picks up the fresh fit on its next control tick

Incrementality, stage by stage:

  * **registry (Alg 4)** — only combinations whose data changed refit
    (``ModelRegistry.refit``); untouched combinations keep their models.
  * **SA (Alg 6)** — chains warm start from the combination's previous
    ``best_subset`` and run a short budget (``warm_iters``); proposals
    merge into the growing log (``annealing.merge_logs``) instead of
    replacing it.
  * **error model (Alg 7)** — retrains on the merged log (cheap).
  * **bank (Alg 8)** — per-row train/eval membership is drawn once when
    a row arrives and never redrawn, so the SA training rows are
    append-only and ``uncertainty.extend_bank`` updates histograms
    additively under the original fixed-bin contract.

Drift: before a combination's data is appended, the incoming delta is
scored against the *current* fit — Alg 8 confidence (collapse means the
new rows look unlike anything the SA log covered, e.g. out-of-range mass
in the reserved boundary bins) and the residual of the Alg 4/5 predictor
against the predicted error (growth means the model is wrong about a
region it claims to know).  The resulting ``DriftSignal`` is returned in
the ``RefitReport`` and consumed by
``repro.serving.autoscaler.ALAAutoscaler``, which can also force a
recalibration mid-run via ``request_refit``.

Robust ingestion: every delta passes a gate *before* drift detection or
any fit.  Non-finite / non-positive throughput rows are always
quarantined; with ``OnlineConfig.gate`` on, exact duplicates (telemetry
replays) and MAD robust-z outliers against the current registry fit are
quarantined too — corrupted telemetry can neither poison a refit nor
fake a ``DriftSignal``.  Refusals are logged in
``OnlineALA.quarantine`` (``QuarantineRecord``) and counted in
``RefitReport.n_quarantined``.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ala import ALA, ALAConfig
from repro.core.annealing import SAConfig, median_ape
from repro.core.dataset import Dataset
from repro.core.registry import DEFAULT_KEYS, ModelRegistry


@dataclasses.dataclass
class OnlineConfig:
    keys: Sequence[str] = DEFAULT_KEYS
    test_frac: float = 0.3            # per-row SA eval membership
    seed: int = 0
    min_rows: int = 8                 # below this: no uncertainty fit yet
    # SA budgets: full budget on a combination's first fit, short
    # warm-started budget on every incremental refit
    sa: SAConfig = dataclasses.field(default_factory=SAConfig)
    warm_iters: int = 20
    warm_chains: Optional[int] = None  # None -> sa.n_chains
    gbt_kw: dict = dataclasses.field(default_factory=dict)
    # refit policy: "changed" refits every combination whose data grew;
    # "drift" refits only drifted / forced / never-fitted ones
    refit: str = "changed"
    # robust-ingestion gate.  Non-finite / non-positive throughput rows
    # are ALWAYS quarantined (a single NaN silently poisons every
    # downstream fit); ``gate=True`` additionally rejects exact
    # duplicates (telemetry replays) and MAD robust-z outliers against
    # the combination's current registry fit — a row is an outlier only
    # if its log-residual z-score exceeds ``gate_z_max`` AND its
    # prediction ratio exceeds ``gate_min_ratio``, so a uniform drift
    # shift (small z) still passes and retrains the model
    gate: bool = False
    gate_z_max: float = 4.0
    gate_min_ratio: float = 5.0
    # drift thresholds (see DriftSignal)
    drift_conf_floor: float = 0.35
    drift_err_ratio: float = 3.0
    drift_min_ape: float = 10.0
    max_subsets: Optional[int] = None  # Alg 8 bank window (None -> default)


@dataclasses.dataclass
class DriftSignal:
    """How an incoming delta relates to the combination's current fit.

    ``confidence`` is the Alg 8 confidence of the delta as one query
    workload; ``pred_err`` the Alg 7 predicted error for it;
    ``resid_ape`` the realized median APE of the serving predictor
    (Alg 4/5) on the delta rows.  ``drifted`` is true on confidence
    collapse (< ``drift_conf_floor``) or residual growth
    (resid > ``drift_err_ratio`` x max(pred_err, ``drift_min_ape``)).
    New combinations report ``reason="new"`` and never count as drift.
    """
    combo: Tuple[str, ...]
    n_rows: int
    confidence: float = float("nan")
    pred_err: float = float("nan")
    resid_ape: float = float("nan")
    drifted: bool = False
    reason: str = ""


@dataclasses.dataclass
class QuarantineRecord:
    """One row the ingestion gate refused, and why."""
    epoch: int
    combo: Tuple[str, ...]
    reason: str                       # "nonfinite" | "duplicate" | "outlier"
    row: Dict


@dataclasses.dataclass
class RefitReport:
    epoch: int
    n_rows: int                                   # delta rows ingested
    changed: List[Tuple[str, ...]]                # combos with new data
    refit: List[Tuple[str, ...]]                  # combos actually refit
    skipped: List[Tuple[str, ...]]                # changed but not refit
    drift: Dict[Tuple[str, ...], DriftSignal]
    registry_s: float = 0.0
    uncertainty_s: float = 0.0
    wall_s: float = 0.0
    n_quarantined: int = 0                        # rows the gate refused


@dataclasses.dataclass
class _ComboState:
    data: Dataset
    test: np.ndarray                  # per-row eval membership, append-only
    rng: np.random.Generator          # draws membership for future appends
    ala: Optional[ALA] = None
    fitted_rows: int = 0              # rows covered by the registry model
    generation: int = 0               # bumps on every uncertainty refit


def _combo_seed(seed: int, combo: Tuple[str, ...]) -> int:
    # stable across processes (unlike hash()) and across the changing
    # set of live combinations (unlike enumeration order)
    return seed + zlib.crc32("\x1f".join(combo).encode())


class OnlineALA:
    """Streaming ALA over hardware/software combinations.

    ``ingest`` appends a ``Dataset`` delta per combination and refits
    incrementally; ``predict``/``estimate`` delegate to the underlying
    ``ModelRegistry`` exactly like the batch pipeline, so the engine is
    a drop-in for registry consumers that also want continuous
    recalibration.
    """

    def __init__(self, cfg: Optional[OnlineConfig] = None,
                 registry: Optional[ModelRegistry] = None,
                 audit: Optional[object] = None):
        self.cfg = cfg or OnlineConfig()
        self.registry = registry or ModelRegistry(keys=self.cfg.keys)
        # observability: a repro.obs.CalibrationAudit; every ingest
        # folds its RefitReport (drift / quarantine / refit events,
        # epoch clock) into the unified audit log
        self.audit = audit
        self.epoch = 0
        self.history: List[RefitReport] = []
        self.quarantine: List[QuarantineRecord] = []
        self._state: Dict[Tuple[str, ...], _ComboState] = {}
        self._keys: Optional[Tuple[str, ...]] = None
        self._forced: set = set()
        self._seen: Dict[Tuple[str, ...], set] = {}

    # -- delta plumbing ------------------------------------------------------
    def combo_of(self, row: Dict) -> Tuple[str, ...]:
        keys = self._keys or tuple(k for k in self.cfg.keys if k in row)
        return tuple(str(row[k]) for k in keys)

    def ala_for(self, combo: Sequence[str]) -> Optional[ALA]:
        st = self._state.get(tuple(str(v) for v in combo))
        return st.ala if st is not None else None

    def generation_of(self, combo: Sequence[str]) -> int:
        """Bumps on every uncertainty refit of the combination.  ALA
        objects refit *in place*, so identity checks can't detect a
        recalibration — consumers (the autoscaler) watch this counter to
        know when to reset evidence gathered against the old fit."""
        st = self._state.get(tuple(str(v) for v in combo))
        return st.generation if st is not None else 0

    def data_for(self, combo: Sequence[str]) -> Optional[Dataset]:
        st = self._state.get(tuple(str(v) for v in combo))
        return st.data if st is not None else None

    def request_refit(self, combo: Sequence[str]) -> None:
        """Force the combination to refit on the next ingest, regardless
        of the refit policy and of whether that ingest carries rows for
        it — the autoscaler's mid-run recalibration trigger."""
        self._forced.add(tuple(str(v) for v in combo))

    def _split_delta(self, delta: Dataset):
        keys = tuple(k for k in self.cfg.keys if k in delta.cols)
        if self._keys is None:
            self._keys = keys
        elif keys != self._keys:
            raise ValueError(f"delta key columns {keys} != the engine's "
                             f"{self._keys}")
        out = []
        for combo in sorted(delta.unique_combos(list(keys))):
            sub = delta
            for k, v in zip(keys, combo):
                sub = sub.mask(sub[k].astype(str) == v)
            out.append((tuple(str(v) for v in combo), sub))
        return out

    # -- robust-ingestion gate ----------------------------------------------
    def _gate(self, combo: Tuple[str, ...], sub: Dataset
              ) -> Tuple[Dataset, int]:
        """Filter a combination's delta before it can touch drift
        detection or any fit.  Always rejects non-finite / non-positive
        throughput and non-finite features; with ``cfg.gate`` also
        rejects exact duplicates and robust-z outliers (see
        ``OnlineConfig``).  Every rejected row lands in
        ``self.quarantine`` with its reason."""
        cfg = self.cfg
        ii, oo, bb, thpt = sub.workload
        n = len(sub)
        reason = [""] * n
        keep = (np.isfinite(ii) & np.isfinite(oo) & np.isfinite(bb)
                & np.isfinite(thpt) & (thpt > 0))
        for i in np.nonzero(~keep)[0]:
            reason[i] = "nonfinite"
        if cfg.gate:
            seen = self._seen.setdefault(combo, set())
            for i in range(n):
                if not keep[i]:
                    continue
                key = (float(ii[i]), float(oo[i]), float(bb[i]),
                       float(thpt[i]))
                if key in seen:
                    keep[i] = False
                    reason[i] = "duplicate"
                else:
                    seen.add(key)
            if keep.any() and combo in self.registry.combos:
                live = np.nonzero(keep)[0]
                with np.errstate(all="ignore"):
                    pred = np.asarray(
                        self.registry.predict(sub.mask(keep)), np.float64)
                    ok = np.isfinite(pred) & (pred > 0)
                    r = np.where(ok, np.log(thpt[live])
                                 - np.log(np.where(ok, pred, 1.0)), np.nan)
                    if ok.any():
                        med = float(np.median(r[ok]))
                        mad = float(np.median(np.abs(r[ok] - med)))
                        scale = max(1.4826 * mad, 1e-3)
                        z = np.abs(r - med) / scale
                        ratio = np.maximum(
                            thpt[live] / np.where(ok, pred, 1.0),
                            np.where(ok, pred, 1.0) / thpt[live])
                        bad = ok & (z > cfg.gate_z_max) \
                            & (ratio > cfg.gate_min_ratio)
                        for j in np.nonzero(bad)[0]:
                            i = int(live[j])
                            keep[i] = False
                            reason[i] = "outlier"
        dropped = np.nonzero(~keep)[0]
        for i in dropped:
            row = {k: (v[i].item() if isinstance(v[i], np.generic)
                       else v[i]) for k, v in sub.cols.items()}
            self.quarantine.append(QuarantineRecord(
                epoch=self.epoch, combo=combo, reason=reason[i], row=row))
        if len(dropped) == 0:
            return sub, 0
        return sub.mask(keep), int(len(dropped))

    # -- drift ---------------------------------------------------------------
    def _drift(self, combo: Tuple[str, ...], sub: Dataset) -> DriftSignal:
        st = self._state.get(combo)
        if st is None or st.ala is None:
            return DriftSignal(combo=combo, n_rows=len(sub), reason="new")
        cfg = self.cfg
        w = sub.workload
        err, _, conf = st.ala.estimate_batch([w], backend="numpy")
        pred_err, confidence = float(err[0]), float(conf[0])
        resid = float("nan")
        if combo in self.registry.combos:
            resid = median_ape(w[3], self.registry.predict(sub))
        collapse = confidence < cfg.drift_conf_floor
        growth = (np.isfinite(resid)
                  and resid > cfg.drift_err_ratio
                  * max(pred_err, cfg.drift_min_ape))
        reason = ("confidence_collapse" if collapse else
                  "residual_growth" if growth else "")
        return DriftSignal(combo=combo, n_rows=len(sub),
                           confidence=confidence, pred_err=pred_err,
                           resid_ape=resid, drifted=collapse or growth,
                           reason=reason)

    # -- the refit stages ----------------------------------------------------
    def _append(self, combo: Tuple[str, ...], sub: Dataset) -> None:
        st = self._state.get(combo)
        if st is None:
            rng = np.random.default_rng(_combo_seed(self.cfg.seed, combo))
            st = _ComboState(data=sub, test=np.zeros(0, bool), rng=rng)
            self._state[combo] = st
        else:
            st.data = st.data.concat(sub)
        # eval membership is drawn once per row, so the SA training rows
        # are append-only and the bank update stays additive
        st.test = np.concatenate(
            [st.test, st.rng.random(len(sub)) < self.cfg.test_frac])

    def _refit_uncertainty(self, combo: Tuple[str, ...]) -> bool:
        cfg = self.cfg
        st = self._state[combo]
        if len(st.data) < cfg.min_rows:
            return False
        te = st.test
        if (~te).sum() < 4 or te.sum() < 1:
            return False
        train = st.data.mask(~te).workload
        test = st.data.mask(te).workload
        if st.ala is None or st.ala.sa_log is None:
            ala_cfg = ALAConfig(sa=cfg.sa)
            if cfg.gbt_kw:
                ala_cfg.gbt_kw = dict(cfg.gbt_kw)
            ala = ALA(ala_cfg)
            ala.fit(*train)
            ala.explore(test)
            ala.fit_error()
            ala.bank(cfg.max_subsets)
            st.ala = ala
        else:
            st.ala.refit(train, test, n_iters=cfg.warm_iters,
                         n_chains=cfg.warm_chains)
        st.generation += 1
        self.registry.attach_ala(combo, st.ala)
        return True

    def ingest(self, delta: Dataset, **gbt_kw) -> RefitReport:
        """One online epoch: append the delta per combination, refit what
        changed (or drifted, under ``cfg.refit == "drift"``), return the
        report with per-combination drift signals."""
        t_all = time.perf_counter()
        self.epoch += 1
        parts = self._split_delta(delta)
        drift: Dict[Tuple[str, ...], DriftSignal] = {}
        changed: List[Tuple[str, ...]] = []
        n_quarantined = 0
        for combo, sub in parts:
            # gate FIRST: quarantined rows must not fake a DriftSignal
            # or reach any fit
            sub, n_q = self._gate(combo, sub)
            n_quarantined += n_q
            if len(sub) == 0:
                continue
            drift[combo] = self._drift(combo, sub)     # vs. the OLD fit
            self._append(combo, sub)
            changed.append(combo)

        if self.cfg.refit == "drift":
            to_refit = [c for c in changed
                        if drift[c].drifted or drift[c].reason == "new"
                        or c in self._forced]
        else:
            to_refit = list(changed)
        # a forced combination refits even with no delta this epoch —
        # skipped epochs may have accumulated rows it was never fit on,
        # and the request promised recalibration at the next ingest
        to_refit += sorted(c for c in self._forced
                           if c in self._state and c not in to_refit)
        self._forced -= set(to_refit)

        # Alg 4: serving predictors, changed combinations only.  Known
        # combinations update group-incrementally (only delta-touched
        # (ii, oo) groups re-solve); brand-new ones take the full fit.
        # n_delta counts every row since the registry model was last
        # fit — under refit="drift", skipped epochs accumulate rows the
        # next refit must treat as delta, not as already-fitted prefix.
        t0 = time.perf_counter()
        fresh = [c for c in to_refit if c not in self.registry.combos]
        for combo in to_refit:
            if combo in fresh:
                continue
            st = self._state[combo]
            self.registry.update_combo(combo, st.data.workload,
                                       len(st.data) - st.fitted_rows,
                                       **gbt_kw)
            st.fitted_rows = len(st.data)
        if fresh:
            full = None
            for combo in fresh:
                d = self._state[combo].data
                full = d if full is None else full.concat(d)
            self.registry.refit(full, combos=fresh, **gbt_kw)
            for combo in fresh:
                st = self._state[combo]
                st.fitted_rows = len(st.data)
        registry_s = time.perf_counter() - t0

        # Alg 6-8: warm-started uncertainty refits
        t0 = time.perf_counter()
        refit = [c for c in to_refit if self._refit_uncertainty(c)]
        uncertainty_s = time.perf_counter() - t0

        report = RefitReport(
            epoch=self.epoch, n_rows=len(delta), changed=changed,
            refit=refit, skipped=[c for c in changed if c not in refit],
            drift=drift, registry_s=registry_s,
            uncertainty_s=uncertainty_s,
            wall_s=time.perf_counter() - t_all,
            n_quarantined=n_quarantined)
        self.history.append(report)
        if self.audit is not None:
            self.audit.ingest_report(report)
        return report

    # -- serving-side reads --------------------------------------------------
    def predict(self, data: Dataset) -> np.ndarray:
        return self.registry.predict(data)

    def estimate(self, data: Dataset, backend: str = "jax"):
        return self.registry.estimate(data, backend=backend)

    @property
    def combos(self):
        return sorted(self._state)

    def full_data(self) -> Dataset:
        """Every ingested row, concatenated in combination order — what a
        from-scratch ``ModelRegistry.fit`` would see (the parity probe
        the benchmark uses)."""
        out = None
        for combo in self.combos:
            d = self._state[combo].data
            out = d if out is None else out.concat(d)
        if out is None:
            raise ValueError("no data ingested yet")
        return out
