"""Analytical LLM-serving performance simulator (roofline step-time model).

Plays the role of the paper's H100+vLLM benchmarking rig: given a model
config, an accelerator profile, a TP degree and a workload (ii, oo, bb),
it produces throughput samples with realistic saturation behaviour and
measurement noise.  The step-time terms mirror the three roofline terms of
EXPERIMENTS.md §Roofline:

  prefill:  compute-bound   2·N_active·ii·bb / (chips·peak·mfu) + attn O(ii²)
  decode:   bandwidth-bound (weights-read + KV-read)/HBM, compute, ICI
  request:  t = t_prefill + oo · t_decode;  thpt = bb·oo / t

The weights-read term amortizes over the batch — exactly the mechanism
behind the paper's saturating thpt(bb) = c − a·e^(−b·bb) observation.
MoE reads only the experts a batch activates; SSM/hybrid models replace
KV reads with O(1) state reads, giving much flatter curves.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List

import numpy as np

from repro.models.config import (FFN_MOE, MIXER_ATTN, MIXER_MAMBA,
                                 MIXER_MLSTM, MIXER_SLSTM, ModelConfig)
from repro.perfmodel.hardware import HardwareProfile


@dataclasses.dataclass(frozen=True)
class ServingSetup:
    cfg: ModelConfig
    hw: HardwareProfile
    chips: int = 4            # TP degree
    framework_eff: float = 1.0  # serving-framework efficiency multiplier
    dtype_bytes: int = 2


def _per_layer_counts(cfg: ModelConfig):
    """(attn_layers, mamba_layers, slstm, mlstm, dense_ffn, moe_ffn)."""
    reps = cfg.n_periods
    attn = sum(b.mixer == MIXER_ATTN for b in cfg.period) * reps
    mamba = sum(b.mixer == MIXER_MAMBA for b in cfg.period) * reps
    sl = sum(b.mixer == MIXER_SLSTM for b in cfg.period) * reps
    ml = sum(b.mixer == MIXER_MLSTM for b in cfg.period) * reps
    dense = sum(b.ffn == "dense" for b in cfg.period) * reps
    moe = sum(b.ffn == FFN_MOE for b in cfg.period) * reps
    return attn, mamba, sl, ml, dense, moe


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    attn, *_ = _per_layer_counts(cfg)
    return attn * 2 * cfg.n_kv_heads * cfg.d_head * dtype_bytes


def state_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    """Recurrent state bytes per sequence (mamba/xlstm)."""
    _, mamba, sl, ml, _, _ = _per_layer_counts(cfg)
    di, ds = cfg.mamba_d_inner, cfg.mamba_d_state
    dp = int(cfg.xlstm_proj_factor * cfg.d_model)
    dk = dp // max(cfg.n_heads, 1)
    return (mamba * (di * ds * 4 + (cfg.mamba_d_conv - 1) * di * dtype_bytes)
            + sl * 3 * dp * 4
            + ml * (cfg.n_heads * dk * dk + cfg.n_heads * dk) * 4)


def weights_read_bytes(cfg: ModelConfig, bb: float,
                       dtype_bytes: int = 2) -> float:
    """Bytes of weights actually touched per decode step at batch bb.

    Dense layers: all weights.  MoE layers: min(E, bb·topk expected hits)
    experts (coupon-collector expectation)."""
    n_dense_equiv = cfg.param_count(active_only=False)
    attn, mamba, sl, ml, dense, moe = _per_layer_counts(cfg)
    if moe == 0:
        return n_dense_equiv * dtype_bytes
    e, k = cfg.n_experts, cfg.top_k
    expert_params = 3 * cfg.d_model * cfg.expert_d_ff
    # expected distinct experts hit by bb·k draws (uniform approx)
    draws = bb * k
    hit = e * (1 - (1 - 1 / e) ** draws)
    moe_total = moe * e * expert_params
    moe_read = moe * hit * expert_params
    return (n_dense_equiv - moe_total + moe_read) * dtype_bytes


def weight_bytes_total(setup: ServingSetup) -> float:
    """Resident parameter bytes across the whole TP group."""
    return setup.cfg.param_count(active_only=False) * setup.dtype_bytes


def kv_capacity_tokens(setup: ServingSetup) -> float:
    """KV-cache token budget: HBM across the TP group minus weights.

    Attention-free models (kv bytes/token == 0) report an effectively
    unbounded budget — their per-sequence state is O(1) and tiny."""
    budget = setup.hw.hbm_bytes * setup.chips - weight_bytes_total(setup)
    per_tok = kv_bytes_per_token(setup.cfg, setup.dtype_bytes)
    if per_tok <= 0.0:
        return float("inf")
    return max(budget, 0.0) / per_tok


def decode_step_time_group(setup: ServingSetup, contexts) -> float:
    """One decode iteration over a heterogeneous running batch.

    ``contexts`` holds each sequence's current context length (prompt +
    generated so far).  Equal contexts reduce exactly to the classic
    ``decode_step_time(setup, bb, context)``."""
    contexts = np.asarray(contexts, np.float64)
    bb = len(contexts)
    if bb == 0:
        return 0.0
    ctx_sum = float(contexts.sum())
    cfg, hw, chips = setup.cfg, setup.hw, setup.chips
    attn, mamba, sl, ml, dense, moe = _per_layer_counts(cfg)
    n_active = cfg.param_count(active_only=True)
    # compute: 2 FLOPs/param/token + attention dot products over context
    flops = 2 * n_active * bb
    flops += 2 * 2 * attn * cfg.n_heads * cfg.d_head * ctx_sum
    t_compute = flops / (chips * hw.flops_at(setup.dtype_bytes)
                         * hw.mfu_prefill)
    # memory: weights touched once + KV/state per sequence
    mem = weights_read_bytes(cfg, bb, setup.dtype_bytes)
    mem += ctx_sum * kv_bytes_per_token(cfg, setup.dtype_bytes)
    mem += bb * state_bytes(cfg, setup.dtype_bytes)
    t_mem = mem / (chips * hw.hbm_bw * hw.mfu_decode)
    # ICI: 2 all-reduces (attn+ffn) of (bb, d_model) per layer, ring cost
    coll_bytes = (2 * cfg.n_layers * bb * cfg.d_model * setup.dtype_bytes
                  * 2 * (chips - 1) / max(chips, 1))
    t_ici = coll_bytes / (hw.ici_bw * hw.ici_eff) if chips > 1 else 0.0
    # moe all-to-all
    if moe:
        t_ici += (2 * moe * bb * cfg.d_model * setup.dtype_bytes
                  / (hw.ici_bw * hw.ici_eff)) if chips > 1 else 0.0
    return max(t_compute, t_mem, t_ici) / setup.framework_eff


def decode_step_time(setup: ServingSetup, bb: float, context: float) -> float:
    return decode_step_time_group(setup, np.full(int(round(bb)), context))


def decode_time_fn(setup: ServingSetup, xp=np):
    """Vectorized closure for ``decode_step_time_group``.

    The group step time depends on the batch only through ``bb`` (its
    size) and ``ctx_sum`` (summed context lengths) — every term above is
    linear in one of the two.  The returned ``f(bb, ctx_sum)`` evaluates
    the identical expression over arrays, so for integer-valued inputs it
    matches the scalar reference to ~1 ulp (float64 sums of integers
    below 2**53 are exact).  Entries with ``bb == 0`` cost 0.

    ``xp`` selects the array namespace: the default ``numpy``, or
    ``jax.numpy`` to build a jittable version (the fleet engine's
    ``traj_backend="jax"``; note jax defaults to float32).
    """
    cfg, hw, chips = setup.cfg, setup.hw, setup.chips
    attn, mamba, sl, ml, dense, moe = _per_layer_counts(cfg)
    # float constants: exact below 2**53, and required for the jax
    # namespace (large Python ints overflow jax's default int32)
    n_active = float(cfg.param_count(active_only=True))
    kv_tok = float(kv_bytes_per_token(cfg, setup.dtype_bytes))
    st = float(state_bytes(cfg, setup.dtype_bytes))
    c_flops = 1.0 / (chips * hw.flops_at(setup.dtype_bytes)
                     * hw.mfu_prefill)
    c_mem = 1.0 / (chips * hw.hbm_bw * hw.mfu_decode)
    attn_flops = float(2 * 2 * attn * cfg.n_heads * cfg.d_head)
    coll_per_bb = (2 * cfg.n_layers * cfg.d_model * setup.dtype_bytes
                   * 2 * (chips - 1) / max(chips, 1))
    moe_per_bb = float(2 * moe * cfg.d_model * setup.dtype_bytes)
    eff = setup.framework_eff
    # weights_read_bytes, with the model constants hoisted out of the
    # closure (the fleet engine calls f thousands of times); the FP
    # expression order matches the scalar reference exactly
    n_dense_equiv = cfg.param_count(active_only=False)
    if moe == 0:
        wread_const = float(n_dense_equiv * setup.dtype_bytes)

        def wread(bb):
            return wread_const
    else:
        e, k = float(cfg.n_experts), float(cfg.top_k)
        expert_params = 3 * cfg.d_model * cfg.expert_d_ff
        moe_fixed = float(n_dense_equiv - moe * cfg.n_experts
                          * expert_params)
        moe_read_coeff = float(moe * expert_params)
        decay = 1 - 1 / e

        def wread(bb):
            hit = e * (1 - decay ** (bb * k))
            moe_read = hit * moe_read_coeff
            return (moe_fixed + moe_read) * setup.dtype_bytes

    def f(bb, ctx_sum):
        bb = xp.asarray(bb)
        ctx_sum = xp.asarray(ctx_sum)
        t_compute = (2 * n_active * bb + attn_flops * ctx_sum) * c_flops
        mem = (wread(bb) + ctx_sum * kv_tok + bb * st)
        t_mem = mem * c_mem
        if chips > 1:
            t_ici = coll_per_bb * bb / (hw.ici_bw * hw.ici_eff)
            if moe:
                t_ici = t_ici + moe_per_bb * bb / (hw.ici_bw * hw.ici_eff)
        else:
            t_ici = xp.zeros_like(t_compute)
        out = xp.maximum(xp.maximum(t_compute, t_mem), t_ici) / eff
        return xp.where(bb > 0, out, 0.0)

    return f


def prefill_step_time(setup: ServingSetup, prompt_lens) -> float:
    """One prefill iteration over a group of prompts of given lengths.

    Equal lengths reduce exactly to ``prefill_time(setup, ii, bb)``."""
    prompt_lens = np.asarray(prompt_lens, np.float64)
    if len(prompt_lens) == 0:
        return 0.0
    tok_sum = float(prompt_lens.sum())
    sq_sum = float((prompt_lens * prompt_lens).sum())
    cfg, hw, chips = setup.cfg, setup.hw, setup.chips
    attn, *_ = _per_layer_counts(cfg)
    n_active = cfg.param_count(active_only=True)
    flops = 2 * n_active * tok_sum
    flops += 2 * 2 * attn * cfg.n_heads * cfg.d_head * sq_sum / 2
    t_compute = flops / (chips * hw.flops_at(setup.dtype_bytes)
                         * hw.mfu_prefill)
    mem = (weights_read_bytes(cfg, 1e9, setup.dtype_bytes)
           + tok_sum * kv_bytes_per_token(cfg, setup.dtype_bytes))
    t_mem = mem / (chips * hw.hbm_bw * hw.mfu_decode)
    return max(t_compute, t_mem) / setup.framework_eff


def prefill_time(setup: ServingSetup, ii: float, bb: float) -> float:
    return prefill_step_time(setup, np.full(int(round(bb)), ii))


def prefill_time_fn(setup: ServingSetup):
    """Vectorized closure for ``prefill_step_time``.

    The group prefill time depends only on ``tok_sum`` (summed prompt
    lengths) and ``sq_sum`` (summed squared prompt lengths); the returned
    ``f(tok_sum, sq_sum)`` evaluates the scalar reference's expression
    over arrays (bit-exact for integer-valued sums).  Entries with
    ``tok_sum == 0`` cost 0.
    """
    cfg, hw, chips = setup.cfg, setup.hw, setup.chips
    attn, *_ = _per_layer_counts(cfg)
    n_active = cfg.param_count(active_only=True)
    kv_tok = kv_bytes_per_token(cfg, setup.dtype_bytes)
    wread = weights_read_bytes(cfg, 1e9, setup.dtype_bytes)
    c_flops = 1.0 / (chips * hw.flops_at(setup.dtype_bytes)
                     * hw.mfu_prefill)
    c_mem = 1.0 / (chips * hw.hbm_bw * hw.mfu_decode)
    attn_flops = 2 * 2 * attn * cfg.n_heads * cfg.d_head
    eff = setup.framework_eff

    def f(tok_sum, sq_sum):
        if isinstance(tok_sum, float):
            # scalar fast path: identical IEEE-double expression, no
            # array round-trip (hot in the fleet engine's prefill starts)
            if tok_sum <= 0:
                return 0.0
            t_compute = (2 * n_active * tok_sum
                         + attn_flops * sq_sum / 2) * c_flops
            t_mem = (wread + tok_sum * kv_tok) * c_mem
            return max(t_compute, t_mem) / eff
        tok_sum = np.asarray(tok_sum, np.float64)
        sq_sum = np.asarray(sq_sum, np.float64)
        t_compute = (2 * n_active * tok_sum
                     + attn_flops * sq_sum / 2) * c_flops
        t_mem = (wread + tok_sum * kv_tok) * c_mem
        out = np.maximum(t_compute, t_mem) / eff
        return np.where(tok_sum > 0, out, 0.0)

    return f


def throughput(setup: ServingSetup, ii: float, oo: float, bb: float) -> float:
    """Output tokens/sec for a batch of bb requests of (ii -> oo) tokens."""
    t_pre = prefill_time(setup, ii, bb)
    ctx = ii + oo / 2.0
    t_dec = decode_step_time(setup, bb, ctx)
    total = t_pre + oo * t_dec
    return bb * oo / total


def throughput_batch(setup: ServingSetup, ii, oo, bb) -> np.ndarray:
    """Vectorized ``throughput`` over row arrays (built on the
    ``*_time_fn`` closures, so it is a pure function of the hardware
    descriptor like everything else here).

    The analytic cross-hardware transfer scaler (paper RQ4 / the
    AMD-style hardware-agnostic model) is the ratio
    ``throughput_batch(setup_to, ...) / throughput_batch(setup_from, ...)``
    applied to a fit benchmarked on ``setup_from``'s hardware."""
    ii = np.asarray(ii, np.float64)
    oo = np.asarray(oo, np.float64)
    bb = np.asarray(bb, np.float64)
    dec = decode_time_fn(setup)
    pre = prefill_time_fn(setup)
    t_pre = pre(ii * bb, ii * ii * bb)
    ctx = ii + oo / 2.0
    t_dec = dec(bb, ctx * bb)
    return bb * oo / (t_pre + oo * t_dec)


def sample_throughput(setup: ServingSetup, ii, oo, bb, reps: int,
                      rng: np.random.Generator,
                      noise_sigma: float = 0.05,
                      straggler_p: float = 0.02) -> np.ndarray:
    """reps noisy measurements (lognormal noise + rare straggler dips)."""
    base = throughput(setup, ii, oo, bb)
    noise = rng.lognormal(mean=0.0, sigma=noise_sigma, size=reps)
    stragglers = np.where(rng.random(reps) < straggler_p,
                          rng.uniform(0.6, 0.9, size=reps), 1.0)
    return base * noise * stragglers
