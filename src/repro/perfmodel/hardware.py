"""Hardware-descriptor subsystem for the analytical serving-performance
simulator.

Every cost function in ``repro.perfmodel.simulator`` is a *pure function*
of a ``HardwareProfile``: the descriptor carries the full roofline —
compute (``peak_flops`` + dtype efficiency knobs), memory bandwidth
(``hbm_bw``), interconnect (``ici_bw``/``ici_eff``, plus the off-group
``net_bw`` NIC figure), and memory capacity (``hbm_bytes``) — together
with the achievable-fraction asymptotes (``mfu_*``).  Swapping the
descriptor retargets the whole stack (perf model, serving simulators,
ALA database) to a different accelerator; nothing above this module may
hard-code an accelerator constant.

Roofline constants are public datasheet numbers (peak dense bf16 tensor
throughput, peak HBM bandwidth, per-direction interconnect bandwidth per
link/chip, HBM capacity per chip); the ``mfu_*``/``*_eff`` fractions are
the usual achievable-fraction fudge factors and are deliberately
conservative.  Sources, per profile, are noted inline.

Cross-hardware transfer (paper RQ4 / Alg 8): ``hardware_distance``
scores how far two descriptors sit in log-roofline space.  The ALA
uncertainty layer adds this distance to the workload-histogram distance
``d_min`` before the ``1 / (1 + d)`` confidence squash, so a fit
transferred to unbenchmarked hardware reports *honestly degraded*
confidence instead of false certainty (see ``docs/hardware_model.md``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Tuple, Union


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # bf16 FLOP/s per chip (dense)
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link (intra-group collective)
    hbm_bytes: float           # capacity per chip
    # achievable fractions (matmul-efficiency asymptotes)
    mfu_prefill: float = 0.55
    mfu_decode: float = 0.70   # of the *bandwidth* roofline
    ici_eff: float = 0.80
    # dtype efficiency knobs: peak-FLOPs multiplier relative to bf16 when
    # serving in 1-byte (fp8/int8) or 4-byte (fp32) precision.  1.0 for
    # fp8 means "no fp8 tensor units — same rate as bf16" (TPU v5e, A100).
    fp8_flops_scale: float = 1.0
    fp32_flops_scale: float = 0.5
    # off-group interconnect (NIC / DCN), bytes/s per chip.  Not in the
    # single-group cost path; used as a descriptor feature for
    # cross-hardware distance and future multi-group scaling.
    net_bw: float = 25e9

    def flops_at(self, dtype_bytes: float) -> float:
        """Peak FLOP/s at the serving precision (pure in the descriptor).

        2-byte (bf16) is the calibration point; 1-byte engages the fp8
        knob, 4-byte the fp32 knob.  Fractional byte-widths interpolate
        in log2 space so the curve is monotone in precision."""
        if dtype_bytes == 2:
            return self.peak_flops
        if dtype_bytes <= 1:
            return self.peak_flops * self.fp8_flops_scale
        if dtype_bytes >= 4:
            return self.peak_flops * self.fp32_flops_scale
        if dtype_bytes < 2:     # (1, 2): blend bf16 <- fp8
            w = 2.0 - dtype_bytes
            return self.peak_flops * self.fp8_flops_scale ** w
        w = (dtype_bytes - 2.0) / 2.0   # (2, 4): blend bf16 -> fp32
        return self.peak_flops * self.fp32_flops_scale ** w

    def features(self) -> Dict[str, float]:
        """Descriptor features on the scale the cost functions see them:
        *delivered* rooflines (peak x achievable fraction), plus capacity
        and the compute:bandwidth intensity ratio."""
        flops = self.peak_flops * self.mfu_prefill
        bw = self.hbm_bw * self.mfu_decode
        return {
            "flops": flops,
            "hbm_bw": bw,
            "ici_bw": self.ici_bw * self.ici_eff,
            "hbm_bytes": self.hbm_bytes,
            "intensity": flops / bw,    # FLOP per byte at the ridge
        }


# -- registered descriptors --------------------------------------------------
# TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM2, 16 GiB/chip, ICI ~50 GB/s per
# link (numbers match EXPERIMENTS.md).  No fp8 tensor path.
TPU_V5E = HardwareProfile(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
    hbm_bytes=16e9)

# TPU v4: 275 TFLOP/s bf16, 1228 GB/s HBM2, 32 GiB/chip, 3D-torus ICI
# ~50 GB/s per link.  No fp8 tensor path.
TPU_V4 = HardwareProfile(
    name="tpu-v4", peak_flops=275e12, hbm_bw=1228e9, ici_bw=50e9,
    hbm_bytes=32e9)

# NVIDIA A100-SXM 80G: 312 TFLOP/s dense bf16, 2039 GB/s HBM2e, 80 GiB,
# NVLink3 300 GB/s per direction per GPU.  No fp8 units (fp8 runs at the
# bf16 rate); fp32 tensor (TF32) ~0.5x.
A100_80G = HardwareProfile(
    name="gpu-a100-80g", peak_flops=312e12, hbm_bw=2039e9, ici_bw=300e9,
    hbm_bytes=80e9, mfu_prefill=0.45, mfu_decode=0.60, ici_eff=0.70,
    net_bw=50e9)

# NVIDIA H100-SXM: 989 TFLOP/s dense bf16, 3350 GB/s HBM3, 80 GiB,
# NVLink4 450 GB/s per direction per GPU; fp8 tensor core 2x bf16.
H100_SXM = HardwareProfile(
    name="gpu-h100-sxm", peak_flops=989e12, hbm_bw=3350e9, ici_bw=450e9,
    hbm_bytes=80e9, mfu_prefill=0.45, mfu_decode=0.60, ici_eff=0.70,
    fp8_flops_scale=2.0, net_bw=50e9)

# AMD MI300X: 1307 TFLOP/s dense bf16, 5300 GB/s HBM3, 192 GiB,
# Infinity Fabric ~128 GB/s per link (7 links/GPU); fp8 2x bf16.
MI300X = HardwareProfile(
    name="gpu-mi300x", peak_flops=1307e12, hbm_bw=5300e9, ici_bw=128e9,
    hbm_bytes=192e9, mfu_prefill=0.40, mfu_decode=0.55, ici_eff=0.65,
    fp8_flops_scale=2.0, net_bw=50e9)

# NVIDIA L4 (inference card): 121 TFLOP/s dense bf16, 300 GB/s GDDR6,
# 24 GiB, PCIe gen4 x16 ~32 GB/s (no NVLink); fp8 2x bf16.
L4 = HardwareProfile(
    name="gpu-l4", peak_flops=121e12, hbm_bw=300e9, ici_bw=32e9,
    hbm_bytes=24e9, mfu_prefill=0.35, mfu_decode=0.50, ici_eff=0.50,
    fp8_flops_scale=2.0, net_bw=12e9)

# stand-in for an accelerator with a very different compute:bandwidth
# ratio — the paper's RQ4 hardware-mismatch case (Qwen2-7B on Intel PVC
# vs the H100-trained predictor)
LEGACY_GPU = HardwareProfile(
    name="legacy-gpu", peak_flops=105e12, hbm_bw=1600e9, ici_bw=25e9,
    hbm_bytes=48e9, mfu_prefill=0.42, mfu_decode=0.55, ici_eff=0.6)

PROFILES = {p.name: p for p in (
    TPU_V5E, TPU_V4, A100_80G, H100_SXM, MI300X, L4, LEGACY_GPU)}


def profile(name: str) -> HardwareProfile:
    """Look up a registered descriptor by name."""
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown hardware profile {name!r}; registered: "
                       f"{sorted(PROFILES)}") from None


ProfileLike = Union[str, HardwareProfile]


def _resolve(p: ProfileLike) -> HardwareProfile:
    return profile(p) if isinstance(p, str) else p


# feature weights for the distance: capacity shifts the saturation point
# (via the KV budget) but not the step-time curve shape, so it counts
# half; the delivered rooflines and the intensity ratio count full.
_DIST_WEIGHTS = {"flops": 1.0, "hbm_bw": 1.0, "ici_bw": 1.0,
                 "hbm_bytes": 0.5, "intensity": 1.0}


def hardware_distance(a: ProfileLike, b: ProfileLike) -> float:
    """Descriptor distance in log-roofline space.

    Weighted mean of ``|log2(feature_a / feature_b)|`` over the
    ``features()`` axes: 0 for identical descriptors, ~1 when the
    delivered rooflines differ by about 2x across the board.  The scale
    is chosen to compose with the Alg 8 workload distance — the
    uncertainty layer forms ``d_eff = d_min + weight * d_hw`` before the
    ``1 / (1 + d)`` squash, so any nonzero hardware distance *strictly*
    lowers transferred confidence on the same workloads."""
    fa, fb = _resolve(a).features(), _resolve(b).features()
    num = sum(w * abs(math.log2(fa[k] / fb[k]))
              for k, w in _DIST_WEIGHTS.items())
    return num / sum(_DIST_WEIGHTS.values())


def feature_row(p: ProfileLike) -> Dict[str, float]:
    """Hardware feature columns for ALA database rows (log10 scale, so
    they sit in the same numeric range as the workload features)."""
    f = _resolve(p).features()
    return {f"hw_{k}": math.log10(v) for k, v in f.items()}


def feature_names() -> Tuple[str, ...]:
    return tuple(f"hw_{k}" for k in TPU_V5E.features())
