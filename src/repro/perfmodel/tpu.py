"""Accelerator profiles for the analytical serving-performance simulator.

TPU v5e numbers match the roofline constants used in EXPERIMENTS.md
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).  The ``legacy-gpu``
profile stands in for the paper's RQ4 hardware-mismatch case (Qwen2-7B on
Intel PVC vs the H100-trained predictor): different compute/bandwidth
ratio => different saturation curve shape.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # capacity per chip
    # achievable fractions (matmul-efficiency asymptotes)
    mfu_prefill: float = 0.55
    mfu_decode: float = 0.70   # of the *bandwidth* roofline
    ici_eff: float = 0.80


TPU_V5E = HardwareProfile(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
    hbm_bytes=16e9)

# stand-in for an accelerator with a very different compute:bandwidth ratio
LEGACY_GPU = HardwareProfile(
    name="legacy-gpu", peak_flops=105e12, hbm_bw=1600e9, ici_bw=25e9,
    hbm_bytes=48e9, mfu_prefill=0.42, mfu_decode=0.55, ici_eff=0.6)

PROFILES = {p.name: p for p in (TPU_V5E, LEGACY_GPU)}
