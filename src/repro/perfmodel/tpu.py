"""Deprecated alias for ``repro.perfmodel.hardware``.

The accelerator descriptors outgrew this module's name the moment they
stopped being TPU-only; the subsystem now lives in
``repro.perfmodel.hardware`` (descriptor dataclass, registered GPU/NPU
profiles, cross-hardware distance).  This shim re-exports the public
names for back-compat and will be removed; in-repo code must import
``repro.perfmodel.hardware`` (enforced by a grep-check test).
"""
from __future__ import annotations

import warnings

from repro.perfmodel.hardware import (  # noqa: F401
    A100_80G, H100_SXM, L4, LEGACY_GPU, MI300X, PROFILES, TPU_V4, TPU_V5E,
    HardwareProfile, hardware_distance, profile)

warnings.warn(
    "repro.perfmodel.tpu is deprecated; import repro.perfmodel.hardware",
    DeprecationWarning, stacklevel=2)
