"""Jit'd public wrapper: shape handling + platform dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_2d
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "force"))
def rmsnorm(x, scale, eps: float = 1e-5, block_rows: int = 256,
            force: str | None = None):
    """RMSNorm over the last dim of an arbitrarily-shaped x.

    ``force``: None (auto: kernel on TPU, interpret-kernel nowhere — oracle
    elsewhere), "kernel", "interpret", or "ref".
    """
    mode = force or ("kernel" if jax.default_backend() == "tpu" else "ref")
    if mode == "ref":
        return rmsnorm_ref(x, scale, eps)
    lead = x.shape[:-1]
    d = x.shape[-1]
    n = 1
    for s in lead:
        n *= int(s)
    x2 = x.reshape(max(n, 1), d)
    rows = x2.shape[0]
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rmsnorm_2d(x2, scale, eps=eps, block_rows=br,
                     interpret=(mode == "interpret"))
    if pad:
        out = out[:rows]
    return out.reshape(*lead, d)
