"""Fused RMSNorm Pallas TPU kernel.

One HBM->VMEM round trip per row block instead of XLA's two (mean-square
reduce, then scale): rows are tiled ``block_rows`` at a time, the full
feature dim stays resident in VMEM (d_model <= 8192 * 4B = 32 KiB/row is
comfortably within the ~16 MiB VMEM for the default 256-row block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_2d(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
               interpret: bool = False):
    """x: (n, d) -> (n, d). n must be divisible by block_rows (ops.py pads)."""
    n, d = x.shape
    assert n % block_rows == 0, (n, block_rows)
    grid = (n // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
