"""Pure-jnp oracle for the GBT gradient/hessian histogram."""
import jax.numpy as jnp


def gbt_hist_ref(bins, grad, hess, n_bins: int):
    """bins: (n, f) int32; grad/hess: (n,) -> (f, n_bins, 2) fp32."""
    onehot = (bins[..., None] ==
              jnp.arange(n_bins)[None, None, :]).astype(jnp.float32)
    hg = jnp.einsum("nfb,n->fb", onehot, grad.astype(jnp.float32))
    hh = jnp.einsum("nfb,n->fb", onehot, hess.astype(jnp.float32))
    return jnp.stack([hg, hh], axis=-1)
