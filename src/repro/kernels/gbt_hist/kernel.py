"""Gradient/hessian histogram build for GBT training — Pallas TPU kernel.

This is the inner loop of histogram gradient boosting (the ALA parameter
predictor, Alg 3/7 of the paper).  On GPU this is an atomic scatter-add;
TPUs have no atomics, so the TPU-idiomatic formulation is a *one-hot
matmul* onto the MXU:

    hist[f, b] = sum_n onehot(bins[n, f] == b) * g[n]

Samples stream over the sequential grid axis in ``block_n`` tiles; each
tile builds a (block_n, block_f * n_bins) one-hot and contracts it with
the (g, h) pair in one dot_general — two MXU passes per tile, fp32
accumulation in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _hist_kernel(bins_ref, g_ref, h_ref, o_ref, acc_ref, *,
                 n_bins: int, block_f: int, block_n: int, n_n_blocks: int):
    i_n = pl.program_id(1)

    @pl.when(i_n == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bins = bins_ref[...]                                  # (bn, bf)
    gh = jnp.stack([g_ref[...], h_ref[...]], axis=-1)     # (bn, 2)
    iota_bins = jax.lax.broadcasted_iota(
        jnp.int32, (block_n, block_f, n_bins), 2)
    onehot = (bins[..., None] == iota_bins).astype(jnp.float32)
    flat = onehot.reshape(block_n, block_f * n_bins)
    # (2, bn) @ (bn, bf*n_bins) -> (2, bf*n_bins)
    contrib = jax.lax.dot_general(
        gh.astype(jnp.float32), flat, (((0,), (0,)), ((), ())))
    acc_ref[...] += contrib.T.reshape(block_f, n_bins, 2)

    @pl.when(i_n == n_n_blocks - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


def gbt_hist(bins, grad, hess, *, n_bins: int, block_f: int = 8,
             block_n: int = 512, interpret: bool = False):
    """bins: (n, f) int32; grad/hess: (n,) -> hist (f, n_bins, 2) fp32.

    Caller pads n to block_n (with grad=hess=0) and f to block_f
    (bin id 0 on padded features is harmless: their histograms are
    discarded)."""
    n, f = bins.shape
    assert n % block_n == 0 and f % block_f == 0, (n, f)
    grid = (f // block_f, n // block_n)
    kernel = functools.partial(
        _hist_kernel, n_bins=n_bins, block_f=block_f, block_n=block_n,
        n_n_blocks=n // block_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_f), lambda i_f, i_n: (i_n, i_f)),
            pl.BlockSpec((block_n,), lambda i_f, i_n: (i_n,)),
            pl.BlockSpec((block_n,), lambda i_f, i_n: (i_n,)),
        ],
        out_specs=pl.BlockSpec((block_f, n_bins, 2),
                               lambda i_f, i_n: (i_f, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((f, n_bins, 2), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_f, n_bins, 2), jnp.float32)],
        interpret=interpret,
    )(bins, grad, hess)
