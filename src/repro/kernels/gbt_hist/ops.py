"""Jit'd public wrappers for the GBT histogram kernel (pads + dispatches)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gbt_hist.kernel import gbt_hist as gbt_hist_kernel
from repro.kernels.gbt_hist.ref import gbt_hist_ref


@functools.partial(jax.jit, static_argnames=("n_bins", "block_f", "block_n",
                                             "force"))
def build_histograms(bins, grad, hess, n_bins: int, block_f: int = 8,
                     block_n: int = 512, force: str | None = None):
    """bins: (n, f) int32; grad/hess: (n,) -> (f, n_bins, 2) fp32."""
    mode = force or ("kernel" if jax.default_backend() == "tpu" else "ref")
    if mode == "ref":
        return gbt_hist_ref(bins, grad, hess, n_bins)
    n, f = bins.shape
    bn = min(block_n, max(8, n))
    pad_n = (-n) % bn
    bf = min(block_f, f)
    pad_f = (-f) % bf
    if pad_n or pad_f:
        bins = jnp.pad(bins, ((0, pad_n), (0, pad_f)))
        grad = jnp.pad(grad, (0, pad_n))
        hess = jnp.pad(hess, (0, pad_n))
    out = gbt_hist_kernel(bins, grad, hess, n_bins=n_bins, block_f=bf,
                          block_n=bn, interpret=(mode == "interpret"))
    return out[:f]


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "block_f",
                                             "block_n", "force"))
def build_node_histograms(bins, grad, hess, node_id, n_nodes: int,
                          n_bins: int, block_f: int = 8, block_n: int = 512,
                          force: str | None = None):
    """Per-tree-node histograms: (n, f) bins + (n,) node ids ->
    (n_nodes, f, n_bins, 2).

    TPUs have no atomics, so node separation is zero-masked weights: one
    kernel pass per node with ``grad * (node_id == node)`` — a
    zero-weight row adds exactly 0.0 to every bin.  The node loop is
    unrolled inside this jit, so level-wise GBT growth issues a single
    XLA call per level instead of ``n_nodes`` host round trips.
    """
    outs = []
    for li in range(n_nodes):
        m = (node_id == li).astype(grad.dtype)
        outs.append(build_histograms(bins, grad * m, hess * m,
                                     n_bins=n_bins, block_f=block_f,
                                     block_n=block_n, force=force))
    return jnp.stack(outs)
