"""Flash attention (prefill) Pallas TPU kernel — causal GQA.

TPU adaptation of FlashAttention-2 [arXiv:2307.08691]: the online-softmax
accumulation runs over a *grid* dimension (TPU grids execute sequentially
over the last axis with VMEM scratch carried across iterations) instead of
a CUDA thread-block loop.  Block shapes keep the MXU fed: q/k tiles are
(block_q, d_head) x (block_k, d_head) with d_head in {64, 128} — both
MXU-aligned (128 lanes).

Layout: q (B, H, S, Dh); k/v (B, KV, S, Dh).  GQA maps query head h to kv
head h // (H // KV) inside the BlockSpec index maps — no KV replication in
HBM.

Causality: kv blocks strictly above the diagonal are skipped via
``pl.when`` (no FLOPs, no VMEM traffic beyond the prefetched tile);
diagonal blocks apply an elementwise mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        # kv block strictly above the diagonal -> nothing to do
        run = (ik * block_k) <= (iq * block_q + block_q - 1)

    @pl.when(run if causal else (ik >= 0))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale     # (bq, bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         scale: float | None = None, block_q: int = 512,
                         block_k: int = 512, interpret: bool = False):
    """q: (B, H, S, Dh); k/v: (B, KV, S, Dh). Returns (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    _, kv, sk, _ = k.shape
    assert h % kv == 0
    block_q = min(block_q, s)
    block_k = min(block_k, sk)
    assert s % block_q == 0 and sk % block_k == 0
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    nq, nk = s // block_q, sk // block_k
    grid = (b, h, nq, nk)
    group = h // kv

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_kv_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
