"""Pure-jnp oracle for flash attention (GQA, optional causal)."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """q: (B, H, S, Dh); k/v: (B, KV, Sk, Dh) -> (B, H, S, Dh)."""
    b, h, s, dh = q.shape
    _, kv, sk, _ = k.shape
    group = h // kv
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    qg = q.reshape(b, kv, group, s, dh)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        idx = jnp.arange(s)[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(idx[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, dh).astype(q.dtype)
