"""Jit'd public wrapper for the prefill flash-attention kernel.

Model code passes (B, S, H, Dh) activations; the kernel wants head-major
(B, H, S, Dh) / (B, KV, S, Dh).  Dispatch: Pallas kernel on TPU,
interpret-mode kernel when forced (tests), jnp oracle otherwise.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "force"))
def flash_attention(q, k, v, causal: bool = True, scale: float | None = None,
                    block_q: int = 512, block_k: int = 512,
                    force: str | None = None):
    """q: (B, S, H, Dh); k/v: (B, S, KV, Dh) -> (B, S, H, Dh)."""
    mode = force or ("kernel" if jax.default_backend() == "tpu" else "ref")
    qh = q.swapaxes(1, 2)
    kh = k.swapaxes(1, 2)
    vh = v.swapaxes(1, 2)
    if mode == "ref":
        out = attention_ref(qh, kh, vh, causal=causal, scale=scale)
    else:
        out = flash_attention_bhsd(
            qh, kh, vh, causal=causal, scale=scale, block_q=block_q,
            block_k=block_k, interpret=(mode == "interpret"))
    return out.swapaxes(1, 2)
