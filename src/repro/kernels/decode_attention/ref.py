"""Pure-jnp oracle for single-query (decode) attention with fill mask."""
import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, scale: float | None = None):
    """q: (B, KV, G, Dh); k/v: (B, KV, T, Dh); attend to t <= pos."""
    b, kv, g, dh = q.shape
    t = k.shape[2]
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    s = jnp.einsum("bhgd,bhtd->bhgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(t)[None, None, None, :] <= pos
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
