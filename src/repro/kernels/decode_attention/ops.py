"""Jit'd public wrapper for the flash-decoding kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_grouped
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("scale", "block_t", "force"))
def decode_attention(q, k, v, pos, scale: float | None = None,
                     block_t: int = 512, force: str | None = None):
    """q: (B, H, Dh); k/v: (B, T, KV, Dh); pos: () — returns (B, H, Dh)."""
    b, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, dh)
    kh = k.swapaxes(1, 2)      # (B, KV, T, Dh)
    vh = v.swapaxes(1, 2)
    mode = force or ("kernel" if jax.default_backend() == "tpu" else "ref")
    if mode == "ref":
        out = decode_attention_ref(qg, kh, vh, pos, scale=scale)
    else:
        out = decode_attention_grouped(
            qg, kh, vh, pos, scale=scale, block_t=block_t,
            interpret=(mode == "interpret"))
    return out.reshape(b, h, dh)
