"""Decode (single-query) attention Pallas TPU kernel — flash-decoding.

TPU adaptation of FlashDecoding [arXiv:2311.01282]: the KV length is split
across the last (sequential) grid axis; partial (max, sum, acc) statistics
live in VMEM scratch and are merged online, so the kernel is a pure
KV-bandwidth streamer — the regime that dominates decode throughput and
that ALA's exponential saturation model captures.

The query token is masked against ``pos`` (the number of valid cache
entries) with an elementwise iota compare, so one compiled kernel serves
any fill level.  ``pos`` arrives via scalar prefetch (SMEM) — the TPU
analogue of passing it in registers.

Layout: q (B, KV, G, Dh) grouped query heads; k/v (B, KV, T, Dh).
Grid (B, KV, nT).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, block_t: int, n_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    # skip blocks entirely beyond the valid prefix
    @pl.when(it * block_t <= pos)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, Dh)
        k = k_ref[0, 0].astype(jnp.float32)             # (bt, Dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale      # (G, bt)
        t_idx = it * block_t + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(t_idx <= pos, s, NEG_INF)
        m_prev = m_ref[...]                              # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(it == n_t_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_grouped(q, k, v, pos, *, scale: float | None = None,
                             block_t: int = 512, interpret: bool = False):
    """q: (B, KV, G, Dh); k/v: (B, KV, T, Dh); pos: () int32.

    Attends to cache positions <= pos. Returns (B, KV, G, Dh)."""
    b, kv, g, dh = q.shape
    _, _, t, _ = k.shape
    block_t = min(block_t, t)
    assert t % block_t == 0
    nt = t // block_t
    scale = scale if scale is not None else 1.0 / (dh ** 0.5)
    grid = (b, kv, nt)
    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_t=block_t, n_t_blocks=nt)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda ib, ih, it, pos: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, block_t, dh),
                         lambda ib, ih, it, pos: (ib, ih, it, 0)),
            pl.BlockSpec((1, 1, block_t, dh),
                         lambda ib, ih, it, pos: (ib, ih, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda ib, ih, it, pos: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), q, k, v)
