"""Quickstart: the complete ALA pipeline in ~60 lines.

Generates a benchmark dataset with the TPU-v5e serving simulator, fits the
analytical+ML model, explores training subsets with simulated annealing,
trains the error predictor, and quantifies uncertainty for a new workload.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.bench.datasets import make_inhouse_dataset, train_test_split
from repro.core.ala import ALA
from repro.core.annealing import SAConfig

# 1. benchmark data: ~4,800 (ii, oo, bb, thpt) points for llama3.1-8b
ds = make_inhouse_dataset()
train, test = train_test_split(ds, test_frac=0.3)
print(f"dataset: {len(ds)} rows, "
      f"{len(np.unique(ds['ii']))} input sizes x "
      f"{len(np.unique(ds['oo']))} output sizes x "
      f"{len(np.unique(ds['bb']))} batch sizes")

# 2. Alg 2 + Alg 3: exponential database + parameter predictor
ala = ALA()
ala.cfg.sa = SAConfig(n_iters=30, gbt_kw=dict(n_estimators=40,
                                              learning_rate=0.2,
                                              max_depth=4))
ala.fit(*train.workload)
print(f"fitted {len(ala.db)} (ii,oo) groups "
      f"(db {ala.timings['fit_db_s']:.2f}s, "
      f"gbt {ala.timings['fit_predictor_s']:.2f}s)")

# 3. Alg 5: predict throughput — observed and unobserved workloads
bb = np.array([1, 4, 16, 64, 256], float)
seen = ala.predict(np.full(5, 1024.0), np.full(5, 512.0), bb)
unseen = ala.predict(np.full(5, 3000.0), np.full(5, 700.0), bb)
print("thpt(bb) @ seen  (1024,512):", np.round(seen, 0))
print("thpt(bb) @ unseen(3000,700):", np.round(unseen, 0))
print(f"held-out median APE: {ala.score(*test.workload):.2f}%")

# 4. Alg 6 + Alg 7: subset exploration -> error predictor
ala.explore(test.workload)
ala.fit_error()
print(f"SA explored {len(ala.sa_log.subsets)} subsets, "
      f"best error {ala.sa_log.best_error:.2f}%")

# 5. Alg 8: predicted error + confidence for a new workload
pred_err, conf = ala.estimate(test.workload)
print(f"new workload: predicted error {pred_err:.2f}%, "
      f"confidence {conf:.2f}")
