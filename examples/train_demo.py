"""Training driver with fault tolerance: train a reduced model for a few
hundred steps on the synthetic pipeline, checkpointing as it goes; re-run
the same command after killing it and it resumes from the latest atomic
checkpoint with an identical batch stream.

Run:  PYTHONPATH=src python examples/train_demo.py \
          [--arch qwen3-0.6b] [--steps 200]
"""
import argparse

from repro.configs import ARCHS, get_smoke_config
from repro.configs.shapes import ShapeSpec
from repro.models.transformer import Model
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_demo")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).scaled(d_model=128, d_ff=256)
    shape = ShapeSpec("demo", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    tcfg = TrainConfig(
        total_steps=args.steps, ckpt_every=50, log_every=10,
        ckpt_dir=args.ckpt_dir,
        opt=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps))
    trainer = Trainer(Model(cfg), shape, None, tcfg)
    trainer.run(seed=0)
    losses = [h["loss"] for h in trainer.history]
    if losses:
        print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
              f"{len(losses)} steps this run "
              f"({sum(h['sec'] for h in trainer.history):.1f}s)")
    # straggler accounting over the run
    print("median step time:",
          f"{trainer.monitor.median_duration():.3f}s;",
          "stragglers flagged:", trainer.monitor.stragglers())


if __name__ == "__main__":
    main()
