"""End-to-end serving driver (the paper's kind of system): serve a small
model with batched requests, MEASURE real throughput across an (ii,oo,bb)
grid, then fit ALA on the measured data and validate its predictions on a
held-out batch size — the complete loop from the paper, on real wall-clock
numbers from the actual JAX engine.

Run:  PYTHONPATH=src python examples/serve_demo.py [--arch llama3.2-3b]
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.core.ala import ALA
from repro.core.annealing import median_ape
from repro.inference.engine import ServingEngine
from repro.models.transformer import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=list(ARCHS))
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params)
    print(f"serving {args.arch} (reduced config: {cfg.n_layers}L "
          f"d={cfg.d_model}, vocab={cfg.vocab_size}) on "
          f"{jax.default_backend()}")

    # 1. benchmark a grid with the real engine
    grid_bb = (1, 2, 4, 8, 16)
    held_bb = 12
    rows = []
    for ii, oo in ((16, 8), (32, 8), (64, 8)):
        for bb in grid_bb:
            rows.extend(engine.measure_throughput(ii, oo, bb,
                                                  reps=args.reps))
        rows.extend(engine.measure_throughput(ii, oo, held_bb, reps=1))
    meas = {k: np.array([r[k] for r in rows], float)
            for k in ("ii", "oo", "bb", "thpt")}
    print(f"measured {len(rows)} points; example: "
          f"ii=32 oo=8 bb=16 -> "
          f"{np.mean(meas['thpt'][(meas['ii'] == 32) & (meas['bb'] == 16)]):.1f} tok/s")

    # 2. fit ALA on the grid points (held_bb excluded)
    train_mask = meas["bb"] != held_bb
    ala = ALA().fit(meas["ii"][train_mask], meas["oo"][train_mask],
                    meas["bb"][train_mask], meas["thpt"][train_mask])

    # 3. validate on the held-out batch size
    hm = ~train_mask
    pred = ala.predict(meas["ii"][hm], meas["oo"][hm], meas["bb"][hm])
    err = median_ape(meas["thpt"][hm], pred)
    for i in np.where(hm)[0][:3]:
        p = ala.predict(meas["ii"][i:i+1], meas["oo"][i:i+1],
                        meas["bb"][i:i+1])[0]
        print(f"  ii={meas['ii'][i]:.0f} oo={meas['oo'][i]:.0f} "
              f"bb={held_bb}: measured {meas['thpt'][i]:8.1f}  "
              f"ALA predicted {p:8.1f}")
    print(f"held-out batch size bb={held_bb}: median APE {err:.1f}%")


if __name__ == "__main__":
    main()
