"""Capacity planning with ALA: pick batch sizes / replica counts from
predictions instead of benchmarking every configuration.

Run:  PYTHONPATH=src python examples/capacity_planning.py
"""
import numpy as np

from repro.bench.datasets import make_inhouse_dataset, train_test_split
from repro.core.ala import ALA
from repro.core.annealing import SAConfig
from repro.inference.scheduler import BatchingQueue, CapacityPlanner, Request

ds = make_inhouse_dataset()
train, test = train_test_split(ds, test_frac=0.3)
ala = ALA()
# 4 SA chains x 8 steps through the batched engine: same 25-ish proposal
# budget as the old serial loop, a fraction of the wall clock
ala.cfg.sa = SAConfig(n_iters=8, gbt_kw=dict(n_estimators=30,
                                             learning_rate=0.2))
ala.fit(*train.workload)
ala.explore(test.workload, n_chains=4)
ala.fit_error()

planner = CapacityPlanner(ala)

print("=== SLO-driven batch-size planning (ii=2048 -> oo=512) ===")
for target in (500.0, 2000.0, 8000.0):
    plan = planner.plan_batch_size(2048, 512, target_thpt=target)
    print(f"target {target:>7.0f} tok/s -> bb={plan.bb:<4d} "
          f"predicted={plan.predicted_thpt:>8.0f} conf={plan.confidence:.2f} "
          f"replicas={plan.replicas}")

print("\n=== latency-bounded planning (per-token SLO) ===")
for slo in (0.01, 0.05):
    plan = planner.plan_batch_size(1024, 256, max_token_latency_s=slo)
    print(f"SLO {slo*1e3:.0f}ms/token -> bb={plan.bb} "
          f"predicted={plan.predicted_thpt:.0f} tok/s")

print("\n=== request queue dispatch ===")
q = BatchingQueue(planner, target_thpt=1000.0)
rng = np.random.default_rng(0)
for rid in range(200):
    ii = int(rng.choice([600, 2000]))
    q.submit(Request(rid=rid, ii=ii, oo=400))
for (bucket, reqs) in q.ready_batches()[:6]:
    print(f"bucket {bucket}: dispatched batch of {len(reqs)} "
          f"(planned bb={q.plans[bucket].bb}, "
          f"conf={q.plans[bucket].confidence:.2f})")
