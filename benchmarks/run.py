"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of
the operation benchmarked; derived = the figure's headline metric) and
writes a JSON report to results/bench_report.json for EXPERIMENTS.md.

  fig2_exponential_fits   — Alg 2 database fit quality on the in-house grid
  fig3_param_prediction   — Alg 3 extrapolation to held-out (ii,oo) groups
  fig6_rq1_training_sets  — RQ1: 4 training-set designs -> error dists
  fig7_rq2_baselines      — RQ2: ALA vs LR/XGB/RF/GB (+ SA trajectory,
                            runtime scaling)
  fig8_rq3_model_zoo      — RQ3: per-architecture error across the 10-arch
                            suite dataset
  table1_rq4_uncertainty  — RQ4: predicted error / confidence / actual,
                            incl. the hardware-mismatch case
  perf_vmapped_fit        — beyond-paper: batched-LM fit vs scalar numpy
  perf_kernels            — kernel oracle timings (CPU reference path)
  sa_engine               — legacy serial SA vs the batched K-chain engine
                            (equal proposal budget; emits BENCH_sa.json)
  uncertainty_engine      — serial Alg 7+8 loop vs the batched SubsetBank
                            kernel at equal query count (>= 64 queries x
                            200 subsets; emits BENCH_uncertainty.json)
  serving_engine          — trace-driven continuous-batching fleet sim:
                            ALA-in-the-loop autoscaling vs the static-bb
                            baseline across >= 3 archs x arrival traces
                            (emits BENCH_serving.json; --smoke for CI)
  fleet_engine            — fleet-scale vectorized serving engine on a
                            3-tenant diurnal/flash workload (100k+
                            requests full-size) vs the heap engine, with
                            a hard >=50x events/s gate (emits
                            BENCH_fleet.json; --smoke for CI)
  online_engine           — epoch-by-epoch trace feed through the
                            OnlineALA incremental-refit engine vs a
                            from-scratch fit+fit_uncertainty on the
                            concatenated data every epoch: prediction
                            parity + speedup (emits BENCH_online.json;
                            --smoke for CI)
  transfer_engine         — cross-hardware ALA transfer: per-target
                            medAPE via the analytic roofline scaler,
                            strict cross- vs same-hardware confidence
                            ordering, and a mixed TPU+GPU fleet where
                            hardware-aware placement beats blind
                            (emits BENCH_transfer.json; --smoke for CI)
  obs_engine              — observability layer gates: <5% tracing
                            overhead at sample_rate=1.0 on the fleet
                            engine, heap/fleet span-statistic parity,
                            mergeable histogram shards, a monotone
                            confidence reliability curve from the
                            calibration audit, and a Perfetto-loadable
                            chrome trace (emits BENCH_obs.json;
                            --smoke for CI)
  wallclock_engine        — real JAX engine sweep via bench.harness
                            (honors --grid-ii/--grid-oo/--grid-bb/--reps)

Run everything:          PYTHONPATH=src python benchmarks/run.py
Run one benchmark:       PYTHONPATH=src python benchmarks/run.py sa_engine
Smoke-size a run:        PYTHONPATH=src python benchmarks/run.py \
                             serving_engine --smoke
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results"
REPORT: dict = {}
_ROWS: list = []
# CLI-provided knobs (argparse fills these in main); benchmarks read them
# so smoke runs and TPU runs share one code path
OPTS: dict = {"smoke": False, "grid_ii": None, "grid_oo": None,
              "grid_bb": None, "reps": None}


def _emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    _ROWS.append((name, us_per_call, derived))


def _timed(fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def _provenance(seed=None, **extra) -> dict:
    """Run provenance stamped into every results/BENCH_*.json: git SHA,
    JAX version + backend/device, wall-clock (UTC), and the scenario
    seed — enough to answer "which code, which machine, which run
    produced this number" from the artifact alone."""
    import datetime
    import platform
    import subprocess
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10).stdout.strip()
    except Exception:
        sha = ""
    prov = {
        "git_sha": sha or "unknown",
        "python": platform.python_version(),
        "numpy": np.__version__,
        "wall_clock_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        import jax
        prov["jax"] = jax.__version__
        prov["backend"] = jax.default_backend()
        prov["device"] = jax.devices()[0].device_kind
    except Exception:
        prov["jax"] = None
    if seed is not None:
        prov["seed"] = seed
    prov.update(extra)
    return prov


def _write_bench(filename: str, payload: dict, seed=None) -> None:
    """The one way benchmark artifacts reach results/: provenance
    stamped, parent dir ensured, stable JSON shape."""
    payload = dict(payload)
    payload["provenance"] = _provenance(seed=seed)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / filename).write_text(json.dumps(payload, indent=1))


# ---------------------------------------------------------------------------
def _data():
    from repro.bench.datasets import load_or_make, train_test_split
    ds = load_or_make("inhouse")
    return ds, train_test_split(ds, test_frac=0.3, seed=0)


def fig2_exponential_fits():
    from repro.core.ala import ALA
    ds, (train, test) = _data()
    ala, us = _timed(lambda: ALA().fit(*train.workload))
    in_err = ala.score(*train.workload)
    REPORT["fig2"] = {"db_groups": len(ala.db), "train_median_ape": in_err,
                      "fit_db_s": ala.timings["fit_db_s"],
                      "fit_predictor_s": ala.timings["fit_predictor_s"]}
    _emit("fig2_exponential_fits", us,
          f"groups={len(ala.db)};train_medAPE={in_err:.2f}%")
    return ala, train, test


def fig3_param_prediction():
    """Hold out entire (ii,oo) groups; ML must extrapolate their params."""
    from repro.core.ala import ALA
    from repro.core.annealing import median_ape
    ds, _ = _data()
    ii, oo, bb, thpt = ds.workload
    rng = np.random.default_rng(7)
    pairs = np.unique(np.stack([ii, oo], 1), axis=0)
    held = pairs[rng.choice(len(pairs), size=max(4, len(pairs) // 5),
                            replace=False)]
    hmask = np.zeros(len(ii), bool)
    for p in held:
        hmask |= (ii == p[0]) & (oo == p[1])
    ala = ALA().fit(ii[~hmask], oo[~hmask], bb[~hmask], thpt[~hmask])
    (pred, us) = _timed(ala.predict, ii[hmask], oo[hmask], bb[hmask])
    err = median_ape(thpt[hmask], pred)
    REPORT["fig3"] = {"held_groups": len(held), "unseen_median_ape": err}
    _emit("fig3_param_prediction", us,
          f"unseen_pairs_medAPE={err:.2f}%")


def fig6_rq1_training_sets():
    from repro.core.ala import ALA
    from repro.bench.datasets import INHOUSE_BB, INHOUSE_II, INHOUSE_OO
    ds, _ = _data()
    ii, oo, bb, thpt = ds.workload
    rng = np.random.default_rng(0)

    def experiment_masks():
        # Exp1: broad balanced coverage (uniform 50% of rows)
        e1 = rng.random(len(ii)) < 0.5
        # Exp2: dense clusters spread across the range, all bb incl. large
        # (paper: "densely clustered metrics within specific regions")
        e2 = (np.isin(ii, (INHOUSE_II[0], INHOUSE_II[1], INHOUSE_II[4],
                           INHOUSE_II[7]))
              & np.isin(oo, (INHOUSE_OO[0], INHOUSE_OO[1], INHOUSE_OO[4],
                             INHOUSE_OO[5])))
        # Exp3: no large batch sizes (bb <= 32)
        e3 = bb <= 32
        # Exp4: sparse across the whole range (every other value per dim)
        e4 = (np.isin(ii, INHOUSE_II[::2]) & np.isin(oo, INHOUSE_OO[::2])
              & np.isin(bb, INHOUSE_BB[::2]))
        return {"exp1_broad": e1, "exp2_dense_clusters": e2,
                "exp3_no_large_bb": e3, "exp4_sparse": e4}

    out = {}
    for name, m in experiment_masks().items():
        ala, us = _timed(
            lambda m=m: ALA().fit(ii[m], oo[m], bb[m], thpt[m]))
        pred = ala.predict(ii[~m], oo[~m], bb[~m])
        ape = np.abs(pred - thpt[~m]) / np.maximum(np.abs(thpt[~m]), 1e-9) \
            * 100.0
        stats = {"median": float(np.median(ape)),
                 "p90": float(np.percentile(ape, 90)),
                 "mean": float(ape.mean()), "n_train": int(m.sum()),
                 "hist": np.histogram(np.clip(ape, 0, 100),
                                      bins=20)[0].tolist()}
        out[name] = stats
        _emit(f"fig6_rq1_{name}", us,
              f"medAPE={stats['median']:.2f}%;p90={stats['p90']:.1f}%")
    REPORT["fig6_rq1"] = out


def fig7_rq2_baselines(n_sa_iters: int = 40):
    from repro.core.ala import ALA
    from repro.core.annealing import (SAConfig, anneal, median_ape,
                                      subset_mask)
    from repro.core.baselines import make_baselines
    ds, (train, test) = _data()

    # (a) headline comparison on the train/test split
    comp = {}
    ala, us_ala = _timed(lambda: ALA().fit(*train.workload))
    comp["ALA"] = {"median_ape": ala.score(*test.workload),
                   "train_us": us_ala}
    for name, bl in make_baselines().items():
        _, us = _timed(bl.fit, *train.workload)
        e = median_ape(test.workload[3], bl.predict(*test.workload[:3]))
        comp[name] = {"median_ape": e, "train_us": us}
        _emit(f"fig7_rq2_{name}", us, f"medAPE={e:.2f}%")
    _emit("fig7_rq2_ALA", us_ala,
          f"medAPE={comp['ALA']['median_ape']:.2f}%")

    # (b) error over SA iterations: ALA vs baselines on the same subsets
    sa_cfg = SAConfig(n_iters=n_sa_iters, seed=0,
                      gbt_kw=dict(n_estimators=40, learning_rate=0.2,
                                  max_depth=4))
    log, us_sa = _timed(lambda: anneal(train.workload, test.workload,
                                       sa_cfg))
    ii, oo, bb, thpt = train.workload
    tii, too, tbb, tthpt = test.workload
    traj = {"ALA": list(map(float, log.errors))}
    for name, bl in make_baselines().items():
        errs = []
        for s in log.subsets:
            m = subset_mask(ii, oo, bb, s)
            if m.sum() < 4:
                errs.append(100.0)
                continue
            bl.fit(ii[m], oo[m], bb[m], thpt[m])
            errs.append(float(median_ape(tthpt,
                                         bl.predict(tii, too, tbb))))
        traj[name] = errs
    summary = {k: {"median": float(np.median(v)),
                   "final": float(v[-1])} for k, v in traj.items()}
    REPORT["fig7_rq2"] = {"comparison": comp,
                          "sa_median_by_method": summary,
                          "sa_trajectory": traj,
                          "sa_us": us_sa, "n_iters": n_sa_iters}
    _emit("fig7_rq2_sa_trajectory", us_sa,
          ";".join(f"{k}={v['median']:.1f}%" for k, v in summary.items()))
    return log


def fig8_rq3_model_zoo():
    from repro.core.registry import ModelRegistry
    from repro.bench.datasets import load_or_make, train_test_split
    suite = load_or_make("suite")
    out = {}
    us_total = 0.0
    for arch in np.unique(suite["model"]):
        sub = suite.filter(model=arch)
        tr, te = train_test_split(sub, 0.3, seed=1)
        reg = ModelRegistry()
        _, us = _timed(reg.fit, tr, n_estimators=60, learning_rate=0.15)
        us_total += us
        pred = reg.predict(te)
        ape = np.abs(pred - te["thpt"]) / np.maximum(te["thpt"], 1e-9) * 100
        out[str(arch)] = {"median": float(np.median(ape)),
                          "p90": float(np.percentile(ape, 90)),
                          "n": int(len(te))}
    REPORT["fig8_rq3"] = out
    worst = max(out.items(), key=lambda kv: kv[1]["median"])
    _emit("fig8_rq3_model_zoo", us_total,
          f"archs={len(out)};median_range="
          f"{min(v['median'] for v in out.values()):.1f}-"
          f"{worst[1]['median']:.1f}%;worst={worst[0]}")


def table1_rq4_uncertainty():
    from repro.core.ala import ALA
    from repro.core.annealing import SAConfig
    from repro.bench.datasets import load_or_make
    ds, (train, test) = _data()
    ala = ALA()
    ala.cfg.sa = SAConfig(n_iters=40, seed=3,
                          gbt_kw=dict(n_estimators=40, learning_rate=0.2,
                                      max_depth=4))
    ala.fit(*train.workload)
    ala.explore(test.workload)
    ala.fit_error()

    rows = {}

    def case(name, data, actual_err):
        (pe, conf), us = _timed(ala.estimate, data)
        rows[name] = {"predicted_error": float(pe),
                      "confidence": float(conf),
                      "actual_error": float(actual_err)}
        _emit(f"table1_rq4_{name}", us,
              f"pred={pe:.2f}%;conf={conf:.2f};actual={actual_err:.2f}%")

    # (1) same-model held-out subset (paper: "LLAMA Subset")
    case("llama_subset", test.workload, ala.score(*test.workload))

    # (2) different model family, same hardware (paper: Mistral 7B)
    suite = load_or_make("suite")
    other = suite.filter(model="llama3.2-3b", back="vllm-jax")
    ow = other.workload
    case("other_model_llama3.2-3b", ow, ala.score(*ow))

    # (3) hardware mismatch (paper: Qwen2-7B on Intel PVC)
    mis = load_or_make("mismatch")
    mw = mis.workload
    case("hw_mismatch_qwen_legacy", mw, ala.score(*mw))

    REPORT["table1_rq4"] = rows


def perf_vmapped_fit():
    """Beyond-paper: one vmapped-LM XLA call vs a python loop of scalar
    numpy LM fits (the scipy-curve_fit-style baseline)."""
    from repro.core.expmodel import exp_model, initial_params
    from repro.core.fit import fit_exponential_groups, fit_exponential_numpy
    rng = np.random.default_rng(0)
    groups = []
    for g in range(512):
        bbv = np.array([1, 2, 4, 8, 16, 32, 64, 128, 256], float)
        a, b = rng.uniform(100, 5000), rng.uniform(0.01, 0.3)
        c = rng.uniform(500, 20000)
        y = exp_model(bbv, a, b, min(c + a, 30000)) \
            * rng.lognormal(0, 0.03, len(bbv))
        groups.append((bbv, y, initial_params(bbv, y)))
    fit_exponential_groups(groups[:2])       # warm up compile
    _, us_batch = _timed(fit_exponential_groups, groups)
    t0 = time.perf_counter()
    for g in groups:
        fit_exponential_numpy(*g, iters=60)
    us_loop = (time.perf_counter() - t0) * 1e6
    REPORT["perf_vmapped_fit"] = {"groups": len(groups),
                                  "batched_us": us_batch,
                                  "loop_us": us_loop,
                                  "speedup": us_loop / max(us_batch, 1e-9)}
    _emit("perf_vmapped_fit", us_batch,
          f"speedup_vs_scalar_loop={us_loop / max(us_batch, 1e-9):.1f}x")


def perf_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import ops as fa
    from repro.kernels.decode_attention import ops as da
    from repro.kernels.rmsnorm import ops as rms
    from repro.kernels.gbt_hist import ops as gh

    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 1024, 2, 64), jnp.float32)
    x = jax.random.normal(key, (4096, 1024), jnp.float32)
    scale = jnp.ones((1024,))
    bins = jax.random.randint(key, (8192, 8), 0, 64)
    g = jax.random.normal(key, (8192,))
    qd = jax.random.normal(key, (8, 16, 64), jnp.float32)
    kd = jax.random.normal(key, (8, 2048, 4, 64), jnp.float32)

    cases = {
        "flash_attention_1k": lambda: fa.flash_attention(
            q, k, k, force="ref").block_until_ready(),
        "decode_attention_2k": lambda: da.decode_attention(
            qd, kd, kd, jnp.array(2000), force="ref").block_until_ready(),
        "rmsnorm_4kx1k": lambda: rms.rmsnorm(
            x, scale, force="ref").block_until_ready(),
        "gbt_hist_8kx8": lambda: gh.build_histograms(
            bins, g, jnp.abs(g), n_bins=64,
            force="ref").block_until_ready(),
    }
    out = {}
    for name, fn in cases.items():
        fn()  # warmup/compile
        _, us = _timed(fn)
        out[name] = us
        _emit(f"perf_kernel_{name}", us, "cpu_reference_path")
    REPORT["perf_kernels_cpu_ref_us"] = out


def sa_engine(n_proposals: int = 60, n_chains: int = 4):
    """Legacy serial SA vs the batched K-chain engine at an equal
    proposal budget.  Both engines score subsets with the same inner
    GBT; the batched one wins on architecture: a fixed-shape masked LM
    solve (one XLA compile per process instead of one per padded subset
    shape), a shared fingerprint cache across chains, vectorized subset
    masking, and candidate/output-joint GBT growth.  Writes
    results/BENCH_sa.json."""
    from repro.core.annealing import SAConfig, anneal, anneal_batched
    ds, (train, test) = _data()
    gbt_kw = dict(n_estimators=40, learning_rate=0.2, max_depth=4)

    cfg_legacy = SAConfig(n_iters=n_proposals, seed=0, gbt_kw=gbt_kw)
    log_l, us_l = _timed(lambda: anneal(train.workload, test.workload,
                                        cfg_legacy))

    cfg_batched = SAConfig(n_iters=n_proposals // n_chains, seed=0,
                           gbt_kw=gbt_kw, n_chains=n_chains)
    log_b, us_b = _timed(lambda: anneal_batched(train.workload,
                                                test.workload, cfg_batched))

    speedup = us_l / max(us_b, 1e-9)
    out = {
        "n_proposals": n_proposals,
        "n_chains": n_chains,
        # best_error: what each engine reports (legacy = final chain
        # state; batched = global min).  best_ape: min over every logged
        # evaluation — the like-for-like quality comparison.
        "legacy": {"wall_s": us_l / 1e6,
                   "best_error": float(log_l.best_error),
                   "best_ape": float(min(log_l.errors)),
                   "n_evals": len(log_l.errors)},
        "batched": {"wall_s": us_b / 1e6,
                    "best_error": float(log_b.best_error),
                    "best_ape": float(min(log_b.errors)),
                    "n_evals": len(log_b.errors)},
        "speedup": speedup,
        "equal_or_better_ape": bool(min(log_b.errors) <= min(log_l.errors)),
    }
    REPORT["sa_engine"] = out
    _write_bench("BENCH_sa.json", out, seed=0)
    _emit("sa_engine_legacy", us_l, f"best_medAPE={log_l.best_error:.2f}%")
    _emit("sa_engine_batched", us_b,
          f"best_medAPE={log_b.best_error:.2f}%;speedup={speedup:.1f}x")
    return out


def uncertainty_engine(n_queries: int = 64, n_subsets: int = 200,
                       n_chains: int = 4):
    """Serial Alg 7+8 (one query at a time through the numpy reference)
    vs the batched engine (whole fleet through the jitted PackedForest
    + SubsetBank kernel) at equal query count.  The two paths share the
    fixed-bin contract, so results must agree to <= 1e-6.  Writes
    results/BENCH_uncertainty.json."""
    from repro.core.ala import ALA
    from repro.core.annealing import SAConfig
    ds, (train, test) = _data()

    # an SA log with >= n_subsets entries (chains + anchor + K*iters)
    n_iters = -(-(n_subsets - n_chains - 1) // n_chains)
    ala = ALA()
    ala.cfg.sa = SAConfig(n_iters=n_iters, seed=0, n_chains=n_chains,
                          gbt_kw=dict(n_estimators=30, learning_rate=0.2,
                                      max_depth=4))
    ala.fit(*train.workload)
    ala.explore(test.workload)
    ala.fit_error()
    bank = ala.bank(max_subsets=n_subsets)

    # fleet of query workloads: random row-subsets of the held-out split
    rng = np.random.default_rng(0)
    tw = test.workload
    queries = []
    for _ in range(n_queries):
        m = rng.random(len(tw[0])) < 0.6
        if m.sum() < 2:
            m[:2] = True
        queries.append(tuple(v[m] for v in tw))

    ala.estimate_batch(queries)     # warm up the two jitted shapes once
    (eb, db_, cb), us_b = _timed(ala.estimate_batch, queries)

    def serial():
        es, dss, cs = [], [], []
        for q in queries:
            e, d, c = ala.estimate_batch([q], backend="numpy")
            es.append(e[0]), dss.append(d[0]), cs.append(c[0])
        return np.asarray(es), np.asarray(dss), np.asarray(cs)

    (es, ds_, cs), us_s = _timed(serial)

    speedup = us_s / max(us_b, 1e-9)
    max_diff = float(max(np.abs(eb - es).max(), np.abs(db_ - ds_).max(),
                         np.abs(cb - cs).max()))
    out = {
        "n_queries": n_queries,
        "n_subsets": int(bank.n_subsets),
        "n_valid_subsets": int(bank.valid.sum()),
        "serial": {"wall_s": us_s / 1e6},
        "batched": {"wall_s": us_b / 1e6},
        "speedup": speedup,
        "max_abs_diff": max_diff,
        "parity_ok": bool(max_diff <= 1e-6),
        "confidence_range": [float(cb.min()), float(cb.max())],
        "predicted_error_range": [float(eb.min()), float(eb.max())],
    }
    REPORT["uncertainty_engine"] = out
    _write_bench("BENCH_uncertainty.json", out, seed=0)
    _emit("uncertainty_engine_serial", us_s, f"queries={n_queries}")
    _emit("uncertainty_engine_batched", us_b,
          f"speedup={speedup:.1f}x;max_abs_diff={max_diff:.2e}")
    return out


def serving_engine(smoke=None, ttft_slo_s: float = 2.0):
    """Trace-driven continuous-batching fleet sim: ALA-in-the-loop
    autoscaling vs a static-bb single-replica baseline, swept over
    arrival processes x trace shapes x >= 3 archs.  Per arch it also
    round-trips the simulated steady-state windows through the adapter
    into a registry fit.  Writes results/BENCH_serving.json."""
    import itertools
    from repro.configs import get_config
    from repro.core.ala import ALA
    from repro.core.annealing import SAConfig
    from repro.core.registry import ModelRegistry
    from repro.perfmodel.simulator import (ServingSetup, sample_throughput,
                                           throughput)
    from repro.perfmodel.hardware import TPU_V5E
    from repro.serving.adapter import windows_to_dataset
    from repro.serving.autoscaler import ALAAutoscaler, StaticPolicy
    from repro.serving.simulator import SimConfig, simulate
    from repro.serving.traces import TraceConfig, make_trace, mix

    smoke = OPTS["smoke"] if smoke is None else smoke
    archs = ("llama3.1-8b",) if smoke else (
        "llama3.1-8b", "qwen2.5-32b", "phi3.5-moe-42b-a6.6b")
    horizon = 12.0 if smoke else 40.0
    shape = mix(("chat", 0.6), ("summarize", 0.2), ("generate", 0.2))
    # representative shape for calibrating arrival rates per arch
    REF_II, REF_OO = 512, 192
    grid = list(itertools.product(
        (128, 512, 2048) if smoke else (128, 256, 512, 1024, 2048),
        (64, 256) if smoke else (64, 128, 256, 512),
        (1, 4, 16, 64) if smoke else (1, 2, 4, 8, 16, 32, 64, 128)))
    sa_iters = 4 if smoke else 10

    report = {"smoke": bool(smoke), "ttft_slo_s": ttft_slo_s, "archs": {}}
    for arch in archs:
        cfg = get_config(arch)
        chips = 8 if cfg.param_count() > 1e10 else 4
        setup = ServingSetup(cfg=cfg, hw=TPU_V5E, chips=chips)

        # ALA trained on a static roofline grid (the PR-1..3 pipeline)
        rng = np.random.default_rng(0)
        rows = [(ii, oo, bb, t) for ii, oo, bb in grid
                for t in sample_throughput(setup, ii, oo, bb, 2, rng)]
        gi, go, gb, gt = map(np.asarray, zip(*rows))
        te = rng.random(len(gi)) < 0.3
        ala = ALA()
        ala.cfg.sa = SAConfig(n_iters=sa_iters, seed=0, n_chains=4,
                              gbt_kw=dict(n_estimators=30,
                                          learning_rate=0.2, max_depth=4))
        ala.fit(gi[~te], go[~te], gb[~te], gt[~te])
        ala.explore((gi[te], go[te], gb[te], gt[te]))
        ala.fit_error()

        # arrival rates sized off single-replica capacity: the baseline
        # replica saturates during bursts, so scaling has to pay off
        cap_req_s = throughput(setup, REF_II, REF_OO, 64) / REF_OO
        scenarios = {"poisson": TraceConfig(
            arrival="poisson", rate=1.2 * cap_req_s, horizon_s=horizon,
            shape_mix=shape, seed=11)}
        if not smoke:
            scenarios["mmpp"] = TraceConfig(
                arrival="mmpp", rate=0.6 * cap_req_s,
                burst_rate=2.4 * cap_req_s, horizon_s=horizon,
                shape_mix=shape, seed=13)
            scenarios["gamma"] = TraceConfig(
                arrival="gamma", rate=1.0 * cap_req_s, cv=3.0,
                horizon_s=horizon, shape_mix=shape, seed=17)

        sim_cfg = SimConfig(setup=setup, batch_cap=64, n_replicas=1,
                            max_replicas=6)
        arch_out = {"chips": chips, "scenarios": {}}
        events = wall = 0.0
        hits = {"static": 0, "ala": 0}
        total = 0
        adapter_res = None
        for sname, tc in scenarios.items():
            tr = make_trace(tc)
            runs = {}
            for pname, policy in (
                    ("static", StaticPolicy(n_replicas=1, batch_cap=64)),
                    ("ala", ALAAutoscaler(ala=ala, max_replicas=6))):
                res, us = _timed(simulate, tr, sim_cfg, policy)
                events += res.n_events
                wall += us / 1e6
                n_ok = sum(1 for r in res.records
                           if r.ttft_s <= ttft_slo_s)
                hits[pname] += n_ok
                runs[pname] = {
                    "slo_attainment": n_ok / max(len(res.records), 1),
                    "goodput_tok_s": res.goodput_tok_s,
                    # completed-only view: every request completes in the
                    # fault-free runs, and the committed numbers predate
                    # the shed-aware (inf-counting) default
                    "p95_ttft_s": res.ttft_percentile(95,
                                                      on_missing="drop"),
                    "replica_seconds": res.replica_seconds,
                    "completed": len(res.completed)}
                if pname == "ala":
                    adapter_res = res
            total += len(tr)
            arch_out["scenarios"][sname] = dict(
                n_requests=len(tr), **runs)

        # adapter round-trip: simulated windows -> Dataset -> registry fit
        ds = windows_to_dataset(adapter_res, setup, arch,
                                window_s=horizon / 8.0)
        reg = ModelRegistry().fit(ds, n_estimators=20)
        pred = reg.predict(ds)
        arch_out["adapter"] = {
            "rows": len(ds),
            "fit_finite": bool(np.isfinite(pred).all()),
            "median_ape": float(np.median(
                np.abs(pred - ds["thpt"])
                / np.maximum(ds["thpt"], 1e-9) * 100.0))}
        arch_out["events_per_sec"] = events / max(wall, 1e-9)
        arch_out["static_attainment"] = hits["static"] / max(total, 1)
        arch_out["ala_attainment"] = hits["ala"] / max(total, 1)
        arch_out["ala_ge_static"] = bool(
            arch_out["ala_attainment"] >= arch_out["static_attainment"])
        report["archs"][arch] = arch_out
        _emit(f"serving_engine_{arch}", wall * 1e6,
              f"evps={arch_out['events_per_sec']:.0f};"
              f"slo_ala={arch_out['ala_attainment']:.3f};"
              f"slo_static={arch_out['static_attainment']:.3f}")

    report["all_ala_ge_static"] = all(
        a["ala_ge_static"] for a in report["archs"].values())
    # smoke runs get their own artifact/report key so the CI command never
    # clobbers the committed full-run numbers
    key = "serving_engine_smoke" if smoke else "serving_engine"
    REPORT[key] = report
    _write_bench(f"BENCH_serving{'_smoke' if smoke else ''}.json", report,
                 seed=11)
    return report


def fleet_engine(smoke=None):
    """Fleet-scale vectorized serving engine: a 3-tenant diurnal/flash
    workload (100k+ requests in the full run) through the time-bucketed
    array engine, with an in-run heap-engine baseline on a trace slice
    and a hard events/s speedup gate vs the committed BENCH_serving
    heap numbers.  Writes results/BENCH_fleet.json."""
    from repro.configs import get_config
    from repro.perfmodel.simulator import ServingSetup
    from repro.perfmodel.hardware import TPU_V5E
    from repro.serving.simulator import SimConfig, simulate
    from repro.serving.traces import (FleetTraceConfig, TenantConfig,
                                      TraceConfig, make_fleet_trace, mix)
    from repro.staticcheck.tracers import assert_max_compiles

    smoke = OPTS["smoke"] if smoke is None else smoke
    # the committed full-run heap baseline (BENCH_serving.json): the
    # >=50x acceptance gate is anchored to its best arch
    heap_evps_recorded = 7684.5
    horizon = 60.0 if smoke else 2000.0
    setup = ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E,
                         chips=4)
    fcfg = FleetTraceConfig(tenants=(
        TenantConfig(name="chat",
                     trace=TraceConfig(arrival="poisson", rate=30.0,
                                       shape_mix=mix(("chat", 1.0))),
                     ttft_slo_s=1.5, diurnal_amp=0.4),
        TenantConfig(name="summarize",
                     trace=TraceConfig(arrival="gamma", rate=8.0, cv=2.0,
                                       shape_mix=mix(("summarize", 1.0))),
                     ttft_slo_s=8.0),
        TenantConfig(name="generate",
                     trace=TraceConfig(arrival="mmpp", rate=12.0,
                                       burst_rate=24.0,
                                       shape_mix=mix(("generate", 1.0))),
                     ttft_slo_s=4.0, flash_crowds=2, flash_mult=3.0,
                     flash_dur_s=15.0),
    ), horizon_s=horizon, seed=42)
    tr = make_fleet_trace(fcfg)
    if not smoke:
        assert len(tr) >= 100_000, f"scenario too small: {len(tr)}"

    cfg = SimConfig(setup=setup, batch_cap=64, n_replicas=8,
                    max_replicas=8, bucket_s=0.5)
    # best-of-2: the first run pays numpy/caching warm-up.  The timed
    # rerun is also the pow2 shape-bucketing gate: every shape bucket
    # was compiled by the warm run, so a steady-state replay may not
    # trigger a single XLA compile (smoke hard-gates; the full run
    # records the count in the artifact)
    res, us = _timed(simulate, tr, cfg, engine="fleet")
    with assert_max_compiles(0 if smoke else None,
                             label="fleet_engine post-warmup") as cgate:
        res, us2 = _timed(simulate, tr, cfg, engine="fleet")
    us = min(us, us2)
    evps = res.n_events / (us / 1e6)

    # same-machine heap baseline on a slice of the same workload (the
    # full heap run at this scale would take minutes)
    heap_slice = tr.slice(0.0, 20.0 if smoke else 60.0)
    href, hus = _timed(simulate, heap_slice, cfg, engine="heap")
    heap_evps = href.n_events / (hus / 1e6)

    slo = fcfg.slo_map
    meta = res.meta_metrics(slo_map=slo)
    speedup_recorded = evps / heap_evps_recorded
    speedup_inrun = evps / max(heap_evps, 1e-9)
    report = {
        "smoke": bool(smoke),
        "n_requests": len(tr),
        "n_events": res.n_events,
        "horizon_s": horizon,
        "bucket_s": cfg.bucket_s,
        "n_replicas": cfg.n_replicas,
        "wall_s": us / 1e6,
        "events_per_sec": evps,
        "heap_baseline": {
            "slice_requests": len(heap_slice),
            "slice_events": href.n_events,
            "events_per_sec": heap_evps,
            "recorded_events_per_sec": heap_evps_recorded},
        "speedup_vs_recorded_heap": speedup_recorded,
        "speedup_vs_inrun_heap": speedup_inrun,
        "fleet_attainment": meta["fleet_attainment"],
        "jain_fairness": meta["jain_fairness"],
        "goodput_tok_s": meta["goodput_tok_s"],
        "shed_rate": meta["shed_rate"],
        "per_tenant": {t: {"n": m["n_requests"],
                           "attainment": m["attainment"],
                           "goodput_share": m["goodput_share"]}
                       for t, m in meta["per_tenant"].items()},
        "compiles_post_warmup": cgate.count,
        "compile_gate": {"limit": cgate.limit,
                         "available": cgate.available}}
    # hard gates: full runs must clear the ISSUE's 50x floor against
    # the committed heap numbers; smoke runs (CI boxes, tiny horizon)
    # gate on an absolute events/s floor instead
    if smoke:
        assert evps >= 50_000.0, f"fleet engine too slow: {evps:.0f} ev/s"
    else:
        assert speedup_recorded >= 50.0, (
            f"speedup {speedup_recorded:.1f}x < 50x vs recorded heap "
            f"baseline {heap_evps_recorded} ev/s")
    res.check_conservation()
    key = "fleet_engine_smoke" if smoke else "fleet_engine"
    REPORT[key] = report
    _write_bench(f"BENCH_fleet{'_smoke' if smoke else ''}.json", report,
                 seed=42)
    _emit(key, us,
          f"evps={evps:.0f};x_recorded={speedup_recorded:.0f};"
          f"x_inrun={speedup_inrun:.0f};"
          f"attain={meta['fleet_attainment']:.3f}")
    return report


def online_engine(smoke=None):
    """Streaming ALA: an epoch-by-epoch trace feed through the
    ``OnlineALA`` incremental-refit engine, against a from-scratch
    ``ModelRegistry.fit`` + ``fit_uncertainty`` on the full concatenated
    data every epoch.  Each epoch slices the arrival trace, simulates it
    with the ALA autoscaler attached to the online engine (drift
    evidence can force recalibration), adapts the steady-state windows
    into a Dataset delta, and ingests it.  Records prediction parity
    (incremental vs from-scratch must agree to <= 1e-6 on the serving
    path) and the cumulative refit speedup.  Writes
    results/BENCH_online.json."""
    from repro.configs import get_config
    from repro.core.annealing import SAConfig, median_ape
    from repro.core.online import OnlineALA, OnlineConfig
    from repro.core.registry import ModelRegistry
    from repro.perfmodel.simulator import (ServingSetup, sample_throughput,
                                           throughput)
    from repro.perfmodel.hardware import TPU_V5E, feature_row
    from repro.serving.adapter import TRACE_BACKEND, windows_to_dataset
    from repro.serving.autoscaler import ALAAutoscaler
    from repro.serving.simulator import SimConfig, simulate
    from repro.serving.traces import TraceConfig, make_trace, mix
    from repro.core.dataset import Dataset
    from repro.staticcheck.tracers import assert_max_compiles, nan_guard

    smoke = OPTS["smoke"] if smoke is None else smoke
    archs = ("llama3.1-8b",) if smoke else ("llama3.1-8b", "qwen2.5-32b")
    n_epochs = 3 if smoke else 8
    epoch_s = 10.0 if smoke else 20.0
    REF_II, REF_OO = 512, 192
    grid = [(ii, oo, bb) for ii in ((128, 512, 2048) if smoke else
                                    (128, 256, 512, 1024, 2048))
            for oo in ((64, 256) if smoke else (64, 128, 256, 512))
            for bb in ((1, 4, 16, 64) if smoke else
                       (1, 2, 4, 8, 16, 32, 64, 128))]
    sa = SAConfig(n_iters=8 if smoke else 20, n_chains=2, seed=0,
                  gbt_kw=dict(n_estimators=20, learning_rate=0.2,
                              max_depth=3))
    gbt_kw = dict(n_estimators=20, learning_rate=0.15)
    eng = OnlineALA(OnlineConfig(sa=sa, warm_iters=3 if smoke else 6,
                                 gbt_kw=dict(sa.gbt_kw)))

    setups, traces, scalers, combos = {}, {}, {}, {}
    seed_rows = []
    for arch in archs:
        cfg = get_config(arch)
        chips = 8 if cfg.param_count() > 1e10 else 4
        setups[arch] = ServingSetup(cfg=cfg, hw=TPU_V5E, chips=chips)
        rng = np.random.default_rng(0)
        # calibration grid stamped onto the trace combination so epochs
        # extend — not sit beside — the static seed fit
        seed_rows += [dict(model=arch, acc=TPU_V5E.name, acc_count=chips,
                           back=TRACE_BACKEND, prec="bf16", mode="serve",
                           ii=ii, oo=oo, bb=bb, thpt=float(t),
                           **feature_row(TPU_V5E))
                      for ii, oo, bb in grid
                      for t in sample_throughput(setups[arch], ii, oo, bb,
                                                 2, rng)]
        cap_req_s = throughput(setups[arch], REF_II, REF_OO, 64) / REF_OO
        traces[arch] = make_trace(TraceConfig(
            arrival="mmpp", rate=0.7 * cap_req_s,
            burst_rate=2.0 * cap_req_s, horizon_s=n_epochs * epoch_s,
            shape_mix=mix(("chat", 0.7), ("generate", 0.3)), seed=29))

    # untimed warmup: run both pipelines once on the seed data so the
    # jitted shape buckets are compiled before either side is timed
    # (otherwise whichever path runs first is charged XLA compile time)
    seed_ds = Dataset.from_rows(seed_rows)
    warm = OnlineALA(OnlineConfig(sa=sa, warm_iters=3,
                                  gbt_kw=dict(sa.gbt_kw)))
    warm.ingest(seed_ds, **gbt_kw)
    ModelRegistry().fit(seed_ds, **gbt_kw).fit_uncertainty(
        seed_ds, seed=0, sa_cfg=sa, **sa.gbt_kw)

    # epoch 0: ingest the seed grids (initial full-budget fits)
    rep0, us0 = _timed(eng.ingest, seed_ds, **gbt_kw)
    inc_wall = us0 / 1e6
    for arch in archs:
        combos[arch] = eng.combo_of(next(r for r in seed_rows
                                         if r["model"] == arch))
        scalers[arch] = ALAAutoscaler(ala=eng.ala_for(combos[arch]),
                                     online=eng, combo=combos[arch],
                                     max_replicas=4)

    def scratch_fit():
        full = eng.full_data()
        reg = ModelRegistry().fit(full, **gbt_kw)
        reg.fit_uncertainty(full, seed=0, sa_cfg=sa, **sa.gbt_kw)
        return reg, full

    (reg_s, full), us_s = _timed(scratch_fit)
    scratch_wall = us_s / 1e6
    epochs_out = [{"epoch": 0, "rows": len(seed_ds),
                   "incremental_s": inc_wall, "scratch_s": scratch_wall,
                   "refit": len(rep0.refit), "skipped": len(rep0.skipped),
                   "drifted": 0}]
    inc_refit = scratch_refit = 0.0     # epochs >= 1: the refit loop
    epoch_compiles: list = []           # XLA compiles per refit epoch
    compile_budget = None               # set by the first measured epoch

    for e in range(n_epochs):
        deltas = []
        # epochs alternate which arch serves, so "refit only what
        # changed" has something to skip in the multi-arch run
        serving = [archs[e % len(archs)]] if len(archs) > 1 else archs
        for arch in serving:
            tr = traces[arch].slice(e * epoch_s, (e + 1) * epoch_s)
            if not len(tr):
                continue
            res = simulate(tr, SimConfig(setup=setups[arch], batch_cap=64,
                                         n_replicas=1, max_replicas=4,
                                         t_start=e * epoch_s),
                           scalers[arch])
            try:
                deltas.append(windows_to_dataset(
                    res, setups[arch], arch,
                    window_s=epoch_s / (4.0 if smoke else 8.0)))
            except ValueError:
                continue          # no steady-state window this epoch
        if not deltas:
            continue
        delta = deltas[0]
        for d in deltas[1:]:
            delta = delta.concat(d)
        # pow2 shape-bucketing gate: after the first measured epoch
        # sets the budget, no later epoch may compile more XLA
        # programs than it did (+2 slack for pow2 bucket crossings as
        # the data grows).  Smoke hard-gates; the full run records the
        # per-epoch counts in the artifact instead.
        with assert_max_compiles(compile_budget if smoke else None,
                                 label=f"online epoch {e + 1}") as cr:
            rep, us_i = _timed(eng.ingest, delta, **gbt_kw)
            (reg_s, full), us_s = _timed(scratch_fit)
        epoch_compiles.append(cr.count)
        if compile_budget is None and cr.available:
            compile_budget = cr.count + 2
        inc_wall += us_i / 1e6
        scratch_wall += us_s / 1e6
        inc_refit += us_i / 1e6
        scratch_refit += us_s / 1e6
        epochs_out.append({
            "epoch": e + 1, "rows": len(delta),
            "incremental_s": us_i / 1e6, "scratch_s": us_s / 1e6,
            "refit": len(rep.refit),
            "skipped": len(rep.skipped),
            "drifted": sum(1 for d in rep.drift.values() if d.drifted)})

    # parity on the serving path over every ingested row; nan_guard is
    # the runtime half of the contract checker — a NaN in either
    # predict path fails the benchmark with the offending leaf named
    p_inc = nan_guard(eng.predict, label="online.predict")(full)
    p_scr = nan_guard(reg_s.predict, label="scratch.predict")(full)
    parity = float(np.abs(p_inc - p_scr).max())
    med_inc = median_ape(full["thpt"].astype(np.float64), p_inc)
    med_scr = median_ape(full["thpt"].astype(np.float64), p_scr)
    _, _, conf_inc = eng.estimate(full, backend="numpy")
    speedup = scratch_wall / max(inc_wall, 1e-9)
    # epoch 0 is an identical full fit on both sides; the refit speedup
    # over epochs >= 1 is the number the online engine is for
    refit_speedup = scratch_refit / max(inc_refit, 1e-9)
    out = {
        "smoke": bool(smoke), "archs": list(archs), "n_epochs": n_epochs,
        "rows_total": len(full),
        "incremental_wall_s": inc_wall, "scratch_wall_s": scratch_wall,
        "speedup": speedup,
        "incremental_refit_s": inc_refit, "scratch_refit_s": scratch_refit,
        "refit_speedup": refit_speedup,
        "predict_parity_max_abs_diff": parity,
        "parity_ok": bool(parity <= 1e-6),
        "median_ape_incremental": med_inc,
        "median_ape_scratch": med_scr,
        "mean_confidence_incremental": float(np.mean(conf_inc)),
        "recalibration_requests": sum(len(s.recalibrations)
                                      for s in scalers.values()),
        "epoch_compiles": epoch_compiles,
        "compile_budget": compile_budget,
        "epochs": epochs_out,
    }
    key = "online_engine_smoke" if smoke else "online_engine"
    REPORT[key] = out
    _write_bench(f"BENCH_online{'_smoke' if smoke else ''}.json", out,
                 seed=29)
    _emit("online_engine_incremental", inc_refit * 1e6,
          f"medAPE={med_inc:.2f}%;parity={parity:.2e}")
    _emit("online_engine_scratch", scratch_refit * 1e6,
          f"medAPE={med_scr:.2f}%;refit_speedup={refit_speedup:.1f}x")
    return out


def fault_engine(smoke=None, ttft_slo_s: float = 2.0):
    """Fault-injection benchmark: the serving stack under three fault
    scenarios (crash storm, straggler epoch, telemetry corruption),
    comparing a static baseline against the online-ALA autoscaler with
    and without the robust-ingestion gate.  Every scenario corrupts the
    telemetry stream at least mildly, so the gated arm's advantage is
    measured everywhere, not just in the corruption scenario.  Fault
    timelines are seed-deterministic (the plan fingerprint is recorded
    and re-derived to prove it) and request conservation (admitted ==
    completed + shed) is asserted for every run — an inconsistency
    fails the benchmark, which is the CI smoke gate.  Writes
    results/BENCH_faults.json."""
    from repro.configs import get_config
    from repro.core.annealing import SAConfig
    from repro.core.dataset import Dataset
    from repro.core.online import OnlineALA, OnlineConfig
    from repro.perfmodel.simulator import ServingSetup, sample_throughput, \
        throughput
    from repro.perfmodel.hardware import TPU_V5E, feature_row
    from repro.serving.adapter import (TRACE_BACKEND, summarize_windows,
                                       windows_to_rows)
    from repro.serving.autoscaler import ALAAutoscaler, StaticPolicy
    from repro.serving.faults import FaultConfig, FaultInjector, FaultPlan
    from repro.serving.simulator import SimConfig, simulate
    from repro.serving.traces import TraceConfig, make_trace, mix

    smoke = OPTS["smoke"] if smoke is None else smoke
    arch = "llama3.1-8b"
    cfg = get_config(arch)
    chips = 4
    setup = ServingSetup(cfg=cfg, hw=TPU_V5E, chips=chips)
    n_epochs = 2 if smoke else 5
    epoch_s = 8.0 if smoke else 20.0
    horizon = n_epochs * epoch_s
    max_replicas = 5
    REF_II, REF_OO = 512, 192
    cap_req_s = throughput(setup, REF_II, REF_OO, 64) / REF_OO
    trace = make_trace(TraceConfig(
        arrival="mmpp", rate=1.5 * cap_req_s, burst_rate=3.0 * cap_req_s,
        horizon_s=horizon, shape_mix=mix(("chat", 0.7), ("generate", 0.3)),
        seed=41))

    # mild corruption rides along in every scenario; the third scenario
    # turns it up and switches the other fault classes off
    mild = dict(drop_p=0.03, dup_p=0.08, poison_nan_p=0.05,
                poison_scale_p=0.18)
    heavy = dict(drop_p=0.05, dup_p=0.10, poison_nan_p=0.10,
                 poison_scale_p=0.30)
    scenarios = {
        "crash_storm": FaultConfig(
            seed=7, horizon_s=horizon, n_replicas=max_replicas,
            mttf_s=0.9 * epoch_s, mttr_s=3.0, restart_warmup_s=1.0,
            **mild),
        # light background crashes ride along: replica failures scale
        # with fleet size, so panic over-provisioning (the poisoned
        # arm's failure mode) carries real exposure, as it would in a
        # production fleet
        "straggler_epoch": FaultConfig(
            seed=8, horizon_s=horizon, n_replicas=max_replicas,
            straggler_rate_hz=0.06, straggler_dur_s=0.6 * epoch_s,
            straggler_slow=4.0, mttf_s=2.5 * epoch_s, mttr_s=3.0,
            restart_warmup_s=1.0, **mild),
        "telemetry_corruption": FaultConfig(
            seed=9, horizon_s=horizon, n_replicas=max_replicas, **heavy),
    }

    grid = [(ii, oo, bb) for ii in ((128, 512, 2048) if smoke else
                                    (128, 256, 512, 1024, 2048))
            for oo in ((64, 256) if smoke else (64, 128, 256))
            for bb in (1, 4, 16, 64)]
    sa = SAConfig(n_iters=4 if smoke else 12, n_chains=2, seed=0,
                  gbt_kw=dict(n_estimators=20, learning_rate=0.2,
                              max_depth=3))
    gbt_kw = dict(n_estimators=20, learning_rate=0.15)
    rng = np.random.default_rng(0)
    # the prior is deliberately miscalibrated (derated throughput): the
    # online loop must *learn* true capacity from trace telemetry, which
    # is exactly the channel corruption attacks — a clean prior would
    # let the ungated arm coast on it and hide the poison
    PRIOR_DERATE = 0.5
    seed_rows = [dict(model=arch, acc=TPU_V5E.name, acc_count=chips,
                      back=TRACE_BACKEND, prec="bf16", mode="serve",
                      ii=ii, oo=oo, bb=bb, thpt=PRIOR_DERATE * float(t),
                      **feature_row(TPU_V5E))
                 for ii, oo, bb in grid
                 for t in sample_throughput(setup, ii, oo, bb, 1, rng)]
    seed_ds = Dataset.from_rows(seed_rows)

    def run_arm(pname: str, plan: FaultPlan):
        """One policy through the scenario's epochal loop.  Each arm
        gets a fresh injector from the SAME plan, so all arms face the
        identical crash/straggler timeline and corruption process."""
        inj = FaultInjector(plan)
        eng = scaler = None
        if pname != "static":
            eng = OnlineALA(OnlineConfig(
                sa=sa, warm_iters=3 if smoke else 5,
                gbt_kw=dict(sa.gbt_kw), gate=(pname == "ala_gated")))
            eng.ingest(seed_ds, **gbt_kw)
            combo = eng.combo_of(seed_rows[0])
            scaler = ALAAutoscaler(ala=eng.ala_for(combo), online=eng,
                                   combo=combo, max_replicas=max_replicas)
        agg = dict(admitted=0, completed=0, shed=0, retries=0,
                   slo_hits=0, out_toks=0.0, span_s=0.0,
                   replica_s=0.0, failed_s=0.0, n_quarantined=0)
        ttfts = []
        for e in range(n_epochs):
            tr = trace.slice(e * epoch_s, (e + 1) * epoch_s)
            if not len(tr):
                continue
            policy = (StaticPolicy(n_replicas=2, batch_cap=64)
                      if pname == "static" else scaler)
            res = simulate(tr, SimConfig(
                setup=setup, batch_cap=64, n_replicas=2,
                max_replicas=max_replicas, t_start=e * epoch_s,
                faults=inj, max_retries=2,
                shed_after_s=4.0 * ttft_slo_s), policy)
            res.check_conservation()          # the CI smoke gate
            acc = res.accounting()
            agg["admitted"] += acc["admitted"]
            agg["completed"] += acc["completed"]
            agg["shed"] += acc["shed"]
            agg["retries"] += res.n_retries
            agg["slo_hits"] += sum(
                1 for r in res.records
                if not r.shed and r.first_token_s is not None
                and r.ttft_s <= ttft_slo_s)
            agg["out_toks"] += sum(r.oo for r in res.completed)
            agg["span_s"] += res.sim_end_s - res.t_start
            den = res.replica_seconds / max(res.availability, 1e-9)
            agg["replica_s"] += res.replica_seconds
            agg["failed_s"] += den - res.replica_seconds
            ttfts += [r.ttft_s for r in res.records]
            if eng is not None:
                rows = windows_to_rows(
                    summarize_windows(res, window_s=epoch_s / 8.0),
                    setup, arch)
                rows, _ = inj.corrupt_rows(rows)
                if rows:
                    rep = eng.ingest(Dataset.from_rows(
                        rows, require_finite=None), **gbt_kw)
                    agg["n_quarantined"] += rep.n_quarantined
        den = agg["replica_s"] + agg["failed_s"]
        finite = np.asarray([t for t in ttfts if np.isfinite(t)])
        return {
            "slo_attainment": agg["slo_hits"] / max(agg["admitted"], 1),
            "goodput_tok_s": agg["out_toks"] / max(agg["span_s"], 1e-9),
            "availability": agg["replica_s"] / den if den > 0 else 1.0,
            "admitted": agg["admitted"], "completed": agg["completed"],
            "shed": agg["shed"], "retries": agg["retries"],
            "p95_ttft_completed_s": (float(np.percentile(finite, 95))
                                     if len(finite) else float("inf")),
            "n_quarantined": agg["n_quarantined"],
            "accounting_ok": agg["admitted"] == agg["completed"]
            + agg["shed"],
        }

    report = {"smoke": bool(smoke), "arch": arch, "chips": chips,
              "ttft_slo_s": ttft_slo_s, "n_epochs": n_epochs,
              "epoch_s": epoch_s, "n_requests": len(trace),
              "scenarios": {}}
    wall = 0.0
    for sname, fcfg in scenarios.items():
        plan = FaultPlan.build(fcfg)
        fp = plan.fingerprint()
        out = {"fingerprint": fp,
               "timeline_deterministic":
                   FaultPlan.build(fcfg).fingerprint() == fp,
               "n_crash_windows": len(plan.crashes),
               "n_straggler_windows": len(plan.stragglers),
               "policies": {}}
        for pname in ("static", "ala_ungated", "ala_gated"):
            arm, us = _timed(run_arm, pname, plan)
            wall += us / 1e6
            out["policies"][pname] = arm
            if not arm["accounting_ok"]:
                raise RuntimeError(
                    f"fault_engine[{sname}/{pname}]: accounting broken: "
                    f"admitted {arm['admitted']} != completed "
                    f"{arm['completed']} + shed {arm['shed']}")
        pol = out["policies"]
        out["gated_beats_static"] = bool(
            pol["ala_gated"]["slo_attainment"]
            >= pol["static"]["slo_attainment"])
        out["gated_beats_ungated"] = bool(
            pol["ala_gated"]["slo_attainment"]
            >= pol["ala_ungated"]["slo_attainment"])
        report["scenarios"][sname] = out
        _emit(f"fault_engine_{sname}", us,
              f"slo_gated={pol['ala_gated']['slo_attainment']:.3f};"
              f"slo_ungated={pol['ala_ungated']['slo_attainment']:.3f};"
              f"slo_static={pol['static']['slo_attainment']:.3f}")
    report["all_gated_wins"] = all(
        s["gated_beats_static"] and s["gated_beats_ungated"]
        for s in report["scenarios"].values())
    key = "fault_engine_smoke" if smoke else "fault_engine"
    REPORT[key] = report
    _write_bench(f"BENCH_faults{'_smoke' if smoke else ''}.json", report,
                 seed=41)
    return report


def transfer_engine(smoke=None, ttft_slo_s: float = 2.0):
    """Cross-hardware ALA transfer + heterogeneous fleet placement.

    (a) Fit the registry (+ uncertainty pipeline) on TPU-v5e rows only,
        then predict every other registered accelerator's ground-truth
        grid via registry transfer with the analytic roofline scaler —
        per-target-hardware medAPE.
    (b) Alg 8 confidence ordering: on *identical* workloads, the
        transferred (cross-hardware) confidence must be strictly below
        the same-hardware confidence for every target.
    (c) Mixed TPU+GPU fleet: the ALA autoscaler placing scale-up
        replicas by transfer-derated predictions (hardware-aware) vs the
        same controller cycling the pool blindly — shed-aware SLO
        attainment / replica-seconds, on both serving engines (parity
        reported).  Writes results/BENCH_transfer.json."""
    import itertools
    from repro.bench.datasets import FRAMEWORKS, _simulate
    from repro.configs import get_config
    from repro.core.annealing import SAConfig
    from repro.core.dataset import Dataset
    from repro.core.registry import ModelRegistry
    from repro.perfmodel.hardware import (PROFILES, feature_row,
                                          hardware_distance, profile)
    from repro.perfmodel.simulator import (ServingSetup, throughput,
                                           throughput_batch)
    from repro.serving.autoscaler import ALAAutoscaler
    from repro.serving.simulator import SimConfig, simulate
    from repro.serving.traces import TraceConfig, make_trace, mix

    smoke = OPTS["smoke"] if smoke is None else smoke
    model = "llama3.1-8b"
    source = "tpu-v5e"
    targets = ("tpu-v4", "gpu-a100-80g", "gpu-l4") if smoke else \
        tuple(sorted(n for n in PROFILES if n != source))
    chips = 4
    cfg = get_config(model)

    def setup_of(hw_name: str) -> ServingSetup:
        return ServingSetup(cfg=cfg, hw=profile(hw_name), chips=chips,
                            framework_eff=FRAMEWORKS["vllm-jax"])

    grid = list(itertools.product(
        (128, 512, 2048) if smoke else (128, 256, 512, 1024, 2048),
        (64, 256) if smoke else (64, 128, 256, 512),
        (1, 4, 16, 64) if smoke else (1, 2, 4, 8, 16, 32, 64, 128)))
    reps = 2 if smoke else 3
    sa_iters = 4 if smoke else 10

    rng = np.random.default_rng(0)
    src = Dataset.from_rows(_simulate(model, profile(source), grid, reps,
                                      rng, chips=chips))
    reg, us_fit = _timed(
        lambda: ModelRegistry().fit(src, n_estimators=25).fit_uncertainty(
            src, sa_cfg=SAConfig(n_iters=sa_iters, seed=0, n_chains=4,
                                 gbt_kw=dict(n_estimators=30,
                                             learning_rate=0.2,
                                             max_depth=4)),
            n_estimators=25))
    hw_i = reg._active_keys.index("acc")

    def scale_fn(combo, donor, ii, oo, bb):
        # analytic roofline transfer: the pure-descriptor throughput
        # ratio between target and donor hardware, per query point
        return (throughput_batch(setup_of(combo[hw_i]), ii, oo, bb)
                / throughput_batch(setup_of(donor[hw_i]), ii, oo, bb))

    report = {"smoke": bool(smoke), "source": source, "model": model,
              "targets": {}}
    src_med = float(np.median(np.abs(
        reg.predict(src) - src["thpt"]) / src["thpt"] * 100.0))
    report["source_median_ape"] = src_med
    # one shared same-workload query set for the confidence ordering:
    # identical (ii, oo, bb, thpt) rows relabeled per hardware
    q_idx = np.random.default_rng(1).choice(
        len(src), size=min(128, len(src)), replace=False)
    base_rows = [{k: src[k][i] for k in src.cols} for i in q_idx]
    _, _, conf_same = reg.estimate(Dataset.from_rows(base_rows))
    assert (conf_same > 0).all(), "source confidence degenerate"
    report["conf_same_median"] = float(np.median(conf_same))
    for tname in targets:
        tgt = Dataset.from_rows(_simulate(model, profile(tname), grid,
                                          reps, rng, chips=chips))
        pred, us_pred = _timed(reg.predict, tgt, transfer=True,
                               scale_fn=scale_fn)
        med = float(np.median(np.abs(pred - tgt["thpt"])
                              / tgt["thpt"] * 100.0))
        hw_cols = feature_row(tname)
        relab = Dataset.from_rows([{**r, "acc": tname, **hw_cols}
                                   for r in base_rows])
        _, _, conf_x = reg.estimate(relab, transfer=True)
        strict = bool((conf_x < conf_same).all())
        d_hw = hardware_distance(source, tname)
        report["targets"][tname] = {
            "transfer_median_ape": med,
            "hardware_distance": float(d_hw),
            "conf_cross_median": float(np.median(conf_x)),
            "strictly_lower_confidence": strict,
        }
        _emit(f"transfer_engine_{tname}", us_pred,
              f"medAPE={med:.2f}%;d_hw={d_hw:.2f};"
              f"conf_x={np.median(conf_x):.3f};strict={strict}")
        # CI gates: transfer must stay accurate (the analytic scaler
        # absorbs the roofline shift; residual is GBT fit error + noise)
        # and must never report >= the same-hardware confidence
        assert med < 20.0, f"{tname}: transfer medAPE {med:.1f}% >= 20%"
        assert strict, f"{tname}: cross-hardware confidence not < same"

    # --- (c) mixed TPU+GPU fleet: aware vs blind placement -----------------
    # Both arms run the SAME slot-cycled TPU+L4 SimConfig; the aware
    # controller overrides the slot hardware through Action.hardware
    # (transfer-derated predictions pick the TPU), the blind controller
    # emits hardware=None and inherits the mixed slot defaults.
    src_setup = setup_of(source)
    pool = (source, "gpu-l4")
    ala = next(iter(reg.combos.values())).ala
    hw_scale = {
        n: (lambda ii, oo, bb, n=n: float(
            throughput_batch(setup_of(n), [ii], [oo], [bb])[0]
            / throughput_batch(src_setup, [ii], [oo], [bb])[0]))
        for n in pool}
    horizon = 16.0 if smoke else 40.0
    shape = mix(("chat", 0.6), ("summarize", 0.2), ("generate", 0.2))
    cap_req_s = throughput(src_setup, 512, 192, 64) / 192
    tr = make_trace(TraceConfig(arrival="poisson", rate=2.0 * cap_req_s,
                                horizon_s=horizon, shape_mix=shape,
                                seed=29))
    sim_cfg = SimConfig(setup=src_setup, batch_cap=64, n_replicas=1,
                        max_replicas=6,
                        replica_setups=(src_setup, setup_of("gpu-l4")))

    def policy(kind: str) -> ALAAutoscaler:
        if kind == "blind":
            return ALAAutoscaler(ala=ala, max_replicas=6)
        return ALAAutoscaler(ala=ala, max_replicas=6, hardware_pool=pool,
                             fitted_hardware=source,
                             hardware_scale=hw_scale, placement="aware")

    fleet_out = {"pool": list(pool), "n_requests": len(tr), "arms": {}}
    for arm in ("aware", "blind"):
        per_engine = {}
        for engine in ("heap", "fleet"):
            res, us = _timed(simulate, tr, sim_cfg, policy(arm),
                             engine=engine)
            res.check_conservation()
            per_engine[engine] = {
                "slo_attainment": res.slo_attainment(ttft_slo_s),
                "goodput_tok_s": res.goodput_tok_s,
                "replica_seconds": res.replica_seconds,
                "n_shed": len(res.shed),
                "hardware": {h: sum(1 for v in res.replica_hw.values()
                                    if v == h)
                             for h in sorted(set(res.replica_hw.values()))},
                "wall_s": us / 1e6,
            }
        per_engine["parity_slo_diff"] = abs(
            per_engine["heap"]["slo_attainment"]
            - per_engine["fleet"]["slo_attainment"])
        fleet_out["arms"][arm] = per_engine
    aware = fleet_out["arms"]["aware"]["heap"]
    blind = fleet_out["arms"]["blind"]["heap"]
    fleet_out["aware_beats_blind"] = bool(
        aware["slo_attainment"] > blind["slo_attainment"]
        or (aware["slo_attainment"] >= blind["slo_attainment"]
            and aware["replica_seconds"] < blind["replica_seconds"]))
    report["fleet"] = fleet_out
    _emit("transfer_engine_fleet", us_fit,
          f"slo_aware={aware['slo_attainment']:.3f};"
          f"slo_blind={blind['slo_attainment']:.3f};"
          f"aware_wins={fleet_out['aware_beats_blind']}")
    # CI gates: placement must pay off, and the two engines must agree
    # on the heterogeneous scenario within the documented tolerance
    assert fleet_out["aware_beats_blind"], \
        "hardware-aware placement did not beat hardware-blind"
    for arm in ("aware", "blind"):
        d = fleet_out["arms"][arm]["parity_slo_diff"]
        assert d <= 0.1, f"{arm}: heap/fleet SLO parity diff {d:.3f} > 0.1"

    key = "transfer_engine_smoke" if smoke else "transfer_engine"
    REPORT[key] = report
    _write_bench(f"BENCH_transfer{'_smoke' if smoke else ''}.json", report,
                 seed=29)
    return report


def obs_engine(smoke=None, ttft_slo_s: float = 2.0):
    """Observability layer end-to-end, with hard gates.

    (1) Overhead: the 3-tenant fleet scenario runs untraced vs traced
    (``ObsConfig(sample_rate=1.0)``, spans derived post-run from the
    engine's own columns); full runs assert <5% throughput overhead.
    (2) Span parity: heap and fleet engines on the same seeded trace
    slice must emit equivalent span statistics (exact counts, TTFT/E2E
    percentiles within the bucket-quantization tolerance).
    (3) Mergeable histograms: per-tenant TTFT shards merge to the
    whole-stream quantile within one bin width, raw values never
    retained.  (4) Calibration: a miscalibrated-prior online loop
    (autoscaler ticks + ingest reports into one CalibrationAudit) must
    yield a monotone-binned confidence reliability curve.  Also writes
    a Perfetto-loadable Chrome trace of the multi-tenant run and
    results/BENCH_obs.json."""
    import dataclasses as _dc

    from repro.configs import get_config
    from repro.core.annealing import SAConfig
    from repro.core.dataset import Dataset
    from repro.core.online import OnlineALA, OnlineConfig
    from repro.obs import (CalibrationAudit, ObsConfig, StreamHist,
                           percentile_with_inf, write_chrome_trace,
                           write_jsonl)
    from repro.obs.tracing import queue_depth_series, span_hists, span_stats
    from repro.perfmodel.simulator import (ServingSetup, sample_throughput,
                                           throughput)
    from repro.perfmodel.hardware import TPU_V5E, feature_row
    from repro.serving.adapter import (TRACE_BACKEND, summarize_windows,
                                       windows_to_rows)
    from repro.serving.autoscaler import ALAAutoscaler
    from repro.serving.simulator import SimConfig, simulate
    from repro.serving.traces import (FleetTraceConfig, TenantConfig,
                                      TraceConfig, make_fleet_trace,
                                      make_trace, mix)

    smoke = OPTS["smoke"] if smoke is None else smoke
    suffix = "_smoke" if smoke else ""
    arch = "llama3.1-8b"
    setup = ServingSetup(cfg=get_config(arch), hw=TPU_V5E, chips=4)

    # -- (1) overhead gate on the multi-tenant fleet scenario ---------------
    horizon = 60.0 if smoke else 600.0
    fcfg = FleetTraceConfig(tenants=(
        TenantConfig(name="chat",
                     trace=TraceConfig(arrival="poisson", rate=30.0,
                                       shape_mix=mix(("chat", 1.0))),
                     ttft_slo_s=1.5, diurnal_amp=0.4),
        TenantConfig(name="summarize",
                     trace=TraceConfig(arrival="gamma", rate=8.0, cv=2.0,
                                       shape_mix=mix(("summarize", 1.0))),
                     ttft_slo_s=8.0),
        TenantConfig(name="generate",
                     trace=TraceConfig(arrival="mmpp", rate=12.0,
                                       burst_rate=24.0,
                                       shape_mix=mix(("generate", 1.0))),
                     ttft_slo_s=4.0, flash_crowds=2, flash_mult=3.0,
                     flash_dur_s=15.0),
    ), horizon_s=horizon, seed=42)
    tr = make_fleet_trace(fcfg)
    cfg = SimConfig(setup=setup, batch_cap=64, n_replicas=8,
                    max_replicas=8, bucket_s=0.5)
    cfg_obs = _dc.replace(cfg, obs=ObsConfig(sample_rate=1.0))
    simulate(tr, cfg, engine="fleet")               # warm-up
    base_us = min(_timed(simulate, tr, cfg, engine="fleet")[1]
                  for _ in range(3))
    res_obs, obs_us = _timed(simulate, tr, cfg_obs, engine="fleet")
    obs_us = min([obs_us] + [_timed(simulate, tr, cfg_obs,
                                    engine="fleet")[1] for _ in range(2)])
    overhead = obs_us / base_us - 1.0
    evps_base = res_obs.n_events / (base_us / 1e6)
    evps_obs = res_obs.n_events / (obs_us / 1e6)
    assert res_obs.spans is not None \
        and res_obs.spans.n == len(tr.requests), "span capture incomplete"
    # full runs gate at the ISSUE's 5%; smoke runs are sub-second on CI
    # boxes where timer noise alone exceeds that, so gate loosely there
    cap = 0.25 if smoke else 0.05
    assert overhead < cap, (
        f"tracing overhead {overhead * 100:.1f}% >= {cap * 100:.0f}% "
        f"at sample_rate=1.0")

    # -- (2) heap-vs-fleet span-statistic parity on a seeded slice ----------
    sl = tr.slice(0.0, 20.0 if smoke else 60.0)
    h = simulate(sl, cfg_obs, engine="heap")
    f = simulate(sl, cfg_obs, engine="fleet")
    sh, sf = span_stats(h.spans), span_stats(f.spans)
    assert sh["n_spans"] == sf["n_spans"], (sh["n_spans"], sf["n_spans"])
    assert sh["n_shed"] == sf["n_shed"], (sh["n_shed"], sf["n_shed"])
    assert sh["out_tokens"] == sf["out_tokens"]
    # fleet admissions are quantized to bucket boundaries: percentile
    # deltas are bounded by the bucket width plus the parity-test margin
    tol50 = cfg.bucket_s + 0.35
    tol95 = cfg.bucket_s + 1.0
    for k, tol in (("ttft_p50_s", tol50), ("ttft_p95_s", tol95),
                   ("e2e_p50_s", tol50), ("e2e_p95_s", tol95)):
        a, b = sh[k], sf[k]
        if np.isfinite(a) or np.isfinite(b):
            assert abs(a - b) <= tol, f"span parity {k}: {a} vs {b}"

    # -- (3) mergeable per-tenant histogram shards --------------------------
    shards = span_hists(res_obs.spans, n_bins=48,
                        by=res_obs.spans.tenant)
    merged = StreamHist.merged(shards.values())
    ttft_all = res_obs.spans.ttft_s()
    exact_p95 = percentile_with_inf(ttft_all, 95.0)
    hist_p95 = merged.quantile(95.0)
    fin = ttft_all[np.isfinite(ttft_all)]
    bin_w = ((fin.max() - fin.min()) / 46.0) if len(fin) else 0.0
    if np.isfinite(exact_p95):
        assert abs(hist_p95 - exact_p95) <= bin_w + 1e-9, (
            f"merged-shard p95 {hist_p95} vs exact {exact_p95} "
            f"(bin width {bin_w})")
    qd = queue_depth_series(res_obs.spans, bucket_s=cfg.bucket_s,
                            t_end=res_obs.sim_end_s)
    qd_hist = StreamHist.from_values(qd["depth"].astype(float), 32)

    # -- Perfetto-loadable trace of the multi-tenant run --------------------
    trace_path = RESULTS / f"obs_trace_fleet{suffix}.json"
    RESULTS.mkdir(parents=True, exist_ok=True)
    write_chrome_trace(res_obs, trace_path,
                       max_step_events=2000 if smoke else 20000,
                       max_span_events=500 if smoke else 5000)
    tj = json.loads(trace_path.read_text())
    assert tj["traceEvents"], "empty chrome trace"
    assert all("ph" in e and "pid" in e for e in tj["traceEvents"])

    # -- (4) calibration audit: miscalibrated prior, online loop ------------
    n_epochs = 3 if smoke else 6
    epoch_s = 10.0 if smoke else 20.0
    REF_II, REF_OO = 512, 192
    cap_req_s = throughput(setup, REF_II, REF_OO, 64) / REF_OO
    cal_tr = make_trace(TraceConfig(
        arrival="mmpp", rate=1.2 * cap_req_s, burst_rate=2.5 * cap_req_s,
        horizon_s=n_epochs * epoch_s,
        shape_mix=mix(("chat", 0.7), ("generate", 0.3)), seed=43))
    grid = [(ii, oo, bb)
            for ii in ((128, 512, 2048) if smoke else
                       (128, 256, 512, 1024, 2048))
            for oo in ((64, 256) if smoke else (64, 128, 256))
            for bb in (1, 4, 16, 64)]
    sa = SAConfig(n_iters=4 if smoke else 12, n_chains=2, seed=0,
                  gbt_kw=dict(n_estimators=20, learning_rate=0.2,
                              max_depth=3))
    gbt_kw = dict(n_estimators=20, learning_rate=0.15)
    rng = np.random.default_rng(0)
    # deliberately derated prior: early ticks are wrong (high APE) at
    # whatever confidence Alg 8 reports; mid-run recalibration from the
    # trace telemetry restores accuracy — exactly the spread a
    # reliability curve needs
    PRIOR_DERATE = 0.6
    seed_rows = [dict(model=arch, acc=TPU_V5E.name, acc_count=4,
                      back=TRACE_BACKEND, prec="bf16", mode="serve",
                      ii=ii, oo=oo, bb=bb, thpt=PRIOR_DERATE * float(t),
                      **feature_row(TPU_V5E))
                 for ii, oo, bb in grid
                 for t in sample_throughput(setup, ii, oo, bb, 1, rng)]
    obs_cal = ObsConfig()
    audit = CalibrationAudit(cfg=obs_cal)
    eng = OnlineALA(OnlineConfig(sa=sa, warm_iters=3 if smoke else 5,
                                 gbt_kw=dict(sa.gbt_kw)), audit=audit)
    eng.ingest(Dataset.from_rows(seed_rows), **gbt_kw)
    combo = eng.combo_of(seed_rows[0])
    scaler = ALAAutoscaler(ala=eng.ala_for(combo), online=eng,
                           combo=combo, max_replicas=4, audit=audit,
                           drift_window=4, drift_ape_threshold=25.0)
    for e in range(n_epochs):
        etr = cal_tr.slice(e * epoch_s, (e + 1) * epoch_s)
        if not len(etr):
            continue
        res = simulate(etr, SimConfig(
            setup=setup, batch_cap=64, n_replicas=2, max_replicas=4,
            t_start=e * epoch_s, control_interval_s=1.0), scaler)
        rows = windows_to_rows(
            summarize_windows(res, window_s=epoch_s / 8.0), setup, arch)
        if rows:
            eng.ingest(Dataset.from_rows(rows), **gbt_kw)
    cal = audit.summary()
    curve = cal["reliability"]
    n_ticks = cal["n_ticks"]
    assert n_ticks >= 5, f"calibration audit starved: {n_ticks} ticks"
    assert audit.counts.get("refit", 0) >= 1, "no ingest reports audited"
    acc = curve["bin_acc"]
    assert len(acc) >= 1 and all(
        acc[i] <= acc[i + 1] + 1e-12 for i in range(len(acc) - 1)), (
        f"reliability curve not monotone-binned: {curve}")
    events_path = RESULTS / f"obs_events{suffix}.jsonl"
    n_ev = write_jsonl(audit.events, events_path)

    key = f"obs_engine{suffix}" if smoke else "obs_engine"
    report = {
        "smoke": bool(smoke),
        "n_requests": len(tr),
        "n_events": res_obs.n_events,
        "overhead_frac": overhead,
        "overhead_cap": cap,
        "events_per_sec_untraced": evps_base,
        "events_per_sec_traced": evps_obs,
        "span_parity": {"heap": sh, "fleet": sf,
                        "tol_p50_s": tol50, "tol_p95_s": tol95},
        "hist_merge": {"exact_p95_s": exact_p95,
                       "merged_p95_s": hist_p95, "bin_width_s": bin_w,
                       "n_shards": len(shards)},
        "queue_depth": {"p50": qd_hist.quantile(50.0),
                        "p95": qd_hist.quantile(95.0),
                        "max": float(qd["depth"].max())
                        if len(qd["depth"]) else 0.0},
        "chrome_trace": {"file": trace_path.name,
                         "n_events": len(tj["traceEvents"])},
        "calibration": cal,
        "audit_events_file": events_path.name,
        "audit_events_written": n_ev,
        "meta": {k: v for k, v in
                 res_obs.meta_metrics(fcfg.slo_map).items()
                 if k != "per_tenant"},
        "per_tenant": res_obs.per_tenant(fcfg.slo_map),
    }
    REPORT[key] = report
    _write_bench(f"BENCH_obs{suffix}.json", report, seed=42)
    _emit(key, obs_us,
          f"overhead={overhead * 100:.1f}%;ticks={n_ticks};"
          f"rel_bins={len(acc)};trace_evs={len(tj['traceEvents'])}")
    return report


def wallclock_engine(arch: str = "qwen3-0.6b"):
    """Real JAX-engine sweep through bench.harness — the CLI grid/reps
    overrides and the module defaults share one code path."""
    from repro.bench.harness import measure_arch
    grids = (OPTS["grid_ii"], OPTS["grid_oo"], OPTS["grid_bb"])
    if OPTS["smoke"] and all(g is None for g in grids):
        grids = ((16,), (8,), (1, 2))
    # None falls through to measure_arch's own default (reps=2)
    reps = OPTS["reps"] if OPTS["reps"] is not None else 2
    ds, us = _timed(measure_arch, arch, *grids, reps=reps)
    med = float(np.median(ds["thpt"]))
    REPORT["wallclock_engine"] = {
        "arch": arch, "rows": len(ds), "reps": reps,
        "grids": [list(g) if g else None for g in grids],
        "median_tok_s": med}
    _emit("wallclock_engine", us, f"rows={len(ds)};median_tok_s={med:.1f}")


BENCHMARKS = {}


def main() -> None:
    def _csv_ints(s):
        return tuple(int(v) for v in s.split(",") if v)

    p = argparse.ArgumentParser(description=__doc__,
                                formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("names", nargs="*",
                   help="benchmarks to run (default: all)")
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized runs (fewer archs, short horizons)")
    p.add_argument("--grid-ii", type=_csv_ints, default=None,
                   metavar="I1,I2,...")
    p.add_argument("--grid-oo", type=_csv_ints, default=None,
                   metavar="O1,O2,...")
    p.add_argument("--grid-bb", type=_csv_ints, default=None,
                   metavar="B1,B2,...")
    p.add_argument("--reps", type=int, default=None)
    args = p.parse_args()
    OPTS.update(smoke=args.smoke, grid_ii=args.grid_ii,
                grid_oo=args.grid_oo, grid_bb=args.grid_bb, reps=args.reps)
    names = args.names
    for n in names:
        if n not in BENCHMARKS:
            print(f"unknown benchmark {n!r}; available: "
                  f"{', '.join(BENCHMARKS)}")
            raise SystemExit(2)
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in BENCHMARKS.items():
        if names and name not in names:
            continue
        fn()
    RESULTS.mkdir(parents=True, exist_ok=True)
    report_path = RESULTS / "bench_report.json"
    report = REPORT
    if names and report_path.exists():
        # partial run: merge into the aggregate instead of clobbering it
        try:
            report = {**json.loads(report_path.read_text()), **REPORT}
        except json.JSONDecodeError:
            pass
    report_path.write_text(json.dumps(report, indent=1))
    print(f"# total {time.time() - t0:.1f}s; report -> {report_path}")


BENCHMARKS.update({
    "fig2_exponential_fits": fig2_exponential_fits,
    "fig3_param_prediction": fig3_param_prediction,
    "fig6_rq1_training_sets": fig6_rq1_training_sets,
    "fig7_rq2_baselines": fig7_rq2_baselines,
    "fig8_rq3_model_zoo": fig8_rq3_model_zoo,
    "table1_rq4_uncertainty": table1_rq4_uncertainty,
    "perf_vmapped_fit": perf_vmapped_fit,
    "perf_kernels": perf_kernels,
    "sa_engine": sa_engine,
    "uncertainty_engine": uncertainty_engine,
    "serving_engine": serving_engine,
    "fleet_engine": fleet_engine,
    "online_engine": online_engine,
    "fault_engine": fault_engine,
    "transfer_engine": transfer_engine,
    "obs_engine": obs_engine,
    "wallclock_engine": wallclock_engine,
})


if __name__ == "__main__":
    main()
