"""Perf simulator + dataset + registry behaviour tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.dataset import Dataset
from repro.core.registry import ModelRegistry
from repro.perfmodel.simulator import (ServingSetup, decode_step_time,
                                       decode_step_time_group,
                                       decode_time_fn, prefill_step_time,
                                       prefill_time, prefill_time_fn,
                                       sample_throughput, throughput,
                                       weights_read_bytes)
from repro.perfmodel.hardware import LEGACY_GPU, PROFILES, TPU_V5E


@pytest.fixture(scope="module")
def llama_setup():
    return ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4)


def test_throughput_saturates_with_batch(llama_setup):
    """thpt(bb) must be increasing and concave-ish toward an asymptote —
    the paper's core empirical observation (Fig 2)."""
    bbs = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]
    th = [throughput(llama_setup, 1024, 512, b) for b in bbs]
    assert all(b > a for a, b in zip(th, th[1:])), th
    # marginal gains shrink: last doubling gains less than first
    first_gain = th[1] / th[0]
    last_gain = th[-1] / th[-2]
    assert last_gain < first_gain
    # saturation: gain from final doubling under 35%
    assert last_gain < 1.35


def test_throughput_decreases_with_context(llama_setup):
    assert throughput(llama_setup, 512, 256, 32) > \
        throughput(llama_setup, 8192, 256, 32)


def test_moe_reads_fewer_weights_at_small_batch():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    w1 = weights_read_bytes(cfg, bb=1)
    w256 = weights_read_bytes(cfg, bb=256)
    wtot = cfg.param_count() * 2
    assert w1 < w256 <= wtot * 1.001
    # at bb=1 only top_k experts of 16 are hit per moe layer
    assert w1 < 0.5 * wtot


def test_ssm_decode_flat_in_context():
    cfg = get_config("xlstm-125m")
    s = ServingSetup(cfg=cfg, hw=TPU_V5E, chips=4)
    t1 = decode_step_time(s, bb=8, context=1024)
    t2 = decode_step_time(s, bb=8, context=524_288)
    assert t2 < t1 * 1.05, "attention-free decode must not scale w/ context"


def test_hardware_profiles_differ():
    cfg = get_config("qwen3-0.6b")
    a = throughput(ServingSetup(cfg=cfg, hw=TPU_V5E, chips=4), 512, 512, 32)
    b = throughput(ServingSetup(cfg=cfg, hw=LEGACY_GPU, chips=4),
                   512, 512, 32)
    assert abs(a - b) / a > 0.1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_sampling_noise_is_unbiased_multiplicative(seed, llama_setup):
    rng = np.random.default_rng(seed)
    base = throughput(llama_setup, 512, 256, 16)
    samples = sample_throughput(llama_setup, 512, 256, 16, reps=200,
                                rng=rng, straggler_p=0.0)
    assert abs(np.median(samples) / base - 1) < 0.05
    assert samples.std() / base < 0.15


def test_prefill_time_scales_superlinearly_in_ii(llama_setup):
    t1 = prefill_time(llama_setup, 1024, 8)
    t2 = prefill_time(llama_setup, 16384, 8)
    assert t2 > 12 * t1   # quadratic attention term kicks in


@pytest.mark.parametrize("hw_name", sorted(PROFILES))
@pytest.mark.parametrize("model", ["llama3.1-8b", "phi3.5-moe-42b-a6.6b"])
def test_closures_match_scalar_reference(hw_name, model):
    """The vectorized serving closures must agree with the scalar
    roofline references on *every* registered profile — the cost model
    is pure in the descriptor, so no accelerator gets special-cased
    math (dense and MoE weight-read branches both covered)."""
    setup = ServingSetup(cfg=get_config(model), hw=PROFILES[hw_name],
                         chips=4)
    dec = decode_time_fn(setup)
    pre = prefill_time_fn(setup)
    batches = ([], [128], [512] * 8, [128, 512, 2048, 100],
               [4096] * 64)
    for ctxs in batches:
        arr = np.asarray(ctxs, np.float64)
        ref_d = decode_step_time_group(setup, arr)
        got_d = float(dec(len(arr), float(arr.sum())))
        assert got_d == pytest.approx(ref_d, rel=1e-9, abs=1e-15), \
            (hw_name, model, "decode", ctxs)
        ref_p = prefill_step_time(setup, arr)
        got_p = float(pre(float(arr.sum()), float((arr * arr).sum())))
        assert got_p == pytest.approx(ref_p, rel=1e-9, abs=1e-15), \
            (hw_name, model, "prefill", ctxs)


# ------------------------------------------------------------------ dataset
def test_dataset_roundtrip(tmp_path):
    ds = Dataset({"model": np.array(["a", "b"]), "ii": np.array([1, 2]),
                  "oo": np.array([3, 4]), "bb": np.array([5, 6]),
                  "thpt": np.array([1.0, 2.0])})
    ds.save(tmp_path / "d")
    ds2 = Dataset.load(tmp_path / "d")
    assert len(ds2) == 2
    np.testing.assert_array_equal(ds2["ii"], ds["ii"])
    sub = ds2.filter(model="a")
    assert len(sub) == 1 and sub["thpt"][0] == 1.0


def test_dataset_unique_combos():
    ds = Dataset({"model": np.array(["a", "a", "b"]),
                  "acc": np.array(["x", "x", "y"]),
                  "ii": np.arange(3), "oo": np.arange(3),
                  "bb": np.arange(3), "thpt": np.ones(3)})
    combos = ds.unique_combos(["model", "acc"])
    assert sorted(combos) == [("a", "x"), ("b", "y")]


# ------------------------------------------------------------------ registry
def test_registry_separates_combos():
    from repro.core.expmodel import exp_model
    rows = []
    bbs = np.array([1, 2, 4, 8, 16, 32, 64], float)
    for model, c in (("m1", 1000.0), ("m2", 4000.0)):
        for ii in (128.0, 512.0):
            for oo in (128.0, 256.0):
                for bb, t in zip(bbs, exp_model(bbs, 0.9 * c, 0.08, c)):
                    rows.append(dict(model=model, acc="hw", acc_count=1,
                                     back="f", prec="bf16", mode="serve",
                                     ii=ii, oo=oo, bb=bb, thpt=t))
    ds = Dataset.from_rows(rows)
    reg = ModelRegistry().fit(ds, n_estimators=20)
    assert len(reg.combos) == 2
    pred = reg.predict(ds)
    ape = np.abs(pred - ds["thpt"]) / ds["thpt"]
    assert np.median(ape) < 0.05
    # the two combos saturate at very different levels
    m1 = pred[ds["model"] == "m1"].max()
    m2 = pred[ds["model"] == "m2"].max()
    assert m2 > 2 * m1
