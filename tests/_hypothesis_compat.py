"""Optional-``hypothesis`` shim for the property tests.

``hypothesis`` is a dev-only dependency (see requirements-dev.txt).
When it is installed, this module re-exports the real ``given`` /
``settings`` / ``st``.  When it is missing, the decorators degrade to
no-ops whose test bodies call ``pytest.importorskip("hypothesis")`` —
so property tests skip with a clear reason instead of failing the whole
module at collection, and every non-property test still runs.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy call
        returns None; the values are never used because the decorated
        test skips before its body runs."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            def _skipped_property_test():
                pytest.importorskip("hypothesis")

            _skipped_property_test.__name__ = fn.__name__
            _skipped_property_test.__doc__ = fn.__doc__
            return _skipped_property_test
        return deco
