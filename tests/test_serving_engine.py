"""Serving subsystem: traces, fleet simulator, autoscaler, adapter."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.ala import ALA
from repro.core.dataset import Dataset
from repro.core.registry import ModelRegistry
from repro.perfmodel.simulator import (ServingSetup, decode_step_time,
                                       decode_step_time_group,
                                       kv_capacity_tokens, prefill_step_time,
                                       prefill_time, sample_throughput)
from repro.perfmodel.hardware import TPU_V5E, feature_names
from _sim_invariants import assert_sim_invariants
from repro.serving.adapter import summarize_windows, windows_to_dataset
from repro.serving.autoscaler import ALAAutoscaler, StaticPolicy
from repro.serving.simulator import (Action, Observation, SimConfig,
                                     simulate)
from repro.serving.traces import (Trace, TraceConfig, gamma_arrivals,
                                  make_trace, mix, mmpp_arrivals,
                                  poisson_arrivals)


@pytest.fixture(scope="module")
def setup():
    return ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4)


@pytest.fixture(scope="module")
def chat_trace():
    return make_trace(TraceConfig(arrival="poisson", rate=6.0,
                                  horizon_s=20.0, seed=3))


# ------------------------------------------------------------------- traces
def test_trace_deterministic_and_pinned():
    cfg = TraceConfig(arrival="poisson", rate=4.0, horizon_s=30.0, seed=123)
    a, b = make_trace(cfg), make_trace(cfg)
    np.testing.assert_array_equal(a.arrivals, b.arrivals)
    np.testing.assert_array_equal(a.to_arrays()["ii"], b.to_arrays()["ii"])
    np.testing.assert_array_equal(a.to_arrays()["oo"], b.to_arrays()["oo"])
    # pin exact values: replayability must survive refactors
    np.testing.assert_allclose(a.arrivals[:3],
                               [0.14924312, 0.17850089, 0.24144956],
                               atol=1e-6)
    assert (a.requests[0].ii, a.requests[0].oo) == (209, 94)


def test_arrival_processes_hit_their_rates():
    rng = np.random.default_rng(0)
    for gen, kw in ((poisson_arrivals, {}), (gamma_arrivals, {"cv": 2.0})):
        t = gen(10.0, 200.0, rng, **kw)
        assert abs(len(t) / 200.0 - 10.0) < 1.5
        assert np.all(np.diff(t) >= 0) and t[-1] < 200.0
    t = mmpp_arrivals(2.0, 20.0, 400.0, rng)
    assert 2.0 * 400 < len(t) < 20.0 * 400
    assert np.all(np.diff(t) >= 0)


def test_mmpp_burstier_than_poisson():
    rng1, rng2 = np.random.default_rng(1), np.random.default_rng(1)
    po = poisson_arrivals(8.0, 300.0, rng1)
    mm = mmpp_arrivals(2.0, 32.0, 300.0, rng2)
    # dispersion of per-second counts: MMPP must exceed Poisson's ~1
    def dispersion(t):
        c = np.bincount(t.astype(int), minlength=300)[:300]
        return c.var() / max(c.mean(), 1e-9)
    assert dispersion(mm) > 2.0 * dispersion(po)


def test_shape_mix_and_roundtrip():
    tr = make_trace(TraceConfig(
        rate=20.0, horizon_s=20.0, seed=5,
        shape_mix=mix(("summarize", 0.5), ("generate", 0.5))))
    arrs = tr.to_arrays()
    assert len(arrs["ii"]) == len(tr) > 100
    tr2 = Trace.from_arrays(**arrs, horizon_s=tr.horizon_s)
    np.testing.assert_array_equal(tr2.arrivals, tr.arrivals)
    # summarize: long prompts; generate: long outputs — both present
    assert arrs["ii"].max() > 1500 and arrs["oo"].max() > 400
    with pytest.raises(KeyError):
        make_trace(TraceConfig(arrival="nope"))


# ---------------------------------------------------------------- simulator
def test_simulator_completes_and_orders_metrics(setup, chat_trace):
    res = simulate(chat_trace, SimConfig(setup=setup, n_replicas=2))
    assert_sim_invariants(res, chat_trace)
    assert len(res.records) == len(chat_trace)
    assert len(res.completed) == len(chat_trace)
    for r in res.completed:
        assert r.arrival_s < r.first_token_s <= r.done_s
        assert np.isfinite(r.tpot_s) and r.tpot_s >= 0.0
    assert res.goodput_tok_s > 0 and res.n_events > len(chat_trace)
    # replica integral covers the active span of both replicas
    assert res.replica_seconds >= 2 * 0.9 * res.sim_end_s


def test_simulator_deterministic(setup, chat_trace):
    cfg = SimConfig(setup=setup, n_replicas=1)
    a, b = simulate(chat_trace, cfg), simulate(chat_trace, cfg)
    assert [r.done_s for r in a.records] == [r.done_s for r in b.records]
    assert a.n_events == b.n_events


def test_more_replicas_cut_ttft(setup, chat_trace):
    cfg1 = SimConfig(setup=setup, n_replicas=1)
    cfg3 = SimConfig(setup=setup, n_replicas=3)
    r1, r3 = simulate(chat_trace, cfg1), simulate(chat_trace, cfg3)
    assert r3.ttft_percentile(95) <= r1.ttft_percentile(95)
    assert r3.slo_attainment(1.0) >= r1.slo_attainment(1.0)


def test_kv_capacity_limits_concurrency(setup):
    # tiny KV budget: only a few requests' worth of tokens fit at once
    tr = make_trace(TraceConfig(arrival="poisson", rate=15.0,
                                horizon_s=15.0, seed=3))
    need = max(r.ii + r.oo for r in tr.requests)
    tight = SimConfig(setup=setup, n_replicas=1, drain_s=5000.0,
                      kv_capacity_override=2.0 * need)
    free = SimConfig(setup=setup, n_replicas=1, drain_s=5000.0)
    rt, rf = simulate(tr, tight), simulate(tr, free)
    assert max(s.bb for s in rt.steps) < 0.5 * max(s.bb for s in rf.steps)
    assert len(rt.completed) == len(tr)            # still drains fully


def test_oversized_request_rejected_not_blocking(setup):
    """A request that can never fit KV must not head-of-line block."""
    tr = make_trace(TraceConfig(arrival="poisson", rate=4.0,
                                horizon_s=10.0, seed=9))
    arrs = tr.to_arrays()
    arrs["ii"][3] = 10_000            # ii+oo far beyond the tiny budget
    big = Trace.from_arrays(**arrs, horizon_s=tr.horizon_s)
    cap = max(r.ii + r.oo for r in big.requests
              if r.ii < 10_000) + 500.0
    cfg = SimConfig(setup=setup, n_replicas=1, drain_s=5000.0,
                    kv_capacity_override=cap)
    res = simulate(big, cfg)
    rejected = [r for r in res.records if r.ii >= 10_000]
    assert len(rejected) == 1 and not rejected[0].completed
    assert rejected[0].ttft_s == np.inf            # counted as SLO miss
    assert len(res.completed) == len(big) - 1      # everyone else served


def test_kv_capacity_tokens_profiles(setup):
    cap = kv_capacity_tokens(setup)
    assert 1e4 < cap < 1e7
    ssm = ServingSetup(cfg=get_config("xlstm-125m"), hw=TPU_V5E, chips=4)
    assert kv_capacity_tokens(ssm) == np.inf


def test_group_step_times_reduce_to_classic(setup):
    np.testing.assert_allclose(
        prefill_step_time(setup, np.full(8, 512.0)),
        prefill_time(setup, 512, 8))
    np.testing.assert_allclose(
        decode_step_time_group(setup, np.full(16, 900.0)),
        decode_step_time(setup, 16, 900.0))
    # heterogeneity matters: one long prompt costs more than its mean
    assert prefill_step_time(setup, [128.0, 8192.0]) > \
        prefill_step_time(setup, [4160.0, 4160.0])


# --------------------------------------------------------------- autoscaler
def _fit_ala(setup, sa_iters=4):
    import itertools
    from repro.core.annealing import SAConfig
    rng = np.random.default_rng(0)
    rows = [(ii, oo, bb, t)
            for ii, oo, bb in itertools.product(
                (128, 512, 2048), (64, 256), (1, 4, 16, 64))
            for t in sample_throughput(setup, ii, oo, bb, 2, rng)]
    gi, go, gb, gt = map(np.asarray, zip(*rows))
    te = rng.random(len(gi)) < 0.3
    ala = ALA()
    ala.cfg.sa = SAConfig(n_iters=sa_iters, seed=0, n_chains=2,
                          gbt_kw=dict(n_estimators=20, learning_rate=0.2,
                                      max_depth=3))
    ala.fit(gi[~te], go[~te], gb[~te], gt[~te])
    ala.explore((gi[te], go[te], gb[te], gt[te]))
    ala.fit_error()
    return ala


def test_ala_autoscaler_beats_static_on_burst(setup):
    ala = _fit_ala(setup)
    tr = make_trace(TraceConfig(arrival="mmpp", rate=4.0, burst_rate=24.0,
                                horizon_s=25.0, seed=7))
    cfg = SimConfig(setup=setup, n_replicas=1, max_replicas=6)
    rs = simulate(tr, cfg, StaticPolicy(n_replicas=1, batch_cap=64))
    pol = ALAAutoscaler(ala=ala, max_replicas=6)
    ra = simulate(tr, cfg, pol)
    assert ra.slo_attainment(2.0) >= rs.slo_attainment(2.0)
    assert max(a.n_replicas for _, a in ra.controls) > 1   # it did scale
    assert pol.log and all(0.0 <= c <= 1.0 for c, _, _ in pol.log)


def test_autoscaler_degenerate_confidence_falls_back(setup):
    ala = _fit_ala(setup)
    pol = ALAAutoscaler(ala=ala)
    pol._predict_per_replica = lambda ii, oo: (64, 5000.0, 0.0)
    obs = Observation(now=2.0, window_s=2.0, n_arrivals=10, mean_ii=256.0,
                      mean_oo=128.0, arrival_rate=5.0, queue_len=0,
                      n_running=4, n_active_replicas=1, batch_cap=64,
                      decode_tokens=2000, busy_s=2.0,
                      measured_tok_s=1000.0)
    act = pol.control(obs)
    # supply = measured 1000 tok/s, demand = 640 tok/s / 0.75 -> 1 replica
    assert act.n_replicas == 1
    assert pol.log[-1][2] is True          # fallback taken
    # idle window: hold steady, no divide-by-zero on empty stats
    idle = Observation(now=4.0, window_s=2.0, n_arrivals=0, mean_ii=0.0,
                       mean_oo=0.0, arrival_rate=0.0, queue_len=0,
                       n_running=0, n_active_replicas=3, batch_cap=32,
                       decode_tokens=0, busy_s=0.0, measured_tok_s=0.0)
    assert pol.control(idle) == Action(n_replicas=3, batch_cap=32)


# ------------------------------------------------------------------ adapter
def test_adapter_windows_and_dataset(setup, chat_trace):
    res = simulate(chat_trace, SimConfig(setup=setup, n_replicas=1))
    wins = summarize_windows(res, window_s=2.5)
    assert wins and all(w.thpt > 0 and w.bb >= 1 for w in wins)
    assert all(w.ii & (w.ii - 1) == 0 for w in wins)   # pow2 buckets
    ds = windows_to_dataset(res, setup, "llama3.1-8b", window_s=2.5)
    assert set(ds.cols) == {"model", "acc", "acc_count", "back", "prec",
                            "mode", "ii", "oo", "bb", "thpt",
                            *feature_names()}
    assert (ds["acc"] == "tpu-v5e").all() and (ds["acc_count"] == 4).all()


def test_adapter_roundtrip_registry_fit(setup, chat_trace):
    """Trace-derived rows feed the same Alg 4 fit path as static grids."""
    res = simulate(chat_trace, SimConfig(setup=setup, n_replicas=1))
    ds = windows_to_dataset(res, setup, "llama3.1-8b", window_s=2.5)
    ds2 = Dataset.from_rows([
        {k: ds[k][i] for k in ds.cols} for i in range(len(ds))])
    np.testing.assert_array_equal(ds2["thpt"], ds["thpt"])
    reg = ModelRegistry().fit(ds2, n_estimators=15)
    assert len(reg.combos) == 1
    pred = reg.predict(ds2)
    assert np.isfinite(pred).all() and (pred > 0).all()


def test_adapter_raises_on_no_steady_state(setup):
    tr = make_trace(TraceConfig(rate=0.05, horizon_s=2.0, seed=1))
    res = simulate(tr, SimConfig(setup=setup))
    with pytest.raises(ValueError):
        windows_to_dataset(res, setup, "llama3.1-8b", window_s=0.01,
                           min_completions=50)
    with pytest.raises(ValueError):
        Dataset.from_rows([])
