"""Multi-device distribution tests (subprocess with fake host devices —
XLA locks the device count at first init, so these can't run in-process)."""
import json
import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def _run(script: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_dev}")
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


EP_MOE_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.distributed.sharding import ShardingPolicy, use_policy
from repro.distributed.ep_moe import ep_available, moe_ffn_ep
from repro.models import moe as moe_mod

# generous capacity so no tokens drop -> EP and GSPMD paths must agree
cfg = get_smoke_config("phi3.5-moe-42b-a6.6b").scaled(capacity_factor=8.0)
mesh = jax.make_mesh((2, 4), ("data", "model"))
policy = ShardingPolicy(mesh, data_axes=("data",), model_axes=("model",))
assert ep_available(cfg, policy)

key = jax.random.key(0)
params = moe_mod.init_moe(cfg, key)
x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model), jnp.float32)
x = x.astype(cfg.compute_dtype)

# reference: single-device GSPMD-free path (policy None)
ref, aux_ref = jax.jit(lambda p, x: moe_mod.moe_ffn(cfg, p, x))(params, x)

def ep(p, xx):
    return moe_ffn_ep(cfg, p, xx, policy)

with use_policy(policy):
    out, aux = jax.jit(ep)(params, x)

err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
aerr = abs(float(aux) - float(aux_ref))
print("MAXERR", err, "AUXERR", aerr)
assert err < 3e-2, err
assert aerr < 1e-3, (float(aux), float(aux_ref))
print("EP_MOE_OK")
"""


def test_ep_moe_matches_reference():
    out = _run(EP_MOE_SCRIPT)
    assert "EP_MOE_OK" in out, out


CP_COMPILE_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import ShardingPolicy, use_policy
from repro.launch.steps import build_step
from repro.models.transformer import Model

# 6 heads on a 4-wide model axis -> not divisible -> CP fallback engages
cfg = get_smoke_config("llama3.2-3b").scaled(
    n_heads=6, n_kv_heads=2, param_dtype=jnp.bfloat16)
mesh = jax.make_mesh((2, 4), ("data", "model"))
policy = ShardingPolicy(mesh, data_axes=("data",), serving=True,
                        serving_2d=False, cp_replicate_weights=True)
shape = ShapeSpec("p", seq_len=64, global_batch=4, kind="prefill")
model = Model(cfg)
step, in_sh, out_sh, args = build_step(model, policy, shape)
with use_policy(policy):
    compiled = jax.jit(step, in_shardings=in_sh,
                       out_shardings=out_sh).lower(*args).compile()
from repro.distributed.compat import cost_analysis_dict
print("CP_COMPILE_OK", cost_analysis_dict(compiled).get("flops"))
"""


def test_cp_policy_compiles_nondivisible_heads():
    out = _run(CP_COMPILE_SCRIPT)
    assert "CP_COMPILE_OK" in out, out


SERVE_STEP_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import ShardingPolicy, use_policy
from repro.launch.steps import build_serve_step
from repro.models.transformer import Model

cfg = get_smoke_config("qwen2.5-32b").scaled(param_dtype=jnp.bfloat16)
mesh = jax.make_mesh((2, 4), ("data", "model"))
policy = ShardingPolicy(mesh, data_axes=("data",), serving=True,
                        serving_2d=False)
shape = ShapeSpec("d", seq_len=64, global_batch=8, kind="decode")
model = Model(cfg)
step, in_sh, out_sh, args = build_serve_step(model, policy, shape)

# run it for real on the fake mesh: sharded decode must equal local decode
params = model.init(jax.random.key(0))
cache = model.init_cache(8, 64, filled=63)
toks = jnp.zeros((8, 1), jnp.int32)
local_logits, _ = jax.jit(model.decode_step)(params, cache, toks)
with use_policy(policy):
    sharded = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
    sh_logits, _ = sharded(params, cache, toks)
err = float(jnp.max(jnp.abs(local_logits.astype(jnp.float32)
                            - sh_logits.astype(jnp.float32))))
print("MAXERR", err)
assert err < 5e-2, err
print("SERVE_SHARDED_OK")
"""


def test_sharded_serve_step_matches_local():
    out = _run(SERVE_STEP_SCRIPT)
    assert "SERVE_SHARDED_OK" in out, out
