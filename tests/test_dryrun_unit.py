"""Dry-run machinery unit tests: collective parser, shape-byte accounting,
sharding rule resolution (no device state required)."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.dryrun import _shape_bytes, collective_stats


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(f32[4], s32[2])") == 24
    assert _shape_bytes("pred[]") == 1   # scalar: product of no dims = 1


def test_collective_stats_counts_real_ops_only():
    hlo = "\n".join([
        "%ag = f32[16,4] all-gather(%x), replica_groups=...",
        "%fusion = f32[999,999] fusion(%ag, %y), calls=%fused",  # consumer!
        "%ar = (f32[8], f32[8]) all-reduce-start(%z)",
        "%ard = f32[8] all-reduce-done(%ar)",
        "%rs = bf16[32] reduce-scatter(%w)",
    ])
    stats = collective_stats(hlo)
    assert stats["all-gather"] == {"count": 1, "bytes": 256}
    assert stats["all-reduce"] == {"count": 1, "bytes": 32}
    assert stats["reduce-scatter"] == {"count": 1, "bytes": 64}
    # the fusion consuming %ag must not be counted
    total = sum(v["bytes"] for v in stats.values())
    assert total == 256 + 32 + 64


def test_sharding_rules_divisibility_fallbacks():
    import jax
    from repro.distributed.sharding import ShardingPolicy
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    # fake a 16-wide model axis via a policy with known divisibility:
    # use the real resolver on shapes and assert the fallback chain.
    policy = ShardingPolicy(mesh)
    # with axis size 1 everything divides; spec picks the first prefs
    spec = policy.resolve("kv_cache", (8, 1024, 4, 128))
    assert spec == P("data", None, "model", None)


def test_sharding_rules_nondivisible_heads_fall_to_seq():
    import jax
    # 4-wide model axis: kv=2 heads don't divide -> seq dim takes model
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    from repro.distributed.sharding import ShardingPolicy
    policy = ShardingPolicy(mesh)
    spec = policy.resolve("kv_cache", (8, 1024, 2, 128))
    assert spec == P("data", "model", None, None)
    # batch=1: batch unshardable; seq takes the model axis (pref order)
    spec2 = policy.resolve("kv_cache", (1, 1024, 2, 128))
    assert spec2 == P(None, "model", None, None)


def test_param_spec_zero1_adds_data_axis():
    import jax
    from repro.distributed.sharding import ShardingPolicy, param_spec
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    policy = ShardingPolicy(mesh)
    base = param_spec("blocks/0/mlp/w_gate", (12, 64, 128), policy,
                      stacked=True)
    assert base == P(None, None, "model")
    opt = param_spec("blocks/0/mlp/w_gate", (12, 64, 128), policy,
                     stacked=True, for_opt_state=True)
    assert opt == P("data", None, "model")
