"""Chunked (online-softmax) attention must match dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _group, _sdpa, _sdpa_chunked


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("sq,sk,chunk", [(64, 64, 16), (32, 128, 32)])
def test_chunked_matches_dense(causal, sq, sk, chunk):
    b, kv, g, dh = 2, 2, 3, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, kv * g, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, sk, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, sk, kv, dh), jnp.float32)
    qg = _group(q, kv)
    if causal and sq != sk:
        pytest.skip("causal mask defined for square in dense ref")
    mask = None
    if causal:
        idx = jnp.arange(sq)
        mask = (idx[:, None] >= jnp.arange(sk)[None, :])[None, None, None]
    dense = _sdpa(qg, k, v, mask, scale=0.25)
    chunked = _sdpa_chunked(qg, k, v, scale=0.25, causal=causal,
                            chunk=chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                               rtol=2e-5, atol=2e-5)


def test_chunked_grad_finite():
    b, s, kv, g, dh = 1, 64, 2, 2, 8
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, s, kv, g, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32)

    def loss(q, k, v):
        return jnp.sum(_sdpa_chunked(q, k, v, 0.35, True, chunk=16) ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for gr in grads:
        assert jnp.isfinite(gr).all()
