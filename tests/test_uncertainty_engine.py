"""Batched uncertainty engine (Alg 7+8): serial-reference parity of the
SubsetBank kernel, batch-of-one equivalence, degenerate-subset and
all-NaN-throughput edge cases, and registry-level dispatch."""
import numpy as np
import pytest

from repro.core.ala import ALA
from repro.core.annealing import (SAConfig, SALog, batch_subset_masks,
                                  subset_mask)
from repro.core.error_predictor import predict_error
from repro.core.expmodel import exp_model
from repro.core.uncertainty import (MIN_SUBSET_ROWS, bank_confidence,
                                    bank_distances, build_subset_bank,
                                    confidence, dmin_confidence)

PARITY = 1e-6
GBT_KW = dict(n_estimators=15, learning_rate=0.2, max_depth=3)


# ----------------------------------------------------------------- helpers --
def _toy_workload(seed=0, noise=0.02):
    rng = np.random.default_rng(seed)
    rows = []
    for ii in (128, 512, 2048):
        for oo in (128, 1024):
            c = 2e4 / np.log2(ii + oo)
            bbs = np.array([1, 2, 4, 8, 16, 32, 64, 128], float)
            y = exp_model(bbs, 0.9 * c, 0.03, c)
            y = y * rng.lognormal(0, noise, len(bbs))
            rows += [(ii, oo, bb, t) for bb, t in zip(bbs, y)]
    arr = np.asarray(rows, float)
    return arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]


def _split_toy(seed=0):
    ii, oo, bb, thpt = _toy_workload(seed=seed)
    rng = np.random.default_rng(seed)
    m = rng.random(len(ii)) < 0.5
    return (ii[m], oo[m], bb[m], thpt[m]), \
        (ii[~m], oo[~m], bb[~m], thpt[~m])


@pytest.fixture(scope="module")
def fitted_ala():
    train, test = _split_toy()
    ala = ALA()
    ala.cfg.sa = SAConfig(n_iters=8, seed=0, n_chains=2, gbt_kw=GBT_KW)
    ala.fit(*train)
    ala.explore(test)
    ala.fit_error()
    return ala, train, test


def _queries(test, n=12, seed=3):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        m = rng.random(len(test[0])) < 0.6
        if m.sum() < 2:
            m[:2] = True
        out.append(tuple(v[m] for v in test))
    return out


# ----------------------------------------------------- masks / bank build --
def test_batch_subset_masks_match_serial():
    train, _ = _split_toy()
    ala_log_subsets = [
        {"ii": frozenset([128.0, 512.0]), "oo": frozenset([128.0]),
         "bb": frozenset([1.0, 4.0, 16.0])},
        {"ii": frozenset([2048.0]), "oo": frozenset([128.0, 1024.0]),
         "bb": frozenset([2.0, 8.0])},
        {"ii": frozenset([128.0, 512.0, 2048.0]),
         "oo": frozenset([128.0, 1024.0]),
         "bb": frozenset([1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0])},
    ]
    ii, oo, bb, _ = train
    got = batch_subset_masks(ii, oo, bb, ala_log_subsets)
    ref = np.stack([subset_mask(ii, oo, bb, s) for s in ala_log_subsets])
    np.testing.assert_array_equal(got, ref)


def test_bank_histograms_count_subset_rows(fitted_ala):
    ala, train, _ = fitted_ala
    bank = ala.bank()
    masks = np.stack([subset_mask(*train[:3], s) for s in bank.subsets])
    np.testing.assert_array_equal(bank.masks, masks)
    # each feature histogram sums to the subset's row count
    np.testing.assert_allclose(bank.hist.sum(axis=2),
                               np.repeat(masks.sum(axis=1)[:, None], 4,
                                         axis=1))
    np.testing.assert_array_equal(
        bank.valid, masks.sum(axis=1) >= MIN_SUBSET_ROWS)


# ------------------------------------------------------- numerical parity --
def test_distance_matrix_jax_matches_serial(fitted_ala):
    ala, _, test = fitted_ala
    bank = ala.bank()
    qs = _queries(test)
    D_np = bank_distances(bank, qs, backend="numpy")
    D_jx = bank_distances(bank, qs, backend="jax")
    assert D_np.shape == (len(qs), bank.n_subsets)
    np.testing.assert_allclose(D_jx, D_np, atol=PARITY, rtol=0)


def test_estimate_batch_parity_on_err_dmin_conf(fitted_ala):
    ala, _, test = fitted_ala
    qs = _queries(test)
    err_j, dmin_j, conf_j = ala.estimate_batch(qs, backend="jax")
    err_n, dmin_n, conf_n = ala.estimate_batch(qs, backend="numpy")
    np.testing.assert_allclose(err_j, err_n, atol=PARITY, rtol=0)
    np.testing.assert_allclose(dmin_j, dmin_n, atol=PARITY, rtol=0)
    np.testing.assert_allclose(conf_j, conf_n, atol=PARITY, rtol=0)
    assert ((conf_j > 0) & (conf_j <= 1)).all()


def test_batch_of_one_equals_estimate(fitted_ala):
    ala, _, test = fitted_ala
    q = _queries(test, n=1)[0]
    err, conf = ala.estimate(q)
    err_b, _, conf_b = ala.estimate_batch([q], backend="jax")
    assert err_b[0] == pytest.approx(err, abs=PARITY)
    assert conf_b[0] == pytest.approx(conf, abs=PARITY)


def test_batched_error_predictor_routes_jax_backend(fitted_ala):
    ala, _, _ = fitted_ala
    log = ala.sa_log
    p_np = predict_error(ala.error_model, log.subsets[:6], log.universes)
    p_jx = predict_error(ala.error_model, log.subsets[:6], log.universes,
                         backend="jax")
    np.testing.assert_allclose(p_jx, p_np, atol=PARITY, rtol=0)


def test_confidence_decreases_under_shift_batched(fitted_ala):
    ala, _, test = fitted_ala
    ii, oo, bb, thpt = test
    shifted = (ii * 7, oo * 5, bb, thpt * 0.1)
    _, _, conf = ala.estimate_batch([test, shifted], backend="jax")
    assert conf[0] > conf[1], conf


def test_out_of_range_mass_lands_in_reserved_bins(fitted_ala):
    """Training rows never occupy the boundary bins; a workload far
    outside the range concentrates there and reads as distant."""
    ala, train, test = fitted_ala
    bank = ala.bank()
    assert (bank.hist[:, :, 0] == 0).all()
    assert (bank.hist[:, :, -1] == 0).all()
    far = tuple(v * 1000.0 for v in test)
    _, dmin, conf = ala.estimate_batch([test, far], backend="jax")
    assert conf[1] < conf[0]
    assert dmin[1] > 0.9          # everything in bins no subset touches


def test_bank_max_subsets_window(fitted_ala):
    """An explicit max_subsets rebuilds a cached bank; the default
    window matches the serial confidence() cap."""
    ala, _, _ = fitted_ala
    full = len(ala.sa_log.subsets)
    default = ala.bank()
    assert default.n_subsets == min(full, 200)
    small = ala.bank(max_subsets=3)
    assert small.n_subsets == 3
    assert small.subsets == ala.sa_log.subsets[-3:]
    assert ala.bank() is small            # None reuses the cache
    assert ala.bank(max_subsets=full).n_subsets == full


# ------------------------------------------------------------ edge cases --
def _degenerate_log(train):
    """Every subset selects < MIN_SUBSET_ROWS training rows."""
    ii, oo, bb, _ = train
    universes = {"ii": np.unique(ii), "oo": np.unique(oo),
                 "bb": np.unique(bb)}
    empty = {"ii": frozenset([universes["ii"][0]]),
             "oo": frozenset([universes["oo"][0]]),
             "bb": frozenset([universes["bb"][0]])}
    # one (ii, oo, bb) cell holds at most one training row
    return SALog(subsets=[empty, dict(empty)], errors=[100.0, 100.0],
                 universes=universes, best_subset=empty, best_error=100.0)


def test_degenerate_log_yields_inf_sentinel_both_paths():
    train, test = _split_toy()
    log = _degenerate_log(train)
    # regression: the legacy serial loop used to report d_min = 1.0
    # (confidence 0.5) when every subset was skipped
    d, c = confidence(train, log, test)
    assert np.isinf(d) and c == 0.0
    bank = build_subset_bank(train, log)
    assert not bank.valid.any()
    for backend in ("numpy", "jax"):
        d_min, conf = bank_confidence(bank, [test], backend=backend)
        assert np.isinf(d_min[0]) and conf[0] == 0.0


def test_partially_degenerate_bank_skips_invalid_subsets(fitted_ala):
    ala, train, test = fitted_ala
    log = ala.sa_log
    tiny = _degenerate_log(train).subsets[0]
    mixed = SALog(subsets=[tiny] + list(log.subsets),
                  errors=[100.0] + list(log.errors),
                  universes=log.universes, best_subset=log.best_subset,
                  best_error=log.best_error)
    bank = build_subset_bank(train, mixed)
    assert not bank.valid[0] and bank.valid[1:].all()
    D = bank_distances(bank, [test], backend="numpy")
    d_min, conf = dmin_confidence(D, bank.valid)
    # the invalid subset's column must not win the min
    assert d_min[0] == pytest.approx(D[0, 1:][bank.valid[1:]].min())
    assert 0.0 < conf[0] <= 1.0


def test_all_nan_throughput_query_filled_with_predictions(fitted_ala):
    ala, _, test = fitted_ala
    ii, oo, bb, _ = test
    nan_q = (ii, oo, bb, np.full(len(ii), np.nan))
    filled_q = (ii, oo, bb, ala.predict(ii, oo, bb))
    err_a, dmin_a, conf_a = ala.estimate_batch([nan_q], backend="jax")
    err_b, dmin_b, conf_b = ala.estimate_batch([filled_q], backend="jax")
    assert np.isfinite([err_a[0], dmin_a[0], conf_a[0]]).all()
    assert err_a[0] == pytest.approx(err_b[0], abs=PARITY)
    assert conf_a[0] == pytest.approx(conf_b[0], abs=PARITY)


def test_ragged_query_lengths_one_call(fitted_ala):
    ala, _, test = fitted_ala
    qs = [tuple(v[:k] for v in test) for k in (2, 5, 17)]
    err, d_min, conf = ala.estimate_batch(qs, backend="jax")
    assert err.shape == d_min.shape == conf.shape == (3,)
    assert np.isfinite(err).all() and np.isfinite(conf).all()
    # per-query results are independent of their batch neighbours
    solo = ala.estimate_batch([qs[1]], backend="jax")
    assert conf[1] == pytest.approx(solo[2][0], abs=PARITY)


# ----------------------------------------------------- registry dispatch --
def test_registry_estimate_groups_rows_by_combo():
    from repro.core.dataset import Dataset
    from repro.core.registry import ModelRegistry
    rng = np.random.default_rng(0)
    cols = {k: [] for k in ("model", "ii", "oo", "bb", "thpt")}
    for model in ("a", "b"):
        for ii in (128.0, 512.0, 2048.0):
            for oo in (128.0, 1024.0):
                c = rng.uniform(2e3, 2e4)
                for bb in (1.0, 2.0, 4.0, 8.0, 16.0, 64.0):
                    cols["model"].append(model)
                    cols["ii"].append(ii)
                    cols["oo"].append(oo)
                    cols["bb"].append(bb)
                    cols["thpt"].append(
                        (c - 0.9 * c * np.exp(-0.05 * bb))
                        * rng.lognormal(0, 0.02))
    data = Dataset({k: np.asarray(v) for k, v in cols.items()})
    sa = SAConfig(n_iters=4, seed=0, n_chains=2, gbt_kw=GBT_KW)
    reg = ModelRegistry(keys=("model",)).fit(data, **GBT_KW)
    reg.fit_uncertainty(data, sa_cfg=sa, **GBT_KW)
    err, d_min, conf = reg.estimate(data)
    assert err.shape == d_min.shape == conf.shape == (len(data),)
    assert np.isfinite(err).all() and (conf > 0).all()
    # rows of one combo all share that combo's single workload estimate
    for model in ("a", "b"):
        m = data["model"] == model
        assert np.unique(err[m]).size == 1
        assert np.unique(conf[m]).size == 1
    # unknown-combo rows get the explicit degenerate sentinel
    other = Dataset({k: np.asarray(v[:6]) if k != "model"
                     else np.asarray(["zz"] * 6)
                     for k, v in cols.items()})
    e2, d2, c2 = reg.estimate(other)
    assert np.isnan(e2).all() and np.isinf(d2).all() and (c2 == 0).all()
