"""Metrics layer: fixed-bin contract vs the uncertainty bank, mergeable
histograms (property-tested where hypothesis is installed, seeded
otherwise), ring logs, and the serving re-export surface."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.uncertainty import FEATS, _bank_edges
from repro.obs.metrics import (Counter, Gauge, RingLog, StreamHist,
                               bucketize, fixed_edges, percentile_with_inf,
                               tenant_rollup)


# -- fixed-bin contract vs uncertainty._bank_edges ---------------------------

def test_fixed_edges_match_bank_edges_per_feature():
    """fixed_edges(lo, hi, B, log=...) must reproduce _bank_edges for
    every feature given the same value range — one binning contract."""
    rng = np.random.default_rng(0)
    ii = rng.integers(64, 4096, 50).astype(np.float64)
    oo = rng.integers(16, 512, 50).astype(np.float64)
    bb = rng.integers(1, 64, 50).astype(np.float64)
    thpt = rng.uniform(100.0, 9000.0, 50)
    n_bins = 24
    bank = _bank_edges((ii, oo, bb, thpt), n_bins)
    cols = dict(zip(FEATS, (ii, oo, bb, thpt)))
    for fi, f in enumerate(FEATS):
        v = cols[f]
        mine = fixed_edges(v.min(), v.max(), n_bins, log=(f != "thpt"))
        np.testing.assert_array_equal(mine, bank[fi], err_msg=f)


def test_fixed_edges_boundary_bins_reserved():
    e = fixed_edges(1.0, 100.0, 16, log=True)
    vals = np.linspace(1.0, 100.0, 200)
    bins = bucketize(vals, e)
    assert bins.min() >= 1 and bins.max() <= 14
    assert bucketize([0.5], e)[0] == 0          # below range
    assert bucketize([150.0], e)[0] == 15       # above range


def test_fixed_edges_rejects_tiny_bin_count():
    with pytest.raises(ValueError):
        fixed_edges(0.0, 1.0, 2)


# -- percentile_with_inf (the shared exact percentile) -----------------------

def test_percentile_with_inf_matches_numpy_on_finite():
    rng = np.random.default_rng(1)
    v = rng.exponential(2.0, 257)
    for q in (0.0, 12.5, 50.0, 95.0, 99.0, 100.0):
        assert percentile_with_inf(v, q) \
            == pytest.approx(float(np.percentile(v, q)))


def test_percentile_with_inf_inf_mass():
    v = np.array([0.1, 0.2, np.inf, np.inf])
    assert percentile_with_inf(v, 25.0) == pytest.approx(0.175)
    assert percentile_with_inf(v, 95.0) == float("inf")
    assert percentile_with_inf(np.array([]), 50.0) == float("inf")


# -- StreamHist: seeded invariants -------------------------------------------

def _rand_vals(rng, n):
    v = rng.exponential(1.0, n)
    v[rng.random(n) < 0.1] = np.inf
    v[rng.random(n) < 0.03] = np.nan
    return v


def test_hist_merge_order_invariance_seeded():
    rng = np.random.default_rng(2)
    shards = [_rand_vals(rng, 200) for _ in range(5)]
    h = StreamHist.from_range(0.0, 8.0, 32)
    parts = []
    for s in shards:
        p = h.copy()
        p.observe(s)
        parts.append(p)
    fwd = StreamHist.merged(parts)
    rev = StreamHist.merged(parts[::-1])
    np.testing.assert_array_equal(fwd.counts, rev.counts)
    assert fwd.n_inf == rev.n_inf and fwd.n_nan == rev.n_nan
    for q in (10.0, 50.0, 95.0):
        assert fwd.quantile(q) == rev.quantile(q)


def test_hist_shard_merge_equals_whole_stream_seeded():
    """Per-shard hists merged == one hist over the concatenated stream
    (identical counts), and the histogram quantile tracks the exact
    percentile within one bin width on the finite mass."""
    rng = np.random.default_rng(3)
    shards = [_rand_vals(rng, 300) for _ in range(4)]
    allv = np.concatenate(shards)
    fin = allv[np.isfinite(allv)]
    lo, hi = float(fin.min()), float(fin.max())
    n_bins = 48
    whole = StreamHist.from_range(lo, hi, n_bins)
    whole.observe(allv)
    parts = []
    for s in shards:
        p = StreamHist.from_range(lo, hi, n_bins)
        p.observe(s)
        parts.append(p)
    merged = StreamHist.merged(parts)
    np.testing.assert_array_equal(merged.counts, whole.counts)
    assert merged.total == whole.total
    bin_w = (hi - lo) / (n_bins - 2)
    # NaN carries no histogram mass, so the exact reference must also
    # exclude it (np.sort would rank NaN above +inf otherwise)
    massv = allv[~np.isnan(allv)]
    for q in (25.0, 50.0, 75.0):
        exact = percentile_with_inf(massv, q)
        if np.isfinite(exact):
            assert abs(merged.quantile(q) - exact) <= bin_w + 1e-9


def test_hist_inf_nan_mass_accounting():
    h = StreamHist.from_range(0.0, 1.0, 16)
    h.observe([0.5, np.inf, np.inf, -np.inf, np.nan, 0.2])
    assert h.n_inf == 2.0 and h.n_neg_inf == 1.0 and h.n_nan == 1.0
    assert h.counts.sum() == 2.0
    assert h.total == 5.0                      # NaN carries no mass
    # >half the mass at -inf pulls low quantiles to -inf; the +inf
    # tail owns the top ranks
    assert h.quantile(10.0) == float("-inf")
    assert h.quantile(99.0) == float("inf")


def test_hist_shed_heavy_run_cannot_report_rosy_p95():
    h = StreamHist.from_range(0.0, 1.0, 16)
    h.observe(np.full(50, 0.1))
    h.observe(np.full(50, np.inf))             # half the traffic shed
    assert h.quantile(95.0) == float("inf")
    assert np.isfinite(h.quantile(40.0))


def test_hist_merge_rejects_mismatched_edges():
    a = StreamHist.from_range(0.0, 1.0, 16)
    b = StreamHist.from_range(0.0, 2.0, 16)
    with pytest.raises(ValueError):
        a.merge(b)


# -- StreamHist: hypothesis properties ---------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n_shards=st.integers(2, 6),
       n_bins=st.integers(8, 64))
def test_hist_merge_order_invariance_property(seed, n_shards, n_bins):
    rng = np.random.default_rng(seed)
    shards = [_rand_vals(rng, int(rng.integers(1, 120)))
              for _ in range(n_shards)]
    parts = []
    for s in shards:
        p = StreamHist.from_range(0.0, 6.0, n_bins)
        p.observe(s)
        parts.append(p)
    perm = rng.permutation(n_shards)
    a = StreamHist.merged(parts)
    b = StreamHist.merged([parts[i] for i in perm])
    np.testing.assert_array_equal(a.counts, b.counts)
    assert (a.n_inf, a.n_neg_inf, a.n_nan) \
        == (b.n_inf, b.n_neg_inf, b.n_nan)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       q=st.floats(1.0, 99.0))
def test_hist_quantile_within_bin_width_property(seed, q):
    rng = np.random.default_rng(seed)
    v = rng.gamma(2.0, 1.5, 500)
    h = StreamHist.from_values(v, 48)
    exact = percentile_with_inf(v, q)
    bin_w = (v.max() - v.min()) / 46.0
    assert abs(h.quantile(q) - exact) <= bin_w + 1e-9


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       inf_frac=st.floats(0.0, 0.9))
def test_hist_inf_mass_property(seed, inf_frac):
    rng = np.random.default_rng(seed)
    n = 200
    v = rng.exponential(1.0, n)
    inf_mask = rng.random(n) < inf_frac
    v[inf_mask] = np.inf
    h = StreamHist.from_values(v, 32)
    assert h.n_inf == float(inf_mask.sum())
    assert h.total == float(n)
    # any rank inside the inf mass must report inf, matching the exact
    # percentile's miss convention
    for q in (50.0, 95.0):
        assert np.isfinite(h.quantile(q)) \
            == np.isfinite(percentile_with_inf(v, q))


# -- Counter / Gauge ---------------------------------------------------------

def test_counter_and_gauge_merge():
    a, b = Counter(), Counter()
    a.inc(3)
    b.inc(4)
    assert a.merge(b).value == 7
    g, h = Gauge(), Gauge()
    for v in (1.0, 5.0):
        g.set(v)
    h.set(-2.0)
    g.merge(h)
    assert g.n == 3 and g.min == -2.0 and g.max == 5.0
    assert g.mean == pytest.approx(4.0 / 3.0)
    assert Gauge().mean != Gauge().mean      # NaN when empty


# -- RingLog -----------------------------------------------------------------

def test_ringlog_caps_but_counts_losslessly():
    log = RingLog(5)
    log.extend(range(12))
    assert len(log) == 5
    assert list(log) == [7, 8, 9, 10, 11]
    assert log.n_total == 12 and log.n_dropped == 7
    assert log[0] == 7 and log[-1] == 11 and log[1:3] == [8, 9]
    log.clear()
    assert len(log) == 0 and log.n_total == 12


def test_ringlog_wraps_existing_list():
    log = RingLog(3, [1, 2, 3, 4])
    assert list(log) == [2, 3, 4] and log.n_total == 4


def test_ringlog_rejects_zero_cap():
    with pytest.raises(ValueError):
        RingLog(0)


# -- serving re-export + rollup parity ---------------------------------------

def test_percentile_reexported_from_serving_simulator():
    """Moved helper stays importable from its old home."""
    from repro.serving.simulator import percentile_with_inf as old
    assert old is percentile_with_inf


def test_tenant_rollup_counts_and_miss_convention():
    tenant = np.array(["a", "a", "b", "b", "b"], object)
    ttft = np.array([0.1, np.inf, 0.2, 0.3, np.inf])
    oo = np.array([10, 20, 30, 40, 50])
    completed = np.array([True, False, True, True, False])
    shed = np.array([False, True, False, False, True])
    retries = np.array([0, 1, 0, 2, 0])
    out = tenant_rollup(tenant, ttft, oo, completed, shed, retries,
                        slo_map={"a": 1.0})
    a, b = out["a"], out["b"]
    assert a["n_requests"] == 2 and a["n_shed"] == 1
    assert a["attainment"] == pytest.approx(0.5)
    assert a["ttft_p95_s"] == float("inf")     # shed mass surfaces
    assert np.isnan(b["attainment"])           # tenant without an SLO
    assert b["n_retries"] == 2
    assert a["goodput_share"] + b["goodput_share"] == pytest.approx(1.0)
    assert a["goodput_share"] == pytest.approx(10.0 / 80.0)
