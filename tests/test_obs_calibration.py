"""Calibration audit: tick accounting, PAV / reliability-curve shape
(property-tested where hypothesis is installed), the autoscaler and
online-loop feeds into one unified event log, and the scorecard /
JSONL export round-trip."""
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.obs import ObsConfig
from repro.obs.calibration import (CalEvent, CalibrationAudit, pav,
                                   reliability_curve)
from repro.obs.export import scorecard_markdown, write_jsonl
from repro.obs.metrics import RingLog
from repro.serving.autoscaler import ALAAutoscaler
from repro.serving.simulator import Observation


# -- tick accounting ---------------------------------------------------------

def test_tick_computes_ape_and_counts():
    a = CalibrationAudit()
    ev = a.tick(1.0, predicted=90.0, measured=100.0, confidence=0.8)
    assert ev.data["ape"] == pytest.approx(10.0)
    a.tick(2.0, predicted=float("nan"), measured=100.0, confidence=0.1)
    assert a.counts == {"tick": 2}
    tk = a.ticks()
    assert np.isinf(tk["ape"][1])              # nonfinite pred -> inf APE
    assert tk["t"].tolist() == [1.0, 2.0]


def test_event_log_ring_cap_keeps_counts_lossless():
    a = CalibrationAudit(cfg=ObsConfig(max_cal_events=4))
    assert isinstance(a.events, RingLog)
    for i in range(10):
        a.tick(float(i), predicted=100.0, measured=100.0, confidence=0.5)
    a.event(10.0, "degradation", reason="backoff")
    assert len(a.events) == 4
    assert a.counts == {"tick": 10, "degradation": 1}
    s = a.summary()
    assert s["n_events_retained"] == 4
    assert s["n_events"] == {"degradation": 1, "tick": 10}


def test_calevent_to_dict_flat():
    ev = CalEvent(t=3.0, kind="drift", clock="epoch",
                  data={"combo": "a/b", "reason": "residual_growth"})
    d = ev.to_dict()
    assert d == {"t": 3.0, "kind": "drift", "clock": "epoch",
                 "combo": "a/b", "reason": "residual_growth"}


# -- PAV / reliability curve -------------------------------------------------

def test_pav_monotone_and_mean_preserving_seeded():
    rng = np.random.default_rng(0)
    y = rng.normal(size=40)
    w = rng.uniform(0.5, 3.0, 40)
    fit = pav(y, w)
    assert (np.diff(fit) >= -1e-12).all()
    assert float((fit * w).sum()) == pytest.approx(float((y * w).sum()))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 60))
def test_pav_monotone_property(seed, n):
    rng = np.random.default_rng(seed)
    fit = pav(rng.normal(size=n), rng.uniform(0.1, 2.0, n))
    assert (np.diff(fit) >= -1e-12).all()


def test_pav_on_sorted_input_is_identity():
    y = np.array([0.1, 0.2, 0.5, 0.9])
    np.testing.assert_allclose(pav(y, np.ones(4)), y)


def test_reliability_curve_on_calibrated_scores():
    """High-confidence ticks accurate, low-confidence ones not: the
    binned curve must recover the upward trend; PAV keeps it monotone
    even on a noisy sample."""
    rng = np.random.default_rng(1)
    conf = rng.uniform(0.0, 1.0, 3000)
    ok = (rng.random(3000) < conf).astype(float)
    cur = reliability_curve(conf, ok, n_bins=10, monotone=True)
    acc = cur["bin_acc"]
    assert len(acc) == 10 and cur["monotone"]
    assert all(acc[i] <= acc[i + 1] + 1e-12 for i in range(len(acc) - 1))
    np.testing.assert_allclose(acc, cur["bin_conf"], atol=0.12)
    assert sum(cur["bin_n"]) == 3000
    # anti-calibrated scores come out flat-or-clamped but still monotone
    bad = reliability_curve(conf, 1.0 - ok, n_bins=10, monotone=True)
    assert all(np.diff(bad["bin_acc"]) >= -1e-12)
    assert bad["raw_acc"] != bad["bin_acc"]    # PAV actually acted


def test_reliability_curve_drops_empty_bins_and_nonfinite_conf():
    conf = np.array([0.05, 0.06, 0.95, 0.96, float("nan")])
    ok = np.array([0.0, 0.0, 1.0, 1.0, 1.0])
    cur = reliability_curve(conf, ok, n_bins=10)
    assert len(cur["bin_conf"]) == 2           # only two occupied bins
    assert sum(cur["bin_n"]) == 4              # NaN conf excluded


# -- autoscaler feed ---------------------------------------------------------

class _StubALA:
    """Duck-typed ALA: fixed per-request throughput, no error model
    (the fallback branch), so control() runs without a fit."""
    error_model = None
    sa_log = None

    def predict(self, ii, oo, bb):
        return np.full(len(np.atleast_1d(ii)), 500.0)


def _obs(now, measured=400.0, window=5.0):
    return Observation(
        now=now, window_s=window, n_arrivals=10, mean_ii=256.0,
        mean_oo=64.0, arrival_rate=2.0, queue_len=3, n_running=8,
        n_active_replicas=2, batch_cap=32, decode_tokens=2000,
        busy_s=5.0, measured_tok_s=measured)


def test_autoscaler_obs_config_builds_audit_and_ticks():
    sc = ALAAutoscaler(ala=_StubALA(), obs=ObsConfig())
    assert sc.audit is not None
    for i in range(4):
        sc.control(_obs(float(i + 1) * 5.0))
    assert sc.audit.counts["tick"] == 4
    tk = sc.audit.ticks()
    np.testing.assert_allclose(tk["predicted"], 500.0)
    np.testing.assert_allclose(tk["measured"], 400.0)
    np.testing.assert_allclose(tk["ape"], 20.0)
    # no estimate() on the stub -> Alg 7 pred_err stays NaN, not stale
    assert np.isnan(tk["pred_err"]).all()


def test_autoscaler_degradation_reaches_audit():
    sc = ALAAutoscaler(ala=_StubALA(), obs=ObsConfig())
    sc.control(_obs(1.0, window=0.0))          # collapsed control window
    assert sc.degradations and sc.degradations[0][1] == "zero_window"
    assert sc.audit.counts.get("degradation") == 1
    ev = [e for e in sc.audit.events if e.kind == "degradation"][0]
    assert ev.data["reason"] == "zero_window"


def test_autoscaler_max_log_entries_caps_diagnostics():
    sc = ALAAutoscaler(ala=_StubALA(),
                       obs=ObsConfig(max_log_entries=3))
    for i in range(8):
        sc.control(_obs(float(i + 1) * 5.0))
    assert isinstance(sc.log, RingLog)
    assert len(sc.log) == 3 and sc.log.n_total == 8


def test_autoscaler_explicit_audit_shared():
    audit = CalibrationAudit()
    sc = ALAAutoscaler(ala=_StubALA(), audit=audit)
    sc.control(_obs(5.0))
    assert audit.counts["tick"] == 1           # no ObsConfig needed


# -- online-loop feed --------------------------------------------------------

def test_ingest_report_folds_into_epoch_clock():
    from repro.core.online import DriftSignal, RefitReport
    audit = CalibrationAudit()
    sig = DriftSignal(combo=("m", "a"), n_rows=8, confidence=0.4,
                      pred_err=30.0, resid_ape=80.0, drifted=True,
                      reason="residual_growth")
    calm = DriftSignal(combo=("m", "b"), n_rows=8, confidence=0.9,
                       pred_err=5.0, resid_ape=6.0, drifted=False,
                       reason="")
    rep = RefitReport(epoch=3, n_rows=16, changed=[("m", "a"), ("m", "b")],
                      refit=[("m", "a")], skipped=[("m", "b")],
                      drift={("m", "a"): sig, ("m", "b"): calm},
                      registry_s=0.1, uncertainty_s=0.2, wall_s=0.3,
                      n_quarantined=4)
    audit.ingest_report(rep)
    assert audit.counts == {"drift": 1, "quarantine": 1, "refit": 1}
    evs = list(audit.events)
    assert all(e.clock == "epoch" and e.t == 3.0 for e in evs)
    drift = next(e for e in evs if e.kind == "drift")
    assert drift.data["combo"] == "m/a"
    assert drift.data["reason"] == "residual_growth"
    ref = next(e for e in evs if e.kind == "refit")
    assert ref.data["n_changed"] == 2 and ref.data["n_refit"] == 1


def test_online_ala_audit_hook_forwards_reports():
    """OnlineALA(audit=...) mirrors every ingest into the audit without
    touching the report itself."""
    import inspect

    from repro.core.online import OnlineALA
    assert "audit" in inspect.signature(OnlineALA.__init__).parameters
    src = inspect.getsource(OnlineALA.ingest)
    assert "ingest_report" in src


# -- export round-trip -------------------------------------------------------

def test_audit_jsonl_and_scorecard(tmp_path):
    a = CalibrationAudit()
    for i in range(20):
        conf = i / 20.0
        err = 5.0 if conf > 0.5 else 60.0
        a.tick(float(i), predicted=100.0 + err, measured=100.0,
               confidence=conf)
    a.event(21.0, "degradation", reason="backoff")
    path = tmp_path / "events.jsonl"
    assert write_jsonl(a.events, path) == 21
    back = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert sum(1 for d in back if d["kind"] == "tick") == 20
    s = a.summary()
    assert s["accuracy_rate"] == pytest.approx(0.45)  # conf <= 0.5 inacc
    card = scorecard_markdown(calibration=s, title="t")
    assert "accuracy_rate" in card and "Reliability curve" in card
    acc = s["reliability"]["bin_acc"]
    assert all(acc[i] <= acc[i + 1] + 1e-12 for i in range(len(acc) - 1))
