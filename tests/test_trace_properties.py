"""Property tests for ``repro.serving.traces`` (hypothesis-driven where
available — see ``_hypothesis_compat``)."""
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.serving.traces import (FleetTraceConfig, TenantConfig, Trace,
                                  TraceConfig, TraceRequest,
                                  make_fleet_trace, make_trace, mix)


def _trace_cfg(arrival, rate, horizon, seed):
    return TraceConfig(arrival=arrival, rate=rate, horizon_s=horizon,
                       seed=seed)


@settings(max_examples=30, deadline=None)
@given(arrival=st.sampled_from(["poisson", "gamma", "mmpp"]),
       rate=st.floats(0.5, 12.0),
       horizon=st.floats(5.0, 60.0),
       seed=st.integers(0, 2**31 - 1))
def test_make_trace_well_formed(arrival, rate, horizon, seed):
    tr = make_trace(_trace_cfg(arrival, rate, horizon, seed))
    arr = tr.arrivals
    # arrivals sorted inside the horizon, non-negative interarrivals
    assert (arr >= 0.0).all()
    assert (arr < tr.horizon_s).all()
    assert (np.diff(arr) >= 0.0).all()
    assert [r.rid for r in tr.requests] == list(range(len(tr)))
    for r in tr.requests:
        assert r.ii >= 1 and r.oo >= 1


@settings(max_examples=20, deadline=None)
@given(arrival=st.sampled_from(["poisson", "gamma", "mmpp"]),
       seed=st.integers(0, 2**31 - 1))
def test_same_seed_same_trace(arrival, seed):
    cfg = _trace_cfg(arrival, 4.0, 20.0, seed)
    a, b = make_trace(cfg), make_trace(cfg)
    assert a.to_arrays()["arrival_s"].tobytes() \
        == b.to_arrays()["arrival_s"].tobytes()
    assert [(r.ii, r.oo) for r in a.requests] \
        == [(r.ii, r.oo) for r in b.requests]


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_to_from_arrays_roundtrip_bit_exact(seed):
    tr = make_trace(_trace_cfg("gamma", 5.0, 25.0, seed))
    arrs = tr.to_arrays()
    back = Trace.from_arrays(arrival_s=arrs["arrival_s"], ii=arrs["ii"],
                             oo=arrs["oo"], tenant=arrs["tenant"],
                             horizon_s=tr.horizon_s)
    b = back.to_arrays()
    assert arrs["arrival_s"].tobytes() == b["arrival_s"].tobytes()
    assert arrs["ii"].tobytes() == b["ii"].tobytes()
    assert arrs["oo"].tobytes() == b["oo"].tobytes()
    assert list(arrs["tenant"]) == list(b["tenant"])
    assert back.horizon_s == tr.horizon_s


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       cuts=st.lists(st.floats(0.01, 0.99), min_size=1, max_size=4,
                     unique=True))
def test_slice_partition_preserves_requests(seed, cuts):
    tr = make_trace(_trace_cfg("poisson", 6.0, 30.0, seed))
    bounds = [0.0] + sorted(c * tr.horizon_s for c in cuts) \
        + [tr.horizon_s]
    parts = [tr.slice(a, b) for a, b in zip(bounds, bounds[1:])]
    assert sum(len(p) for p in parts) == len(tr)
    # every part re-numbers rids densely but keeps payloads; the
    # concatenated payloads equal the original's (arrival order)
    flat = [(r.arrival_s, r.ii, r.oo) for p in parts for r in p.requests]
    assert flat == [(r.arrival_s, r.ii, r.oo) for r in tr.requests]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       amp=st.floats(0.0, 0.9),
       crowds=st.integers(0, 3))
def test_fleet_trace_well_formed_and_deterministic(seed, amp, crowds):
    fcfg = FleetTraceConfig(tenants=(
        TenantConfig(name="a",
                     trace=_trace_cfg("poisson", 3.0, 30.0, 0),
                     ttft_slo_s=1.0, diurnal_amp=amp),
        TenantConfig(name="b",
                     trace=_trace_cfg("gamma", 2.0, 30.0, 0),
                     ttft_slo_s=4.0, flash_crowds=crowds,
                     flash_mult=3.0, flash_dur_s=5.0),
    ), horizon_s=30.0, seed=seed)
    t1, t2 = make_fleet_trace(fcfg), make_fleet_trace(fcfg)
    a1, a2 = t1.to_arrays(), t2.to_arrays()
    assert a1["arrival_s"].tobytes() == a2["arrival_s"].tobytes()
    assert list(a1["tenant"]) == list(a2["tenant"])
    assert set(t1.tenants) <= {"a", "b"}
    assert (np.diff(t1.arrivals) >= 0.0).all()
    assert t1.fleet_config is fcfg
    assert fcfg.slo_map == {"a": 1.0, "b": 4.0}
    # slicing keeps the fleet config attached
    assert t1.slice(0.0, 10.0).fleet_config is fcfg


def test_envelope_bounds():
    """The diurnal × flash envelope stays within its documented bounds
    and ``envelope_max`` really is an upper bound (thinning keep-prob
    must never exceed 1)."""
    tc = TenantConfig(name="x", trace=_trace_cfg("poisson", 1.0, 100.0, 0),
                      diurnal_amp=0.5, flash_crowds=2, flash_mult=4.0,
                      flash_dur_s=10.0)
    crowd = np.array([20.0, 60.0])
    t = np.linspace(0.0, 100.0, 5000)
    env = tc.envelope(t, crowd)
    assert (env >= 0.0).all()
    assert (env <= tc.envelope_max + 1e-12).all()
    inside = (t >= 20.0) & (t < 30.0)
    outside = (t >= 40.0) & (t < 55.0)
    assert env[inside].mean() > env[outside].mean()


def test_tenant_round_trip_through_engine_arrays():
    """Object-dtype tenant column survives to_arrays/from_arrays."""
    reqs = tuple(TraceRequest(rid=i, arrival_s=float(i), ii=8, oo=4,
                              tenant=t)
                 for i, t in enumerate(["x", "y", "x"]))
    tr = Trace(requests=reqs, horizon_s=4.0)
    arrs = tr.to_arrays()
    back = Trace.from_arrays(**arrs, horizon_s=4.0)
    assert [r.tenant for r in back.requests] == ["x", "y", "x"]
    assert tr.tenants == ("x", "y")
