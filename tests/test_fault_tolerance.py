"""Checkpointing, gradient compression, straggler logic, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.configs.shapes import ShapeSpec
from repro.data.pipeline import PipelineConfig, SyntheticPipeline
from repro.models.transformer import Model
from repro.training import checkpoint as ckpt
from repro.training.compression import (compress_with_feedback, decompress,
                                        init_ef_state, quantize_int8,
                                        dequantize_int8)
from repro.training.straggler import StragglerConfig, StragglerMonitor


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": (jnp.zeros((5,)), jnp.full((1,), 7))}}
    ckpt.save_checkpoint(tmp_path, 3, tree)
    restored = ckpt.restore_checkpoint(tmp_path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_atomic_and_gc(tmp_path):
    tree = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save_checkpoint(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.glob("step_*"))
    assert steps == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(tmp_path) == 5
    assert not list(tmp_path.glob(".tmp*")), "staging dir left behind"


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ckpt.save_checkpoint(tmp_path, 1, {"w": jnp.ones((4,))})
    with pytest.raises(AssertionError):
        ckpt.restore_checkpoint(tmp_path, {"w": jnp.ones((5,))})


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Save unsharded, restore with explicit shardings on the host mesh —
    the mesh-reshape path used by elastic restarts."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save_checkpoint(tmp_path, 1, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored = ckpt.restore_checkpoint(tmp_path, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]


# ---------------------------------------------------------------- compression
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_int8_quantization_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(257) * scale, jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_reduces_bias():
    """With EF, the *cumulative* compressed gradient tracks the true sum."""
    rng = np.random.default_rng(0)
    grads = [{"w": jnp.asarray(rng.standard_normal(64) * 0.01
                               + 0.003, jnp.float32)} for _ in range(50)]
    ef = init_ef_state(grads[0])
    acc_comp = np.zeros(64)
    acc_true = np.zeros(64)
    for g in grads:
        q, ef = compress_with_feedback(g, ef)
        acc_comp += np.asarray(decompress(q)["w"])
        acc_true += np.asarray(g["w"])
    # residual is bounded by one quantization step, not O(n_steps)
    resid = np.abs(acc_comp - acc_true).max()
    single_step = np.abs(np.asarray(grads[0]["w"])).max() / 127
    assert resid <= 2 * single_step + 1e-6


# ------------------------------------------------------------------ straggler
def test_straggler_detection():
    mon = StragglerMonitor(StragglerConfig(window=16, threshold=1.5))
    for step in range(10):
        for host in range(8):
            mon.record(host, 1.0 if host != 3 else 2.5)
    assert mon.stragglers() == [3]


def test_bounded_staleness():
    mon = StragglerMonitor(StragglerConfig(max_stale=2))
    assert mon.should_proceed_without(7)
    assert mon.should_proceed_without(7)
    assert not mon.should_proceed_without(7)   # staleness bound hit
    mon.mark_arrived(7)
    assert mon.should_proceed_without(7)


# ------------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_resumable():
    cfg = get_smoke_config("llama3.2-3b")
    shape = ShapeSpec("t", seq_len=32, global_batch=2, kind="train")
    p1 = SyntheticPipeline(cfg, shape, PipelineConfig(seed=5))
    p2 = SyntheticPipeline(cfg, shape, PipelineConfig(seed=5))
    for step in (0, 7, 123):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps differ
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = get_smoke_config("llama3.2-3b")
    shape = ShapeSpec("t", seq_len=16, global_batch=2, kind="train")
    b = SyntheticPipeline(cfg, shape).batch_at(0)
    assert b["tokens"].shape == (2, 16)
    assert (b["labels"] < cfg.vocab_size).all()
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_pipeline_vision_and_audio_fronts():
    vcfg = get_smoke_config("internvl2-1b")
    shape = ShapeSpec("t", seq_len=32, global_batch=2, kind="train")
    vb = SyntheticPipeline(vcfg, shape).batch_at(0)
    assert vb["patches"].shape == (2, vcfg.n_patches, vcfg.d_model)
    assert vb["tokens"].shape[1] == 32 - vcfg.n_patches

    acfg = get_smoke_config("whisper-medium")
    ab = SyntheticPipeline(acfg, shape).batch_at(0)
    assert ab["frames"].shape == (2, acfg.encoder_seq, acfg.d_model)
