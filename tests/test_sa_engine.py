"""Batched annealing engine: evaluator parity with the serial path,
packed-GBT jax/numpy equivalence, and registry parallel-fit determinism."""
import numpy as np
import pytest

from repro.core.annealing import (SAConfig, _BatchedEvaluator, anneal,
                                  anneal_batched, evaluate_subset)
from repro.core.database import build_group_structure
from repro.core.error_predictor import train_error_predictor
from repro.core.expmodel import exp_model, initial_params
from repro.core.fit import fit_exponential_groups, fit_exponential_masked
from repro.core.gbt import (GBTRegressor, MultiOutputGBT, fit_packed_forest,
                            kernel_histograms, pack_models)


# ----------------------------------------------------------------- helpers --
def _toy_workload(seed=0, noise=0.02):
    rng = np.random.default_rng(seed)
    iis, oos = [128, 512, 2048], [128, 1024]
    bbs = np.array([1, 2, 4, 8, 16, 32, 64, 128], float)
    rows = []
    for ii in iis:
        for oo in oos:
            c = 2e4 / np.log2(ii + oo)
            y = exp_model(bbs, 0.9 * c, 0.03, c)
            y = y * rng.lognormal(0, noise, len(bbs))
            rows += [(ii, oo, bb, t) for bb, t in zip(bbs, y)]
    arr = np.asarray(rows, float)
    return arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]


def _split_toy(seed=0):
    ii, oo, bb, thpt = _toy_workload(seed=seed)
    rng = np.random.default_rng(seed)
    m = rng.random(len(ii)) < 0.5
    return (ii[m], oo[m], bb[m], thpt[m]), \
        (ii[~m], oo[~m], bb[~m], thpt[~m])


GBT_KW = dict(n_estimators=20, learning_rate=0.2, max_depth=3)


# ---------------------------------------------------------- eval parity -----
def test_batched_evaluator_matches_serial_eval():
    train, test = _split_toy()
    ev = _BatchedEvaluator(train, test, GBT_KW, n_slots=3)
    subs = [
        {"ii": frozenset(np.unique(train[0]).tolist()),
         "oo": frozenset(np.unique(train[1]).tolist()),
         "bb": frozenset(np.unique(train[2]).tolist())},
        {"ii": frozenset([128.0, 512.0]),
         "oo": frozenset([128.0, 1024.0]),
         "bb": frozenset([1.0, 4.0, 16.0, 64.0, 128.0])},
        {"ii": frozenset([128.0]), "oo": frozenset([128.0]),
         "bb": frozenset([1.0, 2.0])},          # degenerate -> 100.0
    ]
    batched = ev.evaluate_batch(subs)
    for s, e in zip(subs, batched):
        serial = evaluate_subset(train, test, s, GBT_KW)
        # identical pipeline; small float32 padding noise in the LM solve
        assert e == pytest.approx(serial, rel=0.05, abs=0.5), (s, e, serial)


def test_batched_anneal_reaches_legacy_best():
    """Equal proposal budget, fixed seed: the K-chain engine must find a
    subset at least as good as the serial loop's."""
    train, test = _split_toy()
    legacy = anneal(train, test, SAConfig(n_iters=20, seed=0,
                                          gbt_kw=GBT_KW))
    batched = anneal_batched(train, test,
                             SAConfig(n_iters=10, seed=0, gbt_kw=GBT_KW,
                                      n_chains=2))
    assert batched.best_error <= legacy.best_error + 1e-6
    assert all(np.isfinite(batched.errors))
    # global best really is the minimum of the log
    assert batched.best_error == pytest.approx(min(batched.errors))


def test_batched_log_feeds_error_predictor():
    train, test = _split_toy()
    log = anneal_batched(train, test,
                         SAConfig(n_iters=8, seed=1, gbt_kw=GBT_KW,
                                  n_chains=3))
    # chains + anchor + n_iters * n_chains entries, Alg 7 trains on them
    assert len(log.errors) == 3 + 1 + 8 * 3
    model = train_error_predictor(log, n_estimators=40)
    assert np.isfinite(model.predict(
        np.zeros((1, sum(len(u) for u in log.universes.values()))))).all()


def test_batched_engine_accepts_sampling_gbt_kw():
    """gbt_kw options the serial engine accepts (subsample/colsample/
    seed) must not crash the batched engine — they drop to the
    per-candidate fallback trainer."""
    train, test = _split_toy()
    kw = dict(GBT_KW, subsample=0.8, seed=3)
    log = anneal_batched(train, test,
                         SAConfig(n_iters=3, seed=0, gbt_kw=kw,
                                  n_chains=2))
    assert all(np.isfinite(log.errors))
    serial = evaluate_subset(train, test, log.best_subset, kw)
    assert log.best_error == pytest.approx(serial, rel=0.05, abs=0.5)


def test_evaluation_cache_dedupes(monkeypatch):
    train, test = _split_toy()
    cfg = SAConfig(n_iters=10, seed=3, gbt_kw=GBT_KW, n_chains=2)
    ev = _BatchedEvaluator(train, test, cfg.gbt_kw, n_slots=3)
    calls = []
    orig = ev.evaluate_batch

    def counting(subsets):
        calls.append(len(subsets))
        return orig(subsets)

    monkeypatch.setattr(ev, "evaluate_batch", counting)
    log = anneal_batched(train, test, cfg, evaluator=ev)
    assert sum(calls) < len(log.errors)      # cache hits happened


# ----------------------------------------------------- masked LM parity -----
def test_fit_exponential_masked_matches_groups():
    rng = np.random.default_rng(0)
    G, maxn = 6, 9
    X = np.zeros((G, maxn))
    Y = np.zeros((G, maxn))
    W = np.zeros((G, maxn))
    groups = []
    for g in range(G):
        n = rng.integers(5, maxn + 1)
        bb = np.sort(rng.choice([1, 2, 4, 8, 16, 32, 64, 128, 256],
                                size=n, replace=False)).astype(float)
        a, b, c = 100 * (g + 1), 0.02 * (g + 1), 600 * (g + 2)
        y = exp_model(bb, a, b, c)
        X[g, :n] = bb
        Y[g, :n] = y
        W[g, :n] = 1.0
        groups.append((bb, y, initial_params(bb, y)))
    theta_m = fit_exponential_masked(
        np.stack([g[2] for g in groups]), X, Y, W)
    theta_g = fit_exponential_groups(groups)
    for g in range(G):
        bb = groups[g][0]
        np.testing.assert_allclose(exp_model(bb, *theta_m[g]),
                                   exp_model(bb, *theta_g[g]), rtol=1e-3)


def test_group_structure_covers_rows():
    ii, oo, bb, thpt = _toy_workload()
    gs = build_group_structure(ii, oo, bb, thpt)
    assert len(gs) == 6
    assert gs.row_w.sum() == len(ii)
    # padded rows reproduce the original data per group
    g = 2
    real = gs.row_w[g] > 0
    key = gs.keys[g]
    rows = (ii == key[0]) & (oo == key[1])
    np.testing.assert_array_equal(np.sort(gs.bb[g, real]), np.sort(bb[rows]))


# ------------------------------------------------- packed GBT inference -----
def test_gbt_jax_backend_matches_numpy():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 10, size=(300, 5))
    y = 2 * X[:, 0] + np.sin(X[:, 1]) * 3 + X[:, 2]
    m = GBTRegressor(n_estimators=40, learning_rate=0.1, max_depth=4)
    m.fit(X[:200], y[:200])
    p_np = m.predict(X[200:])
    p_jax = m.predict(X[200:], backend="jax")
    np.testing.assert_allclose(p_jax, p_np, rtol=1e-5, atol=1e-5)


def test_packed_forest_backends_agree():
    rng = np.random.default_rng(1)
    X = rng.uniform(0, 5, size=(120, 4))
    Y = np.stack([X[:, 0] ** 2, X @ np.ones(4)], axis=1)
    mo = MultiOutputGBT(2, n_estimators=15, learning_rate=0.2).fit(X, Y)
    pf = pack_models([list(mo.models)])
    q = rng.uniform(0, 5, size=(1, 50, 4))
    np.testing.assert_allclose(pf.predict(q, backend="jax"),
                               pf.predict(q, backend="numpy"),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(pf.predict(q, backend="numpy")[0],
                               mo.predict(q[0]), rtol=1e-5, atol=1e-5)


def test_joint_multioutput_fit_identical_to_sequential():
    rng = np.random.default_rng(2)
    X = rng.uniform(0, 10, size=(60, 6))
    Y = np.stack([X[:, 0] * 2, np.sin(X[:, 1]), X[:, 2] - X[:, 3]], axis=1)
    kw = dict(n_estimators=12, learning_rate=0.15, max_depth=4)
    seq = MultiOutputGBT(3, **kw).fit(X, Y, joint=False)
    joint = MultiOutputGBT(3, **kw).fit(X, Y, joint=True)
    q = rng.uniform(0, 10, size=(80, 6))
    np.testing.assert_array_equal(seq.predict(q), joint.predict(q))


def test_masked_packed_fit_equals_subset_fit():
    """Zero row weights must reproduce training on the filtered rows."""
    rng = np.random.default_rng(3)
    X = rng.uniform(0, 10, size=(50, 5))
    Y = np.stack([X[:, 0] + X[:, 1], X[:, 2] ** 1.5], axis=1)
    W = np.ones((1, 50))
    W[0, ::4] = 0.0
    kw = dict(n_estimators=10, learning_rate=0.2, max_depth=3)
    pf = fit_packed_forest(X[None], Y[None], W, **kw)
    keep = W[0] > 0
    ref = MultiOutputGBT(2, **kw).fit(X[keep], Y[keep], joint=False)
    q = rng.uniform(0, 10, size=(40, 5))
    np.testing.assert_allclose(pf.predict(q[None], backend="numpy")[0],
                               ref.predict(q), rtol=1e-5, atol=1e-5)


def test_kernel_histogram_route_matches_scatter_add():
    rng = np.random.default_rng(4)
    bins = rng.integers(0, 16, size=(96, 3)).astype(np.int32)
    grad = rng.normal(size=96)
    hess = np.abs(rng.normal(size=96))
    node = rng.integers(0, 4, size=96)
    hist = np.zeros((4, 3, 16, 2))
    fidx = np.broadcast_to(np.arange(3)[None, :], bins.shape)
    nidx = np.broadcast_to(node[:, None], bins.shape)
    np.add.at(hist, (nidx, fidx, bins, 0),
              np.broadcast_to(grad[:, None], bins.shape))
    np.add.at(hist, (nidx, fidx, bins, 1),
              np.broadcast_to(hess[:, None], bins.shape))
    for force in (None, "interpret"):
        hk = kernel_histograms(bins, grad, hess, node, 4, 16, force=force)
        np.testing.assert_allclose(hk, hist, atol=1e-4)


def test_gbt_use_kernel_fit_close_to_reference():
    rng = np.random.default_rng(5)
    X = rng.uniform(0, 10, size=(200, 4))
    y = X[:, 0] * 3 + X[:, 1]
    kw = dict(n_estimators=8, max_depth=3, n_bins=16)
    a = GBTRegressor(**kw).fit(X, y).predict(X)
    b = GBTRegressor(use_kernel=True, **kw).fit(X, y).predict(X)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


# ------------------------------------------------- registry determinism -----
def test_registry_parallel_fit_deterministic():
    from repro.core.dataset import Dataset
    from repro.core.registry import ModelRegistry
    rng = np.random.default_rng(0)
    rows = []
    for model in ("a", "b"):
        for back in ("x", "y"):
            for ii in (128.0, 512.0):
                for oo in (128.0, 1024.0):
                    c = rng.uniform(2e3, 2e4)
                    for bb in (1.0, 4.0, 16.0, 64.0):
                        rows.append((model, back, ii, oo, bb,
                                     c - 0.9 * c * np.exp(-0.05 * bb)))
    cols = {
        "model": np.array([r[0] for r in rows]),
        "back": np.array([r[1] for r in rows]),
        "ii": np.array([r[2] for r in rows]),
        "oo": np.array([r[3] for r in rows]),
        "bb": np.array([r[4] for r in rows]),
        "thpt": np.array([r[5] for r in rows]),
    }
    data = Dataset(cols)
    kw = dict(n_estimators=10, learning_rate=0.2)
    serial = ModelRegistry(keys=("model", "back"), n_workers=1) \
        .fit(data, **kw)
    parallel = ModelRegistry(keys=("model", "back"), n_workers=4) \
        .fit(data, **kw)
    assert list(serial.combos) == list(parallel.combos)
    np.testing.assert_array_equal(serial.predict(data),
                                  parallel.predict(data))
