"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, assert output shapes + no NaNs; prefill+decode consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.configs.common import SMOKE_DECODE, SMOKE_PREFILL, SMOKE_TRAIN
from repro.models.io import make_batch
from repro.models.transformer import Model


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, SMOKE_TRAIN)
    loss, grads = jax.jit(jax.value_and_grad(model.train_loss))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))),
                     grads))
    assert jnp.isfinite(gnorm), f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.key(1))
    batch = make_batch(cfg, SMOKE_PREFILL)
    max_len = SMOKE_PREFILL.seq_len + 4
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=max_len))(params, batch)
    b = SMOKE_PREFILL.global_batch
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()

    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)[:, None]
    tok = tok.astype(jnp.int32)
    step = jax.jit(model.decode_step)
    for _ in range(3):
        logits2, cache = step(params, cache, tok)
        assert logits2.shape == (b, 1, cfg.padded_vocab)
        assert jnp.isfinite(logits2.astype(jnp.float32)).all()
        tok = jnp.argmax(
            logits2[:, -1, :cfg.vocab_size], axis=-1)[:, None].astype(jnp.int32)


def test_decode_matches_prefill_dense():
    """Decoding token-by-token must match teacher-forced prefill logits."""
    cfg = get_smoke_config("llama3.2-3b")
    model = Model(cfg)
    params = model.init(jax.random.key(2))
    batch = make_batch(cfg, SMOKE_PREFILL)
    toks = batch["tokens"]
    s = toks.shape[1]

    # full prefill logits at last position
    full_logits, _ = jax.jit(model.prefill)(params, {"tokens": toks})

    # prefill first s-1 tokens, then decode the final token
    logits_p, cache = jax.jit(
        lambda p, t: model.prefill(p, {"tokens": t}, max_len=s))(
        params, toks[:, :-1])
    logits_d, _ = jax.jit(model.decode_step)(params, cache, toks[:, -1:])
    assert jnp.allclose(
        full_logits.astype(jnp.float32),
        logits_d.astype(jnp.float32), atol=2e-2), (
        jnp.abs(full_logits.astype(jnp.float32)
                - logits_d.astype(jnp.float32)).max())
