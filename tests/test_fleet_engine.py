"""Vectorized fleet engine: determinism, lazy result surface, tenant
metrics, cost-closure parity, the optional jax trajectory backend, the
adapter fast path, and the zero-window autoscaler guard."""
import numpy as np
import pytest

from _sim_invariants import (assert_per_tenant_consistent,
                             assert_sim_invariants)
from repro.configs import get_config
from repro.perfmodel.simulator import (ServingSetup, decode_step_time_group,
                                       decode_time_fn, prefill_step_time,
                                       prefill_time_fn)
from repro.perfmodel.hardware import TPU_V5E
from repro.serving import adapter
from repro.serving.autoscaler import ALAAutoscaler, StaticPolicy
from repro.serving.simulator import (RequestRecord, SimConfig, SimResult,
                                     StepRecord, simulate)
from repro.serving.traces import (FleetTraceConfig, TenantConfig,
                                  TraceConfig, make_fleet_trace,
                                  make_trace, mix)


@pytest.fixture(scope="module")
def setup():
    return ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4)


@pytest.fixture(scope="module")
def fleet_trace():
    return make_fleet_trace(FleetTraceConfig(tenants=(
        TenantConfig(name="chat",
                     trace=TraceConfig(arrival="poisson", rate=4.0,
                                       shape_mix=mix(("chat", 1.0))),
                     ttft_slo_s=1.5, diurnal_amp=0.4),
        TenantConfig(name="gen",
                     trace=TraceConfig(arrival="mmpp", rate=2.0,
                                       shape_mix=mix(("generate", 1.0))),
                     ttft_slo_s=4.0, flash_crowds=1, flash_mult=3.0,
                     flash_dur_s=8.0),
    ), horizon_s=40.0, seed=17))


# --------------------------------------------------------- cost closures
@pytest.mark.parametrize("arch", ["llama3.1-8b", "qwen2.5-32b",
                                  "phi3.5-moe-42b-a6.6b", "xlstm-125m"])
def test_decode_time_fn_matches_scalar_reference(arch):
    s = ServingSetup(cfg=get_config(arch), hw=TPU_V5E, chips=4)
    fn = decode_time_fn(s)
    rng = np.random.default_rng(0)
    for _ in range(25):
        bb = int(rng.integers(1, 96))
        ctxs = rng.integers(1, 4096, bb)
        ref = decode_step_time_group(s, ctxs)
        got = float(fn(np.array([bb]), np.array([float(ctxs.sum())]))[0])
        assert got == pytest.approx(ref, rel=1e-12)
    assert float(fn(np.array([0]), np.array([0.0]))[0]) == 0.0


@pytest.mark.parametrize("arch", ["llama3.1-8b", "phi3.5-moe-42b-a6.6b"])
def test_prefill_time_fn_matches_scalar_reference(arch):
    s = ServingSetup(cfg=get_config(arch), hw=TPU_V5E, chips=4)
    fn = prefill_time_fn(s)
    rng = np.random.default_rng(1)
    for _ in range(25):
        lens = rng.integers(16, 4096, int(rng.integers(1, 9)))
        ref = prefill_step_time(s, lens)
        tok = float(lens.sum())
        sq = float((lens.astype(np.float64) ** 2).sum())
        # scalar fast path and the array path agree with the reference
        assert fn(tok, sq) == pytest.approx(ref, rel=1e-12)
        got = float(fn(np.array([tok]), np.array([sq]))[0])
        assert got == pytest.approx(ref, rel=1e-12)
    assert fn(0.0, 0.0) == 0.0


# --------------------------------------------------------------- engine
def test_fleet_engine_deterministic(setup, fleet_trace):
    cfg = SimConfig(setup=setup, batch_cap=32, n_replicas=2, bucket_s=0.25)
    a = simulate(fleet_trace, cfg, engine="fleet")
    b = simulate(fleet_trace, cfg, engine="fleet")
    assert a.req["done_s"].tobytes() == b.req["done_s"].tobytes()
    assert a.req["first_token_s"].tobytes() \
        == b.req["first_token_s"].tobytes()
    assert a.n_events == b.n_events and a.sim_end_s == b.sim_end_s
    assert_sim_invariants(a, fleet_trace)


def test_fleet_engine_unknown_backend_raises(setup, fleet_trace):
    cfg = SimConfig(setup=setup, traj_backend="torch")
    with pytest.raises(KeyError):
        simulate(fleet_trace, cfg, engine="fleet")
    with pytest.raises(KeyError):
        simulate(fleet_trace, SimConfig(setup=setup), engine="warp")


def test_lazy_records_match_arrays(setup, fleet_trace):
    cfg = SimConfig(setup=setup, batch_cap=32, n_replicas=2, bucket_s=0.25)
    res = simulate(fleet_trace, cfg, engine="fleet")
    assert len(res.records) == len(fleet_trace)
    r7 = res.records[7]
    assert isinstance(r7, RequestRecord)
    assert r7.rid == int(res.req["rid"][7])
    assert r7.tenant == str(res.req["tenant"][7])
    assert isinstance(res.steps[0], StepRecord)
    assert res.steps[-1].t_end <= res.sim_end_s + 1e-9
    # steps arrive time-sorted like the heap engine's log
    t = np.array([s.t_end for s in res.steps[:200]])
    assert (np.diff(t) >= 0).all()
    # slicing and iteration work through the lazy sequence
    assert [r.rid for r in res.records[:3]] == [0, 1, 2]


def test_per_tenant_and_meta_metrics(setup, fleet_trace):
    cfg = SimConfig(setup=setup, batch_cap=32, n_replicas=2, bucket_s=0.25)
    res = simulate(fleet_trace, cfg, engine="fleet")
    slo = fleet_trace.fleet_config.slo_map
    assert_per_tenant_consistent(res, slo_map=slo)
    per = res.per_tenant(slo_map=slo)
    assert set(per) == {"chat", "gen"}
    assert per["chat"]["ttft_slo_s"] == 1.5
    meta = res.meta_metrics(slo_map=slo)
    for key in ("fleet_attainment", "jain_fairness", "goodput_tok_s",
                "shed_rate", "retry_rate", "availability"):
        assert np.isfinite(meta[key])
    # and the heap engine produces the same metric *shape*
    href = simulate(fleet_trace, cfg, engine="heap")
    hmeta = href.meta_metrics(slo_map=slo)
    assert set(hmeta) == set(meta)
    assert set(hmeta["per_tenant"]) == set(meta["per_tenant"])


def test_fleet_engine_with_autoscaler_policy(setup):
    """Control ticks, provisioning, and draining through the vectorized
    engine with a static policy forcing a mid-run scale-up."""

    class Step:
        def __init__(self):
            self.t = []

        def control(self, obs):
            self.t.append(obs.now)
            from repro.serving.simulator import Action
            n = 1 if obs.now < 10.0 else 3
            return Action(n_replicas=n, batch_cap=obs.batch_cap)

    tr = make_trace(TraceConfig(arrival="poisson", rate=6.0,
                                horizon_s=30.0, seed=2))
    cfg = SimConfig(setup=setup, batch_cap=32, n_replicas=1,
                    max_replicas=4, control_interval_s=2.0,
                    provision_delay_s=1.0, bucket_s=0.25)
    pol = Step()
    res = simulate(tr, cfg, engine="fleet")
    res2 = simulate(tr, cfg, pol, engine="fleet")
    assert_sim_invariants(res2, tr)
    assert len(res2.controls) == len(pol.t) > 5
    # the scale-up must reduce latency vs the single-replica run
    assert res2.ttft_percentile(95.0) <= res.ttft_percentile(95.0) + 1e-9
    reps = {r.replica for r in res2.records if r.replica >= 0}
    assert len(reps) >= 2                 # provisioned replicas served


def test_zero_window_control_tick_guard(setup):
    """A control tick whose window collapsed to ~zero width must hold
    the fleet instead of dividing by the window length."""
    from repro.core.ala import ALA
    from repro.serving.simulator import Observation
    asc = ALAAutoscaler(ala=ALA.__new__(ALA), min_replicas=1,
                        max_replicas=8)
    obs = Observation(now=5.0, window_s=0.0, n_arrivals=9, mean_ii=64.0,
                      mean_oo=32.0, arrival_rate=float("inf"),
                      queue_len=3, n_running=4, n_active_replicas=2,
                      batch_cap=16, decode_tokens=100, busy_s=1.0,
                      measured_tok_s=100.0)
    act = asc.control(obs)
    assert act.n_replicas == 2 and act.batch_cap == 16
    assert asc.degradations and asc.degradations[-1][1] == "zero_window"


# --------------------------------------------------------- jax backend
def test_jax_traj_backend_parity(setup, fleet_trace):
    jax = pytest.importorskip("jax")
    del jax
    cfg_np = SimConfig(setup=setup, batch_cap=32, n_replicas=2,
                       bucket_s=0.25)
    cfg_jx = SimConfig(setup=setup, batch_cap=32, n_replicas=2,
                       bucket_s=0.25, traj_backend="jax")
    a = simulate(fleet_trace, cfg_np, engine="fleet")
    b = simulate(fleet_trace, cfg_jx, engine="fleet")
    assert_sim_invariants(b, fleet_trace)
    assert a.accounting() == b.accounting()
    # float32 trajectory math: loose per-request agreement
    da = a.req["done_s"]
    db = b.req["done_s"]
    m = np.isfinite(da) & np.isfinite(db)
    assert m.mean() > 0.99
    assert np.abs(da[m] - db[m]).max() < 0.5


# ------------------------------------------------------- adapter fast path
def test_adapter_fast_path_matches_slow_path(setup, fleet_trace):
    cfg = SimConfig(setup=setup, batch_cap=32, n_replicas=2, bucket_s=0.25)
    res = simulate(fleet_trace, cfg, engine="fleet")
    n_win = max(int(np.ceil(res.sim_end_s / 5.0)), 1)
    fast = adapter._accumulate_fast(res, 5.0, n_win)
    slow = adapter._accumulate_slow(res, 5.0, n_win)
    for a, b in zip(fast, slow):
        np.testing.assert_allclose(np.asarray(a, float),
                                   np.asarray(b, float),
                                   rtol=1e-9, atol=1e-9)
    ws = adapter.summarize_windows(res, 5.0)
    assert ws and all(w.t1 > w.t0 for w in ws)
    # heap result (no raw arrays) matches through the slow path
    href = simulate(fleet_trace, cfg, engine="heap")
    hws = adapter.summarize_windows(href, 5.0)
    assert len(hws) == len(ws)
    for a, b in zip(ws, hws):
        assert a.ii == b.ii and a.oo == b.oo
        assert a.thpt == pytest.approx(b.thpt, rel=0.1)


def test_summarize_windows_zero_duration_guard():
    """Regression: a degenerate run that ends at t=0 used to emit a
    zero-duration window (t0 == t1 == 0) that poisons downstream rate
    math; now every emitted window has positive duration and a fully
    degenerate run yields no windows at all."""
    rec = RequestRecord(rid=0, ii=8, oo=4, arrival_s=0.0,
                        first_token_s=0.0, done_s=0.0)
    steps = [StepRecord(t_end=0.0, replica=0, kind="decode", bb=2,
                        duration_s=1.0, tokens_out=2)]
    res = SimResult(records=[rec, rec], steps=steps, sim_end_s=0.0,
                    n_events=3, replica_seconds=0.0, controls=[])
    assert adapter.summarize_windows(res, 5.0, min_completions=1) == []
    with pytest.raises(ValueError):
        adapter.summarize_windows(res, 0.0)
