"""Differential parity: vectorized fleet engine vs the event-heap
reference on identical seeded traces.

The fleet engine's only semantic divergence is bucketed admission
(arrivals quantized to ``bucket_s`` boundaries), so per-request metrics
must agree within a documented tolerance: roughly one bucket plus one
step time for the typical request, with a small outlier allowance for
load-tie routing flips (two requests arriving within one bucket can
swap replicas; their individual latencies swap with them, and under
congestion the swap perturbs the convoy behind it).  Both engines must
pass request conservation and the shared invariant suite on every
scenario.
"""
import numpy as np
import pytest

from _sim_invariants import assert_sim_invariants
from repro.configs import get_config
from repro.perfmodel.simulator import ServingSetup
from repro.perfmodel.hardware import TPU_V5E, profile
from repro.serving.faults import FaultConfig, injector
from repro.serving.simulator import SimConfig, simulate
from repro.serving.traces import (FleetTraceConfig, TenantConfig,
                                  TraceConfig, make_fleet_trace,
                                  make_trace, mix)

BUCKET_S = 0.1


@pytest.fixture(scope="module")
def setup():
    return ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4)


def _pair(trace, setup, **kw):
    """Run both engines on one trace; fault injectors are stateless
    reads of the plan, but build one per run to rule out shared state."""

    def cfg():
        k = dict(kw)
        if "fault_cfg" in k:
            k["faults"] = injector(k.pop("fault_cfg"))
        return SimConfig(setup=setup, bucket_s=BUCKET_S, **k)

    return (simulate(trace, cfg(), engine="heap"),
            simulate(trace, cfg(), engine="fleet"))


def _deltas(h, f):
    hv = {r.rid: r for r in h.records}
    fv = {r.rid: r for r in f.records}
    assert set(hv) == set(fv)
    ttft, tpot, e2e = [], [], []
    for k, hr in hv.items():
        fr = fv[k]
        assert hr.shed == fr.shed, f"shed flag mismatch on rid {k}"
        assert hr.ii == fr.ii and hr.oo == fr.oo
        if hr.first_token_s is not None and fr.first_token_s is not None:
            ttft.append(abs(fr.first_token_s - hr.first_token_s))
        if hr.done_s is not None and fr.done_s is not None:
            e2e.append(abs(fr.done_s - hr.done_s))
            if hr.oo > 1:
                ht = (hr.done_s - hr.first_token_s) / (hr.oo - 1)
                ft = (fr.done_s - fr.first_token_s) / (fr.oo - 1)
                tpot.append(abs(ft - ht))
    return np.asarray(ttft), np.asarray(tpot), np.asarray(e2e)


def _assert_close(h, f, p95_s=0.35, outlier_s=6.0, outlier_frac=0.05):
    """Documented tolerance contract: the bulk of requests within one
    bucket + a couple of step times; a bounded fraction of tie-flip /
    convoy outliers; nothing unbounded."""
    ttft, tpot, e2e = _deltas(h, f)
    for name, d in (("ttft", ttft), ("e2e", e2e)):
        assert len(d), f"no comparable {name} values"
        assert np.percentile(d, 95) <= p95_s, \
            f"{name} p95 delta {np.percentile(d, 95):.3f}s > {p95_s}s"
        assert d.max() <= outlier_s, \
            f"{name} max delta {d.max():.3f}s > {outlier_s}s"
        assert np.mean(d > p95_s) <= outlier_frac
    if len(tpot):
        assert np.percentile(tpot, 95) <= 0.05


def test_parity_plain(setup):
    tr = make_trace(TraceConfig(arrival="poisson", rate=6.0,
                                horizon_s=60.0, seed=3))
    h, f = _pair(tr, setup, batch_cap=32, n_replicas=2)
    assert_sim_invariants(h, tr)
    assert_sim_invariants(f, tr)
    assert h.accounting() == f.accounting()
    _assert_close(h, f)
    # same simulated span and event count, within bucket slack
    assert abs(h.sim_end_s - f.sim_end_s) < 1.0
    assert abs(h.n_events - f.n_events) / h.n_events < 0.01


def test_parity_bursty_multireplica(setup):
    tr = make_trace(TraceConfig(arrival="mmpp", rate=5.0, horizon_s=45.0,
                                seed=9))
    h, f = _pair(tr, setup, batch_cap=24, n_replicas=3)
    assert_sim_invariants(h, tr)
    assert_sim_invariants(f, tr)
    assert h.accounting() == f.accounting()
    _assert_close(h, f)


def test_parity_kv_throttled(setup):
    """Tight KV budget: admission stalls + head-of-line blocking.
    Congestion amplifies the bucket offset through queueing, so the
    contract here is looser in the tail but the bulk must still agree
    and shed decisions must match exactly."""
    tr = make_trace(TraceConfig(arrival="poisson", rate=8.0,
                                horizon_s=40.0, seed=11,
                                shape_mix=mix(("summarize", 1.0))))
    h, f = _pair(tr, setup, batch_cap=48, n_replicas=2,
                 kv_capacity_override=9000.0)
    assert_sim_invariants(h, tr)
    assert_sim_invariants(f, tr)
    assert h.accounting() == f.accounting()
    _assert_close(h, f, p95_s=3.0, outlier_s=10.0, outlier_frac=0.15)


def test_parity_oversized_shed(setup):
    """Requests larger than the KV budget shed identically (same rids,
    same reason) — bucketing cannot change an admission-time shed."""
    tr = make_trace(TraceConfig(arrival="poisson", rate=4.0,
                                horizon_s=20.0, seed=5,
                                shape_mix=mix(("summarize", 1.0),
                                              ("chat", 1.0))))
    h, f = _pair(tr, setup, batch_cap=16, n_replicas=2,
                 kv_capacity_override=2500.0)
    hs = {r.rid: r.shed_reason for r in h.records if r.shed}
    fs = {r.rid: r.shed_reason for r in f.records if r.shed}
    assert {k: v for k, v in hs.items() if v == "oversized"} \
        == {k: v for k, v in fs.items() if v == "oversized"}
    assert_sim_invariants(h, tr)
    assert_sim_invariants(f, tr)


FAULTY = FaultConfig(seed=5, horizon_s=60.0, n_replicas=3, mttf_s=25.0,
                     mttr_s=4.0, restart_warmup_s=1.0,
                     straggler_rate_hz=0.02, straggler_dur_s=6.0,
                     straggler_slow=3.0)


def test_parity_fault_plan(setup):
    """Crashes, restart warmup, and straggler windows.  The heap engine
    waits for stale-incarnation steps to drain before its final clock
    reading while the fleet engine discards them at the crash, so exact
    sim-end/event-count parity is out of scope; per-request metrics,
    retry/shed decisions, and availability must still agree."""
    tr = make_trace(TraceConfig(arrival="poisson", rate=6.0,
                                horizon_s=60.0, seed=7))
    h, f = _pair(tr, setup, batch_cap=32, n_replicas=3,
                 fault_cfg=FAULTY, max_retries=2, shed_after_s=30.0)
    assert_sim_invariants(h, tr)
    assert_sim_invariants(f, tr)
    assert h.accounting() == f.accounting()
    _assert_close(h, f, p95_s=2.0, outlier_s=10.0, outlier_frac=0.10)
    # crash/restore timelines are plan-driven and must match exactly
    assert [(e.t, e.kind, e.replica) for e in h.fault_log] \
        == [(e.t, e.kind, e.replica) for e in f.fault_log]
    assert abs(h.availability - f.availability) < 0.1
    assert abs(h.n_retries - f.n_retries) <= 5


def test_parity_multitenant_fleet_trace(setup):
    """Multi-tenant trace through both engines: per-tenant attainment
    splits agree within the latency tolerance."""
    fcfg = FleetTraceConfig(tenants=(
        TenantConfig(name="chat",
                     trace=TraceConfig(arrival="poisson", rate=4.0,
                                       shape_mix=mix(("chat", 1.0))),
                     ttft_slo_s=1.5, diurnal_amp=0.5),
        TenantConfig(name="gen",
                     trace=TraceConfig(arrival="gamma", rate=2.0,
                                       shape_mix=mix(("generate", 1.0))),
                     ttft_slo_s=4.0, flash_crowds=1, flash_mult=3.0,
                     flash_dur_s=8.0),
    ), horizon_s=45.0, seed=21)
    tr = make_fleet_trace(fcfg)
    h, f = _pair(tr, setup, batch_cap=32, n_replicas=2)
    assert_sim_invariants(h, tr)
    assert_sim_invariants(f, tr)
    _assert_close(h, f)
    hp = h.per_tenant(slo_map=fcfg.slo_map)
    fp = f.per_tenant(slo_map=fcfg.slo_map)
    assert set(hp) == set(fp) == set(tr.tenants)
    for name in hp:
        assert hp[name]["n_requests"] == fp[name]["n_requests"]
        assert abs(hp[name]["attainment"] - fp[name]["attainment"]) <= 0.05
        assert abs(hp[name]["goodput_share"]
                   - fp[name]["goodput_share"]) <= 0.02


def test_parity_mixed_hardware_fleet(setup):
    """Heterogeneous fleet (TPU v5e + GPU L4 slots): the engines must
    agree on which hardware every replica runs and on per-request
    metrics.  A load-tie flip now swaps a request between *dissimilar*
    replicas, so the flip perturbation is larger than in homogeneous
    scenarios — the contract here matches the congested kv-throttled
    tier, with shed decisions still exact."""
    l4 = ServingSetup(cfg=get_config("llama3.1-8b"),
                      hw=profile("gpu-l4"), chips=4)
    tr = make_trace(TraceConfig(arrival="poisson", rate=5.0,
                                horizon_s=45.0, seed=19))
    h, f = _pair(tr, setup, batch_cap=32, n_replicas=2,
                 replica_setups=(setup, l4))
    assert_sim_invariants(h, tr)
    assert_sim_invariants(f, tr)
    assert h.accounting() == f.accounting()
    assert h.replica_hw == f.replica_hw
    assert set(h.replica_hw.values()) == {"tpu-v5e", "gpu-l4"}
    _assert_close(h, f, p95_s=3.0, outlier_s=12.0, outlier_frac=0.15)


def test_parity_tightens_with_bucket(setup):
    """Halving the bucket must not widen the typical-request gap — the
    documented tolerance really is driven by bucket quantization."""
    tr = make_trace(TraceConfig(arrival="poisson", rate=6.0,
                                horizon_s=30.0, seed=13))

    def run(b):
        cfg = SimConfig(setup=ServingSetup(cfg=get_config("llama3.1-8b"),
                                           hw=TPU_V5E, chips=4),
                        batch_cap=32, n_replicas=2, bucket_s=b)
        return simulate(tr, cfg, engine="fleet")

    h = simulate(tr, SimConfig(setup=ServingSetup(
        cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4),
        batch_cap=32, n_replicas=2), engine="heap")
    p95 = {}
    for b in (0.4, 0.1):
        ttft, _, _ = _deltas(h, run(b))
        p95[b] = np.percentile(ttft, 95)
    assert p95[0.1] <= p95[0.4] + 1e-6
