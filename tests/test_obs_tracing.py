"""Span tracing: column derivation from both engines, heap-vs-fleet
span parity, deterministic hash sampling, ring caps with lossless step
totals, and chrome-trace well-formedness.  The strict <5% overhead gate
at full sampling runs full-size in ``benchmarks/run.py obs_engine``."""
import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.obs import ObsConfig
from repro.obs.export import chrome_trace, spans_to_dicts, write_jsonl
from repro.obs.tracing import (_keep_mask, queue_depth_series, record_spans,
                               span_hists, span_stats)
from repro.perfmodel.simulator import ServingSetup
from repro.perfmodel.hardware import TPU_V5E
from repro.serving.simulator import SimConfig, simulate
from repro.serving.traces import (FleetTraceConfig, TenantConfig,
                                  TraceConfig, make_fleet_trace,
                                  make_trace, mix)

BUCKET_S = 0.1


@pytest.fixture(scope="module")
def setup():
    return ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4)


@pytest.fixture(scope="module")
def fleet_trace():
    return make_fleet_trace(FleetTraceConfig(tenants=(
        TenantConfig(name="chat",
                     trace=TraceConfig(arrival="poisson", rate=6.0,
                                       shape_mix=mix(("chat", 1.0))),
                     ttft_slo_s=1.5),
        TenantConfig(name="generate",
                     trace=TraceConfig(arrival="mmpp", rate=3.0,
                                       burst_rate=8.0,
                                       shape_mix=mix(("generate", 1.0))),
                     ttft_slo_s=4.0),
    ), horizon_s=30.0, seed=7))


def _cfg(setup, **kw):
    kw.setdefault("obs", ObsConfig())
    return SimConfig(setup=setup, bucket_s=BUCKET_S, n_replicas=2,
                     batch_cap=32, **kw)


# -- span derivation ---------------------------------------------------------

def test_heap_spans_match_records(setup, fleet_trace):
    res = simulate(fleet_trace, _cfg(setup), engine="heap")
    t = res.spans
    assert t is not None and t.n == len(res.records)
    recs = {r.rid: r for r in res.records}
    for i in range(t.n):
        r = recs[int(t.rid[i])]
        assert str(t.tenant[i]) == r.tenant
        assert int(t.oo[i]) == r.oo
        assert bool(t.shed[i]) == r.shed
        if r.first_token_s is None:
            assert np.isnan(t.first_token_s[i])
        else:
            assert t.first_token_s[i] == pytest.approx(r.first_token_s)
    ttft = t.ttft_s()
    assert np.isinf(ttft[t.shed]).all()        # miss convention


def test_span_parity_heap_vs_fleet(setup, fleet_trace):
    h = simulate(fleet_trace, _cfg(setup), engine="heap")
    f = simulate(fleet_trace, _cfg(setup), engine="fleet")
    sh, sf = span_stats(h.spans), span_stats(f.spans)
    for k in ("n_spans", "n_source", "n_completed", "n_shed",
              "n_retries", "out_tokens", "shed_by_reason"):
        assert sh[k] == sf[k], (k, sh[k], sf[k])
    # fleet admissions quantize to bucket boundaries
    for k, tol in (("ttft_p50_s", BUCKET_S + 0.35),
                   ("ttft_p95_s", BUCKET_S + 1.0),
                   ("e2e_p50_s", BUCKET_S + 0.35)):
        if np.isfinite(sh[k]) or np.isfinite(sf[k]):
            assert abs(sh[k] - sf[k]) <= tol, (k, sh[k], sf[k])


def test_sampling_deterministic_and_engine_independent(setup, fleet_trace):
    obs = ObsConfig(sample_rate=0.4, sample_seed=3)
    h = simulate(fleet_trace, _cfg(setup, obs=obs), engine="heap")
    f = simulate(fleet_trace, _cfg(setup, obs=obs), engine="fleet")
    assert set(h.spans.rid.tolist()) == set(f.spans.rid.tolist())
    assert h.spans.n_source == len(fleet_trace)
    assert 0 < h.spans.n < len(fleet_trace)
    # a different seed keeps a different subset
    g = record_spans(h, ObsConfig(sample_rate=0.4, sample_seed=4))
    assert set(g.rid.tolist()) != set(h.spans.rid.tolist())


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rate=st.floats(0.05, 0.95))
def test_keep_mask_rate_property(seed, rate):
    rid = np.arange(4000, dtype=np.int64)
    m = _keep_mask(rid, rate, seed)
    assert abs(m.mean() - rate) < 0.05
    np.testing.assert_array_equal(
        m, _keep_mask(rid[::-1], rate, seed)[::-1])   # order independent


def test_obs_disabled_or_absent_records_no_spans(setup, fleet_trace):
    res = simulate(fleet_trace,
                   SimConfig(setup=setup, bucket_s=BUCKET_S, n_replicas=2),
                   engine="fleet")
    assert res.spans is None and res.steps_dropped == 0
    res = simulate(fleet_trace,
                   _cfg(setup, obs=ObsConfig(enabled=False)),
                   engine="fleet")
    assert res.spans is None


# -- ring caps + lossless totals ---------------------------------------------

@pytest.mark.parametrize("engine", ["heap", "fleet"])
def test_step_ring_cap_lossless_totals(setup, fleet_trace, engine):
    full = simulate(fleet_trace, _cfg(setup), engine=engine)
    capped = simulate(
        fleet_trace, _cfg(setup, obs=ObsConfig(max_steps=100,
                                               max_fault_events=50)),
        engine=engine)
    assert len(capped.steps) == 100
    assert capped.steps_dropped == len(full.steps) - 100
    # totals survive the drop — accounting never truncates
    assert capped.step_totals == full.step_totals
    assert full.step_totals["n"] == len(full.steps)
    assert full.step_totals["busy_s"] == pytest.approx(
        sum(s.duration_s for s in full.steps))
    # the retained window is the most recent steps
    assert capped.steps[-1].t_end == pytest.approx(full.steps[-1].t_end)
    # per-request outcomes are untouched by telemetry caps
    assert span_stats(capped.spans) == span_stats(full.spans)


# -- derived views -----------------------------------------------------------

def test_span_hists_shards_merge_to_fleet_view(setup, fleet_trace):
    res = simulate(fleet_trace, _cfg(setup), engine="fleet")
    t = res.spans
    from repro.obs.metrics import StreamHist, percentile_with_inf
    shards = span_hists(t, n_bins=32, by=t.tenant)
    assert set(shards) == {"chat", "generate"}
    merged = StreamHist.merged(shards.values())
    assert merged.total == t.n
    ttft = t.ttft_s()
    assert np.isfinite(merged.quantile(50.0)) \
        == np.isfinite(percentile_with_inf(ttft, 50.0))


def test_queue_depth_series_bounds(setup, fleet_trace):
    res = simulate(fleet_trace, _cfg(setup), engine="fleet")
    qd = queue_depth_series(res.spans, bucket_s=0.5,
                            t_end=res.sim_end_s)
    assert (qd["depth"] >= 0).all()
    assert len(qd["t_s"]) == len(qd["depth"])
    assert qd["depth"].max() <= res.spans.n


# -- export ------------------------------------------------------------------

def test_chrome_trace_well_formed(setup, fleet_trace, tmp_path):
    res = simulate(fleet_trace, _cfg(setup), engine="fleet")
    doc = chrome_trace(res, max_step_events=500, max_span_events=100)
    evs = doc["traceEvents"]
    assert evs and doc["displayTimeUnit"] == "ms"
    assert doc["metadata"]["n_steps_emitted"] <= 500
    assert doc["metadata"]["n_spans_total"] == res.spans.n
    phases = {e["ph"] for e in evs}
    assert {"X", "M", "b", "e"} <= phases
    for e in evs:
        assert "ph" in e and "pid" in e
        if e["ph"] != "M":
            assert np.isfinite(e["ts"]) and e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 1.0             # >= 1us, renderable
    # async begin/end pairs balance per id
    b = sorted(e["id"] for e in evs if e["ph"] == "b")
    ee = sorted(e["id"] for e in evs if e["ph"] == "e")
    assert b == ee
    json.dumps(doc)                            # serializable as-is


def test_spans_jsonl_roundtrip(setup, fleet_trace, tmp_path):
    res = simulate(fleet_trace, _cfg(setup), engine="fleet")
    dicts = spans_to_dicts(res.spans)
    path = tmp_path / "spans.jsonl"
    assert write_jsonl(dicts, path) == res.spans.n
    back = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert len(back) == res.spans.n
    assert {d["rid"] for d in back} == set(res.spans.rid.tolist())
    for d in back:
        if d["shed"]:
            assert "shed_reason" in d and "done_s" not in d
