"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle across
shape/dtype sweeps, plus hypothesis property tests on kernel invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.gbt_hist import ops as gh_ops
from repro.kernels.gbt_hist.ref import gbt_hist_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- rmsnorm --
@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (1, 256), (17, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_matches_ref(shape, dtype):
    key = jax.random.key(0)
    x = jax.random.normal(key, shape, dtype)
    scale = jax.random.normal(jax.random.key(1), shape[-1:], jnp.float32)
    got = rms_ops.rmsnorm(x, scale, force="interpret", block_rows=8)
    want = rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 33), d=st.sampled_from([32, 64, 128]))
def test_rmsnorm_property_unit_norm(rows, d):
    """RMSNorm output with unit scale has RMS ~= 1 per row."""
    x = jax.random.normal(jax.random.key(rows), (rows, d), jnp.float32) * 5.0
    out = rms_ops.rmsnorm(x, jnp.ones((d,)), force="interpret", block_rows=8)
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones(rows), rtol=1e-3)


# ---------------------------------------------------------- flash attention --
@pytest.mark.parametrize("b,h,kv,s,dh", [
    (1, 4, 4, 128, 64),     # MHA
    (2, 8, 2, 256, 64),     # GQA 4x
    (1, 4, 1, 128, 128),    # MQA
    (2, 6, 2, 64, 32),      # heads not multiple of 4
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, h, kv, s, dh, causal, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=causal, force="interpret",
                                 block_q=64, block_k=64)
    want = fa_ops.flash_attention(q, k, v, causal=causal, force="ref")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


def test_flash_attention_block_shape_sweep():
    b, s, h, kv, dh = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32)
    want = fa_ops.flash_attention(q, k, v, force="ref")
    for bq, bk in [(32, 64), (64, 32), (128, 128), (256, 64)]:
        got = fa_ops.flash_attention(q, k, v, force="interpret",
                                     block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"block {bq}x{bk}")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_flash_attention_property_convex_combination(seed):
    """Attention output rows lie in the convex hull of V rows => bounded by
    per-batch max |v|."""
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)
    out = fa_ops.flash_attention(q, k, v, force="interpret",
                                 block_q=32, block_k=32)
    assert np.all(np.abs(np.asarray(out)) <= np.abs(np.asarray(v)).max()
                  + 1e-4)


# ---------------------------------------------------------- decode attention --
@pytest.mark.parametrize("b,h,kv,t,dh", [
    (2, 8, 2, 128, 64),
    (1, 4, 4, 512, 128),
    (4, 16, 8, 256, 64),
])
@pytest.mark.parametrize("pos_frac", [0.1, 0.5, 1.0])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(b, h, kv, t, dh, pos_frac, dtype):
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (b, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, t, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, t, kv, dh), dtype)
    pos = jnp.array(int((t - 1) * pos_frac), jnp.int32)
    got = da_ops.decode_attention(q, k, v, pos, force="interpret",
                                  block_t=64)
    want = da_ops.decode_attention(q, k, v, pos, force="ref")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype))


def test_decode_attention_ignores_stale_cache():
    """Entries beyond pos must not affect the output."""
    ks = jax.random.split(jax.random.key(5), 3)
    b, h, kv, t, dh = 1, 4, 2, 128, 32
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, dh), jnp.float32)
    pos = jnp.array(63, jnp.int32)
    out1 = da_ops.decode_attention(q, k, v, pos, force="interpret",
                                   block_t=32)
    k2 = k.at[:, 64:].set(99.0)
    v2 = v.at[:, 64:].set(-99.0)
    out2 = da_ops.decode_attention(q, k2, v2, pos, force="interpret",
                                   block_t=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ------------------------------------------------------------------ gbt hist --
@pytest.mark.parametrize("n,f,n_bins", [(100, 3, 16), (512, 8, 64),
                                        (1000, 11, 32), (7, 1, 8)])
def test_gbt_hist_matches_ref(n, f, n_bins):
    rng = np.random.default_rng(0)
    bins = jnp.asarray(rng.integers(0, n_bins, (n, f)), jnp.int32)
    grad = jnp.asarray(rng.standard_normal(n), jnp.float32)
    hess = jnp.asarray(rng.random(n), jnp.float32)
    got = gh_ops.build_histograms(bins, grad, hess, n_bins=n_bins,
                                  force="interpret", block_n=64, block_f=4)
    want = gbt_hist_ref(bins, grad, hess, n_bins)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), n=st.integers(1, 300),
       n_bins=st.sampled_from([8, 16, 32]))
def test_gbt_hist_property_mass_conservation(seed, n, n_bins):
    """Sum over bins equals the total gradient/hessian mass per feature."""
    rng = np.random.default_rng(seed)
    f = 3
    bins = jnp.asarray(rng.integers(0, n_bins, (n, f)), jnp.int32)
    grad = jnp.asarray(rng.standard_normal(n), jnp.float32)
    hess = jnp.asarray(rng.random(n), jnp.float32)
    hist = gh_ops.build_histograms(bins, grad, hess, n_bins=n_bins,
                                   force="interpret", block_n=64, block_f=4)
    total = np.asarray(hist).sum(axis=1)   # (f, 2)
    np.testing.assert_allclose(total[:, 0], float(grad.sum()) * np.ones(f),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(total[:, 1], float(hess.sum()) * np.ones(f),
                               rtol=1e-4, atol=1e-4)
