"""Fault-injection layer: deterministic fault plans, crash/retry/shed
request conservation, straggler slowdowns, graceful autoscaler
degradation (backoff + scale-down hysteresis), and the shed-aware
metric consistency between ``slo_attainment`` and ``ttft_percentile``.
"""
import numpy as np
import pytest

from _sim_invariants import assert_sim_invariants
from repro.configs import get_config
from repro.core.dataset import Dataset
from repro.perfmodel.simulator import ServingSetup
from repro.perfmodel.hardware import TPU_V5E
from repro.serving import adapter
from repro.serving.adapter import WindowSummary, windows_to_dataset
from repro.serving.autoscaler import ALAAutoscaler
from repro.serving.faults import (CrashWindow, FaultConfig, FaultInjector,
                                  FaultPlan, StragglerWindow, injector)
from repro.serving.simulator import (Observation, RequestRecord, SimConfig,
                                     SimResult, simulate)
from repro.serving.traces import TraceConfig, make_trace


@pytest.fixture(scope="module")
def setup():
    return ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4)


@pytest.fixture(scope="module")
def trace():
    return make_trace(TraceConfig(arrival="poisson", rate=6.0,
                                  horizon_s=15.0, seed=3))


CRASHY = FaultConfig(seed=11, horizon_s=15.0, n_replicas=3, mttf_s=5.0,
                     mttr_s=2.0, restart_warmup_s=0.5)


@pytest.fixture(scope="module")
def crash_results(setup, trace):
    cfg = lambda: SimConfig(setup=setup, n_replicas=3, faults=injector(  # noqa: E731
        CRASHY), max_retries=2, shed_after_s=20.0)
    return simulate(trace, cfg()), simulate(trace, cfg())


# ------------------------------------------------------------- fault plans
def test_fault_plan_deterministic_and_seed_sensitive():
    cfg = FaultConfig(seed=3, horizon_s=60.0, n_replicas=4, mttf_s=20.0,
                      mttr_s=4.0, straggler_rate_hz=0.02)
    a, b = FaultPlan.build(cfg), FaultPlan.build(cfg)
    assert a == b and a.fingerprint() == b.fingerprint()
    assert a.crashes and a.stragglers
    other = FaultPlan.build(FaultConfig(seed=4, horizon_s=60.0,
                                        n_replicas=4, mttf_s=20.0,
                                        mttr_s=4.0,
                                        straggler_rate_hz=0.02))
    assert other.fingerprint() != a.fingerprint()
    # windows are well-formed: positive spans inside (or started in) the
    # horizon, replica ids within the plan's fleet
    for w in a.crashes:
        assert 0 <= w.replica < 4 and 0.0 <= w.t_down < 60.0
        assert w.t_up > w.t_down
    quiet = FaultPlan.build(FaultConfig(seed=3, horizon_s=60.0))
    assert not quiet.crashes and not quiet.stragglers


def test_corrupt_rows_deterministic_and_accounted():
    cfg = FaultConfig(seed=5, drop_p=0.1, dup_p=0.1, poison_nan_p=0.1,
                      poison_scale_p=0.1)
    rows = [dict(ii=128, oo=64, bb=8, thpt=1000.0 + i) for i in range(200)]
    out1, rep1 = injector(cfg).corrupt_rows(rows)
    out2, rep2 = injector(cfg).corrupt_rows(rows)
    assert repr(out1) == repr(out2)         # same plan -> same corruption
    assert repr(rep1) == repr(rep2)         # (repr: NaN poison != itself)
    # every input row is exactly one of dropped/duplicated/poisoned/clean
    assert rep1.n_in == len(rows)
    assert len(out1) == rep1.n_in - rep1.n_dropped + rep1.n_duplicated
    assert len(rep1.clean_rows) == rep1.n_in - rep1.n_dropped \
        - rep1.n_poisoned
    assert rep1.n_dropped and rep1.n_duplicated and rep1.n_poisoned
    # poisoned rows really are poisoned: non-finite or wildly scaled
    bad = [r for r in out1 if r not in rep1.clean_rows]
    assert any(not np.isfinite(r["thpt"]) for r in bad)


# ------------------------------------------------- crash/retry conservation
def test_crash_sim_conservation_and_availability(crash_results):
    res, _ = crash_results
    assert_sim_invariants(res)
    acc = res.accounting()
    assert acc["admitted"] == acc["completed"] + acc["shed"]
    assert res.n_retries > 0                # crashes displaced work
    assert 0.0 < res.availability < 1.0
    kinds = {e.kind for e in res.fault_log}
    assert kinds == {"crash", "restore"}
    crashes = [e for e in res.fault_log if e.kind == "crash"]
    assert any(e.n_displaced > 0 for e in crashes)


def test_fault_timeline_bit_identical(crash_results):
    a, b = crash_results
    assert [r.done_s for r in a.records] == [r.done_s for r in b.records]
    assert [r.retries for r in a.records] == [r.retries for r in b.records]
    assert [(e.t, e.kind, e.replica, e.n_displaced) for e in a.fault_log] \
        == [(e.t, e.kind, e.replica, e.n_displaced) for e in b.fault_log]


def test_no_faults_is_the_old_simulator(setup, trace):
    res = simulate(trace, SimConfig(setup=setup, n_replicas=2))
    res.check_conservation()
    assert res.availability == 1.0 and not res.fault_log
    assert not res.shed and res.n_retries == 0


def test_straggler_window_slows_completion(setup, trace):
    base_cfg = FaultConfig(seed=0, horizon_s=trace.horizon_s, n_replicas=1)
    slow = FaultInjector(FaultPlan(
        cfg=base_cfg, crashes=(),
        stragglers=(StragglerWindow(replica=0, t0=0.0, t1=1e9, slow=3.0),)))
    r_slow = simulate(trace, SimConfig(setup=setup, n_replicas=1,
                                       faults=slow))
    r_base = simulate(trace, SimConfig(setup=setup, n_replicas=1))
    assert slow.slow_factor(0, 5.0) == 3.0
    assert slow.slow_factor(1, 5.0) == 1.0          # other replicas fine
    # every step ran 3x longer, so the run drains later and p95 grows
    assert r_slow.sim_end_s > r_base.sim_end_s
    assert r_slow.ttft_percentile(95) > r_base.ttft_percentile(95)


def test_retry_budget_and_deadline_shedding(setup):
    tr = make_trace(TraceConfig(arrival="poisson", rate=8.0,
                                horizon_s=6.0, seed=5))
    # replica 0 dies every 2 s and stays down 1.5 s: with a zero retry
    # budget every displaced in-flight sequence sheds immediately
    plan = FaultPlan(
        cfg=FaultConfig(seed=0, horizon_s=6.0, n_replicas=1, mttr_s=1.5),
        crashes=tuple(CrashWindow(replica=0, t_down=t, t_up=t + 1.5)
                      for t in (2.0, 4.0, 6.0)),
        stragglers=())
    res = simulate(tr, SimConfig(setup=setup, n_replicas=1,
                                 faults=FaultInjector(plan),
                                 max_retries=0, shed_after_s=8.0))
    res.check_conservation()
    assert res.shed
    reasons = {r.shed_reason for r in res.shed}
    assert reasons <= {"retry_budget", "deadline", "unserved"}
    assert "retry_budget" in reasons
    # shed requests are SLO misses in BOTH metrics (the satellite bugfix)
    assert res.slo_attainment(1e9) == pytest.approx(
        len(res.completed) / len(res.records))
    assert np.isinf(res.ttft_percentile(100.0))
    assert np.isfinite(res.ttft_percentile(95.0, on_missing="drop"))


def test_oversized_request_shed_with_reason(setup):
    tr = make_trace(TraceConfig(arrival="poisson", rate=4.0,
                                horizon_s=5.0, seed=9))
    arrs = tr.to_arrays()
    arrs["ii"][1] = 10_000
    from repro.serving.traces import Trace
    big = Trace.from_arrays(**arrs, horizon_s=tr.horizon_s)
    cap = max(r.ii + r.oo for r in big.requests if r.ii < 10_000) + 500.0
    res = simulate(big, SimConfig(setup=setup, n_replicas=1,
                                  drain_s=5000.0,
                                  kv_capacity_override=cap))
    res.check_conservation()
    oversized = [r for r in res.shed if r.shed_reason == "oversized"]
    assert len(oversized) == 1 and oversized[0].ii == 10_000


# ----------------------------------------------- metric consistency (unit)
def test_slo_and_percentile_agree_on_shed():
    done = RequestRecord(rid=0, ii=8, oo=4, arrival_s=0.0,
                         first_token_s=1.0, done_s=2.0)
    lost = RequestRecord(rid=1, ii=8, oo=4, arrival_s=0.0, shed=True,
                         shed_s=3.0, shed_reason="retry_budget")
    res = SimResult(records=[done, lost], steps=[], sim_end_s=5.0,
                    n_events=2, replica_seconds=5.0, controls=[])
    res.check_conservation()
    assert res.slo_attainment(10.0) == pytest.approx(0.5)
    assert np.isinf(res.ttft_percentile(99.0))
    assert res.ttft_percentile(99.0, on_missing="drop") \
        == pytest.approx(1.0)
    # double-counting must be caught
    lost.done_s = 4.0
    with pytest.raises(RuntimeError, match="conservation"):
        res.check_conservation()


# ------------------------------------------------- autoscaler degradation
def _obs(now, measured=1000.0, n_active=1, n_running=4):
    return Observation(now=now, window_s=2.0, n_arrivals=10, mean_ii=256.0,
                       mean_oo=128.0, arrival_rate=5.0, queue_len=0,
                       n_running=n_running, n_active_replicas=n_active,
                       batch_cap=64, decode_tokens=2000, busy_s=2.0,
                       measured_tok_s=measured)


def _pol(pred):
    pol = ALAAutoscaler(ala=None)
    pol._predict_per_replica = lambda ii, oo: pred
    pol._note_drift = lambda obs, conf: None
    return pol


def test_backoff_arms_after_sustained_unreliable_ticks():
    pol = _pol((64, float("nan"), 0.0))
    for i in range(2):
        act = pol.control(_obs(2.0 * (i + 1)))
        assert act.n_replicas >= 1
    assert not pol.degradations             # 2 ticks: not armed yet
    pol.control(_obs(6.0))
    assert [k for _, k in pol.degradations] == ["backoff"]
    assert pol._backoff_left == pol.backoff_base - 1
    # during backoff the controller sizes from measured throughput and
    # keeps the fleet's batch cap instead of re-planning off the model
    act = pol.control(_obs(8.0))
    assert act.batch_cap == 64
    assert pol.log[-1][2] is True           # fallback path
    # repeated arming doubles the hold up to the cap
    for i in range(12):
        pol.control(_obs(10.0 + 2 * i))
    assert sum(1 for _, k in pol.degradations if k == "backoff") >= 2
    assert pol._backoff_len <= pol.backoff_cap


def test_backoff_releases_on_reliable_ticks():
    pol = _pol((64, float("nan"), 0.0))
    for i in range(3):
        pol.control(_obs(2.0 * (i + 1)))
    assert pol.degradations
    pol._predict_per_replica = lambda ii, oo: (64, 5000.0, 0.9)
    for i in range(4):
        pol.control(_obs(10.0 + 2 * i))
    assert pol._unreliable_streak == 0 and pol._backoff_left == 0
    assert pol._backoff_len == 0            # healed: next arm starts small


def test_unreliable_prediction_holds_fleet_when_nothing_measured():
    pol = _pol((64, float("nan"), 0.0))
    act = pol.control(_obs(2.0, measured=0.0, n_active=3))
    assert act.n_replicas == 3              # no model, no data: hold


def test_scale_down_hysteresis_delays_shrink():
    pol = _pol((64, 1e6, 1.0))              # huge supply -> wants 1 replica
    o = lambda t: _obs(t, measured=0.0, n_active=4, n_running=0)  # noqa: E731
    act1 = pol.control(o(2.0))
    assert act1.n_replicas == 4             # held: first shrink-wanting tick
    assert ("hold_down" in [k for _, k in pol.degradations])
    act2 = pol.control(o(4.0))
    assert act2.n_replicas == 1             # patience met: shrink allowed
    # an up-or-hold tick resets the streak
    pol2 = _pol((64, 1e6, 1.0))
    pol2.control(o(2.0))
    pol2._predict_per_replica = lambda ii, oo: (64, 10.0, 1.0)
    pol2.control(o(4.0))                    # wants MORE replicas: reset
    assert pol2._down_streak == 0


# ------------------------------------------------ non-finite row validation
def test_from_rows_rejects_nonfinite_and_opt_out():
    rows = [dict(ii=128, oo=64, bb=8, thpt=1000.0),
            dict(ii=128, oo=64, bb=8, thpt=float("nan"))]
    with pytest.raises(ValueError, match=r"'thpt'.*non-finite.*row 1"):
        Dataset.from_rows(rows)
    rows[1]["thpt"] = float("inf")
    with pytest.raises(ValueError, match="non-finite"):
        Dataset.from_rows(rows)
    ds = Dataset.from_rows(rows, require_finite=None)   # corruption path
    assert len(ds) == 2 and np.isinf(ds["thpt"][1])
    # string key columns never trip the finite check
    ok = Dataset.from_rows([dict(model="m", ii=1, oo=2, bb=3, thpt=4.0)])
    assert len(ok) == 1


def test_windows_to_dataset_drops_nonfinite_with_warning(setup,
                                                         monkeypatch):
    good = WindowSummary(t0=0.0, t1=5.0, ii=256, oo=128, bb=8.0,
                         thpt=1200.0, n_completions=4)
    bad = WindowSummary(t0=5.0, t1=10.0, ii=256, oo=128, bb=8.0,
                        thpt=float("nan"), n_completions=4)
    monkeypatch.setattr(adapter, "summarize_windows",
                        lambda *a, **kw: [good, bad])
    dummy = SimResult(records=[], steps=[], sim_end_s=10.0, n_events=0,
                      replica_seconds=10.0, controls=[])
    with pytest.warns(RuntimeWarning, match="dropped 1 non-finite"):
        ds = windows_to_dataset(dummy, setup, "llama3.1-8b")
    assert len(ds) == 1 and float(ds["thpt"][0]) == pytest.approx(1200.0)
    with pytest.raises(ValueError, match="1 non-finite"):
        windows_to_dataset(dummy, setup, "llama3.1-8b",
                           on_nonfinite="raise")
    monkeypatch.setattr(adapter, "summarize_windows",
                        lambda *a, **kw: [bad])
    with pytest.warns(RuntimeWarning):
        with pytest.raises(ValueError, match="no steady-state"):
            windows_to_dataset(dummy, setup, "llama3.1-8b")
