"""ALA core: curve fitting, GBT, database, SA, error predictor,
uncertainty — unit + property tests."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.ala import ALA, ALAConfig
from repro.core.annealing import SAConfig, anneal, evaluate_subset, median_ape
from repro.core.database import build_exponential_database
from repro.core.error_predictor import encode_subset, train_error_predictor
from repro.core.expmodel import exp_model, initial_params
from repro.core.fit import fit_exponential_groups, fit_exponential_numpy
from repro.core.gbt import GBTRegressor, LinearRegression, MultiOutputGBT
from repro.core.uncertainty import confidence, workload_distance


# ------------------------------------------------------------------- fit --
@settings(max_examples=15, deadline=None)
@given(a=st.floats(10, 2000), b=st.floats(0.005, 0.5),
       c=st.floats(100, 20000), seed=st.integers(0, 100))
def test_lm_recovers_exponential_params(a, b, c, seed):
    """Noise-free exponential data must be recovered to ~1%."""
    if c <= a:  # keep thpt positive at bb=0-ish
        c = a + c
    bb = np.array([1, 2, 4, 8, 16, 32, 64, 128, 256], float)
    y = exp_model(bb, a, b, c)
    theta0 = initial_params(bb, y)
    theta = fit_exponential_groups([(bb, y, theta0)])[0]
    pred = exp_model(bb, *theta)
    err = np.max(np.abs(pred - y) / np.maximum(np.abs(y), 1e-9))
    assert err < 0.01, (theta, (a, b, c), err)


def test_lm_jax_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    bb = np.array([1, 2, 4, 8, 16, 32, 64], float)
    y = exp_model(bb, 800.0, 0.08, 1000.0) * rng.lognormal(0, 0.02, len(bb))
    theta0 = initial_params(bb, y)
    tj = fit_exponential_groups([(bb, y, theta0)])[0]
    tn = fit_exponential_numpy(bb, y, theta0)
    pj = exp_model(bb, *tj)
    pn = exp_model(bb, *tn)
    np.testing.assert_allclose(pj, pn, rtol=5e-2)


def test_fit_batched_groups_independent():
    """vmapped fit must equal per-group fits."""
    rng = np.random.default_rng(1)
    groups = []
    for i in range(5):
        bb = np.array([1, 2, 4, 8, 16, 32, 64, 128], float)
        a, b, c = 100 * (i + 1), 0.02 * (i + 1), 500 * (i + 2)
        y = exp_model(bb, a, b, c)
        groups.append((bb, y, initial_params(bb, y)))
    batch = fit_exponential_groups(groups)
    for g, th in zip(groups, batch):
        single = fit_exponential_groups([g])[0]
        np.testing.assert_allclose(exp_model(g[0], *th),
                                   exp_model(g[0], *single), rtol=1e-3)


# ------------------------------------------------------------------- gbt --
def test_gbt_fits_simple_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 10, size=(2000, 3))
    y = 3 * X[:, 0] + np.sin(X[:, 1]) * 5 + X[:, 2] ** 2
    m = GBTRegressor(n_estimators=150, learning_rate=0.1, max_depth=4)
    m.fit(X[:1500], y[:1500])
    pred = m.predict(X[1500:])
    rmse = np.sqrt(np.mean((pred - y[1500:]) ** 2))
    assert rmse < 0.15 * y.std(), rmse


def test_gbt_deterministic_given_seed():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(500, 4))
    y = X @ np.array([1.0, -2.0, 0.5, 3.0])
    p1 = GBTRegressor(seed=7, subsample=0.8).fit(X, y).predict(X[:50])
    p2 = GBTRegressor(seed=7, subsample=0.8).fit(X, y).predict(X[:50])
    np.testing.assert_array_equal(p1, p2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_gbt_training_reduces_error_property(seed):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, size=(400, 2))
    y = X[:, 0] * X[:, 1] + 0.1 * rng.normal(size=400)
    base_err = np.mean((y - y.mean()) ** 2)
    m = GBTRegressor(n_estimators=60, max_depth=3).fit(X, y)
    fit_err = np.mean((m.predict(X) - y) ** 2)
    assert fit_err < base_err


def test_multioutput_gbt_shapes():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(200, 5))
    Y = rng.normal(size=(200, 3))
    m = MultiOutputGBT(3, n_estimators=20).fit(X, Y)
    assert m.predict(X[:17]).shape == (17, 3)


def test_linear_regression_exact_on_linear_data():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(100, 3))
    y = X @ np.array([2.0, -1.0, 0.5]) + 4.0
    m = LinearRegression().fit(X, y)
    np.testing.assert_allclose(m.predict(X), y, atol=1e-8)


# -------------------------------------------------------------- database --
def _toy_workload(seed=0, noise=0.0):
    rng = np.random.default_rng(seed)
    iis, oos = [128, 512, 2048], [128, 1024]
    bbs = np.array([1, 2, 4, 8, 16, 32, 64, 128], float)
    rows = []
    for ii in iis:
        for oo in oos:
            c = 2e4 / np.log2(ii + oo)
            a, b = 0.9 * c, 0.03
            y = exp_model(bbs, a, b, c)
            if noise:
                y = y * rng.lognormal(0, noise, len(bbs))
            for bb, t in zip(bbs, y):
                rows.append((ii, oo, bb, t))
    arr = np.asarray(rows, float)
    return arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]


def test_database_covers_all_pairs():
    ii, oo, bb, thpt = _toy_workload()
    db = build_exponential_database(ii, oo, bb, thpt)
    assert len(db) == 6
    assert db.lookup(128, 1024) is not None
    assert db.lookup(999, 999) is None
    # DB predictions reproduce the generating curve
    th = db.lookup(512, 128)
    pred = exp_model(np.array([4.0, 64.0]), *th)
    truth = [exp_model(v, 0.9 * 2e4 / np.log2(640), 0.03,
                       2e4 / np.log2(640)) for v in (4.0, 64.0)]
    np.testing.assert_allclose(pred, truth, rtol=0.02)


def test_ala_db_hit_beats_ml_miss():
    """On observed pairs ALA uses exact fits; unseen pairs go through ML."""
    ii, oo, bb, thpt = _toy_workload(noise=0.01)
    ala = ALA().fit(ii, oo, bb, thpt)
    seen = ala.predict(np.array([512.0]), np.array([128.0]),
                       np.array([32.0]))[0]
    truth = exp_model(32.0, 0.9 * 2e4 / np.log2(640), 0.03,
                      2e4 / np.log2(640))
    assert abs(seen - truth) / truth < 0.05


# ------------------------------------------------------ annealing / Alg 6-8 --
def _split_toy(seed=0):
    ii, oo, bb, thpt = _toy_workload(seed=seed, noise=0.02)
    rng = np.random.default_rng(seed)
    m = rng.random(len(ii)) < 0.5
    return (ii[m], oo[m], bb[m], thpt[m]), \
        (ii[~m], oo[~m], bb[~m], thpt[~m])


def test_anneal_logs_and_improves():
    train, test = _split_toy()
    cfg = SAConfig(n_iters=20, seed=0,
                   gbt_kw=dict(n_estimators=20, learning_rate=0.2,
                               max_depth=3))
    log = anneal(train, test, cfg)
    assert len(log.errors) == 22   # init + full-coverage anchor + 20 iters
    assert log.best_error <= log.errors[0] + 1e-9
    assert all(np.isfinite(e) for e in log.errors)


def test_error_predictor_learns_subset_error_map():
    train, test = _split_toy()
    cfg = SAConfig(n_iters=40, seed=1,
                   gbt_kw=dict(n_estimators=20, learning_rate=0.2,
                               max_depth=3))
    log = anneal(train, test, cfg)
    model = train_error_predictor(log, n_estimators=80)
    X = np.stack([encode_subset(s, log.universes) for s in log.subsets])
    pred = model.predict(X)
    resid = np.abs(pred - np.asarray(log.errors))
    # in-sample fit should be much tighter than predicting the mean
    assert np.median(resid) < np.std(log.errors) + 1e-9


def test_confidence_decreases_with_distribution_shift():
    train, test = _split_toy()
    cfg = SAConfig(n_iters=15, seed=2,
                   gbt_kw=dict(n_estimators=15, learning_rate=0.2,
                               max_depth=3))
    log = anneal(train, test, cfg)
    # similar workload: the held-out half
    d_same, c_same = confidence(train, log, test)
    # shifted workload: scaled thpt (different hardware) + shifted sizes
    ii, oo, bb, thpt = test
    shifted = (ii * 7, oo * 5, bb, thpt * 0.1)
    d_shift, c_shift = confidence(train, log, shifted)
    assert c_same > c_shift, (c_same, c_shift)
    assert 0.0 <= c_shift <= c_same <= 1.0


def test_workload_distance_zero_for_identical():
    ii, oo, bb, thpt = _toy_workload()
    rows = {"ii": ii, "oo": oo, "bb": bb, "thpt": thpt}
    assert workload_distance(rows, dict(rows)) < 1e-12


def test_median_ape_basic():
    assert median_ape(np.array([100.0, 200.0]),
                      np.array([110.0, 180.0])) == 10.0
