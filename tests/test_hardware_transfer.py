"""Cross-hardware layer: descriptor distances, the deprecated ``tpu``
shim, registry transfer (RQ4), placement-aware autoscaling, and the
heterogeneous-fleet data path."""
import importlib
import pathlib
import sys

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.annealing import SAConfig
from repro.core.dataset import Dataset
from repro.core.expmodel import exp_model
from repro.core.registry import ModelRegistry
from repro.perfmodel.hardware import (PROFILES, TPU_V5E, HardwareProfile,
                                      feature_names, feature_row,
                                      hardware_distance, profile)
from repro.perfmodel.simulator import ServingSetup
from repro.serving.adapter import (windows_to_dataset,
                                   windows_to_datasets_by_hardware)
from repro.serving.autoscaler import ALAAutoscaler
from repro.serving.simulator import Action, Observation, SimConfig, simulate
from repro.serving.traces import TraceConfig, make_trace

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"


# ------------------------------------------------------------------ shim
def test_tpu_shim_warns_and_reexports():
    sys.modules.pop("repro.perfmodel.tpu", None)
    with pytest.warns(DeprecationWarning, match="repro.perfmodel.hardware"):
        shim = importlib.import_module("repro.perfmodel.tpu")
    # aliases, not copies: profile identity survives the move
    assert shim.TPU_V5E is TPU_V5E
    assert shim.PROFILES is PROFILES
    assert shim.hardware_distance is hardware_distance


def test_no_in_repo_imports_of_deprecated_shim():
    """Everything under src/ must import repro.perfmodel.hardware; the
    shim exists only for out-of-tree callers.  The old grep over src/
    is promoted into the repro-check rule engine — same guarantee, one
    mechanism, and the AST rule also catches ``importlib`` spellings
    grep could only see as strings."""
    from repro.staticcheck import RULES_BY_NAME, check_paths
    res = check_paths([SRC], rules=[RULES_BY_NAME["no-shim-import"]],
                      root=SRC.parent)
    assert res.n_files > 50, "shim sweep saw too few files"
    offenders = [f.format() for f in res.findings
                 if f.rule == "no-shim-import"]
    assert not offenders, f"deprecated tpu imports remain: {offenders}"


# ------------------------------------------------------------ descriptors
def test_profiles_registered_and_featurized():
    # the tentpole floor: TPU baseline plus >= 4 GPU/NPU descriptors
    assert len(PROFILES) >= 6
    assert sum(1 for n in PROFILES if not n.startswith("tpu")) >= 4
    for name, p in PROFILES.items():
        assert p.name == name
        row = feature_row(p)
        assert tuple(row) == feature_names()
        assert all(np.isfinite(v) for v in row.values())
        # delivered rooflines are positive by construction
        assert all(v > 0 for v in p.features().values())


def test_hardware_distance_metric_properties():
    names = sorted(PROFILES)
    for a in names:
        assert hardware_distance(a, a) == 0.0
        for b in names:
            d = hardware_distance(a, b)
            assert d >= 0.0 and np.isfinite(d)
            assert d == pytest.approx(hardware_distance(b, a))
    # names and descriptor objects are interchangeable
    assert hardware_distance("tpu-v5e", PROFILES["tpu-v4"]) \
        == pytest.approx(hardware_distance(PROFILES["tpu-v5e"], "tpu-v4"))
    # the TPU sibling sits closer to v5e than a small inference GPU
    assert hardware_distance("tpu-v5e", "tpu-v4") \
        < hardware_distance("tpu-v5e", "gpu-l4")
    with pytest.raises(KeyError, match="unknown hardware"):
        profile("martian-npu")


def test_flops_at_dtype_scaling():
    p = PROFILES["gpu-h100-sxm"]
    bf16 = p.flops_at(2)
    assert bf16 == pytest.approx(p.peak_flops)
    assert p.flops_at(1) > bf16          # fp8 speedup on H100
    assert p.flops_at(4) < bf16          # fp32 slowdown everywhere


# --------------------------------------------------------- registry transfer
def _grid_rows(acc: str, cap: float, rng) -> list:
    """Synthetic saturating-throughput rows on one accelerator, with the
    hardware identity and descriptor feature columns the adapter and the
    bench datasets now stamp."""
    hw_cols = feature_row(acc) if acc in PROFILES else {
        k: 0.0 for k in feature_names()}
    bbs = np.array([1, 2, 4, 8, 16, 32, 64], float)
    rows = []
    for ii in (128.0, 512.0):
        for oo in (128.0, 256.0):
            for bb, t in zip(bbs, exp_model(bbs, 0.9 * cap, 0.08, cap)):
                rows.append(dict(model="m", acc=acc, acc_count=4, back="f",
                                 prec="bf16", mode="serve", ii=ii, oo=oo,
                                 bb=bb, thpt=t * rng.normal(1.0, 0.01),
                                 **hw_cols))
    return rows


@pytest.fixture(scope="module")
def fitted_registry():
    rng = np.random.default_rng(0)
    src = Dataset.from_rows(_grid_rows("tpu-v5e", 4000.0, rng))
    reg = ModelRegistry().fit(src, n_estimators=20)
    reg.fit_uncertainty(
        src, sa_cfg=SAConfig(n_iters=3, seed=0, n_chains=2,
                             gbt_kw=dict(n_estimators=15)),
        n_estimators=15)
    return reg, src


def _relabel(src: Dataset, acc: str) -> Dataset:
    cols = dict(src.cols)
    cols["acc"] = np.full(len(src), acc)
    hw = (feature_row(acc) if acc in PROFILES
          else {k: 0.0 for k in feature_names()})
    for k, v in hw.items():
        cols[k] = np.full(len(src), v)
    return Dataset(cols)


def test_donor_is_nearest_fitted_hardware(fitted_registry):
    reg, src = fitted_registry
    rng = np.random.default_rng(1)
    far = Dataset.from_rows(_grid_rows("gpu-l4", 900.0, rng))
    reg2 = ModelRegistry().fit(src.concat(far), n_estimators=20)
    v5e, l4 = None, None
    hi = reg2._active_keys.index("acc")
    for combo in reg2.combos:
        if combo[hi] == "tpu-v5e":
            v5e = combo
        if combo[hi] == "gpu-l4":
            l4 = combo
    query = v5e[:hi] + ("tpu-v4",) + v5e[hi + 1:]
    assert reg2.donor_for(query) == v5e       # v4 is nearer v5e than l4
    # descriptor distance, not vendor family, picks the donor: a100's
    # delivered rooflines sit nearer the v5e than the small L4's
    query_far = l4[:hi] + ("gpu-a100-80g",) + l4[hi + 1:]
    assert reg2.donor_for(query_far) == v5e
    # unregistered hardware has no finite descriptor distance to any
    # candidate, so nothing qualifies as its donor
    query_alien = v5e[:hi] + ("martian-npu",) + v5e[hi + 1:]
    assert reg2.donor_for(query_alien) is None


def test_transfer_confidence_strictly_below_native(fitted_registry):
    reg, src = fitted_registry
    native_err, native_d, native_conf = reg.estimate(src)
    assert np.isfinite(native_conf).all() and (native_conf > 0).all()
    moved = _relabel(src, "tpu-v4")
    # without transfer: unknown combination -> degenerate sentinel
    err0, d0, c0 = reg.estimate(moved)
    assert np.isnan(err0).all() and np.isinf(d0).all() and (c0 == 0).all()
    # with transfer: honest, strictly degraded confidence
    err, d, conf = reg.estimate(moved, transfer=True)
    assert np.isfinite(conf).all() and (conf > 0).all()
    assert (conf < native_conf).all()
    # workload distance reported is the donor's (pure d_min, no hw term)
    np.testing.assert_allclose(d, native_d)


def test_transfer_unknown_hardware_keeps_sentinel(fitted_registry):
    reg, src = fitted_registry
    alien = _relabel(src, "martian-npu")
    err, d, conf = reg.estimate(alien, transfer=True)
    assert np.isnan(err).all() and np.isinf(d).all() and (conf == 0).all()


def test_transfer_predict_applies_scale_fn(fitted_registry):
    reg, src = fitted_registry
    moved = _relabel(src, "tpu-v4")
    hi = reg._active_keys.index("acc")
    raw = reg.predict(moved, transfer=True)
    assert (raw > 0).all()

    def scale(combo, donor, ii, oo, bb):
        assert combo[hi] == "tpu-v4" and donor[hi] == "tpu-v5e"
        return 1.5

    scaled = reg.predict(moved, transfer=True, scale_fn=scale)
    np.testing.assert_allclose(scaled, raw * 1.5)


# ------------------------------------------------------------ mixed datasets
def test_concat_keys_mixed_hardware_apart():
    rng = np.random.default_rng(2)
    a = Dataset.from_rows(_grid_rows("tpu-v5e", 4000.0, rng))
    b = Dataset.from_rows(_grid_rows("gpu-l4", 900.0, rng))
    both = a.concat(b)
    assert len(both) == len(a) + len(b)
    combos = both.unique_combos(["model", "acc"])
    assert sorted(c[1] for c in combos) == ["gpu-l4", "tpu-v5e"]
    # and the registry fits them as separate combinations
    reg = ModelRegistry().fit(both, n_estimators=15)
    assert len(reg.combos) == 2


def test_concat_rejects_featureless_rows():
    """Rows missing the hw_* descriptor columns cannot silently join a
    featurized dataset — schema mismatch is an error, not a drop."""
    rng = np.random.default_rng(3)
    feat = Dataset.from_rows(_grid_rows("tpu-v5e", 4000.0, rng))
    bare_rows = [{k: v for k, v in r.items()
                  if not k.startswith("hw_")}
                 for r in _grid_rows("gpu-l4", 900.0, rng)]
    bare = Dataset.from_rows(bare_rows)
    with pytest.raises(ValueError, match="schema mismatch"):
        feat.concat(bare)
    with pytest.raises(ValueError, match="schema mismatch"):
        bare.concat(feat)


# ------------------------------------------------------- autoscaler placement
def _obs(**kw):
    base = dict(now=10.0, window_s=5.0, n_arrivals=10, mean_ii=512.0,
                mean_oo=128.0, arrival_rate=2.0, queue_len=0, n_running=8,
                n_active_replicas=1, batch_cap=32, decode_tokens=1000,
                busy_s=4.0, measured_tok_s=250.0)
    base.update(kw)
    return Observation(**base)


def _controller(**kw):
    return ALAAutoscaler(ala=None, hardware_pool=("tpu-v5e", "gpu-l4"),
                         fitted_hardware="tpu-v5e", **kw)


def test_aware_placement_prefers_fitted_hardware():
    ctl = _controller()
    name, pred_hw, conf_hw = ctl._choose_hardware(_obs(), 32, 100.0, 0.9)
    assert name == "tpu-v5e"
    assert pred_hw == pytest.approx(100.0)
    # d_hw = 0 round-trips the Alg 8 squash exactly
    assert conf_hw == pytest.approx(0.9)
    assert ctl.placements and ctl.placements[-1][1] == "tpu-v5e"


def test_aware_placement_crosses_when_scaled_throughput_wins():
    ctl = _controller(hardware_scale={"gpu-l4": lambda ii, oo, bb: 10.0})
    name, pred_hw, conf_hw = ctl._choose_hardware(_obs(), 32, 100.0, 0.9)
    assert name == "gpu-l4"
    assert pred_hw == pytest.approx(1000.0)
    assert conf_hw < 0.9            # cross-hardware confidence is derated


def test_roundrobin_placement_ignores_predictions():
    ctl = _controller(placement="roundrobin",
                      hardware_scale={"gpu-l4": lambda ii, oo, bb: 10.0})
    seen = [ctl._choose_hardware(_obs(), 32, 100.0, 0.9)[0]
            for _ in range(4)]
    assert seen == ["tpu-v5e", "gpu-l4", "tpu-v5e", "gpu-l4"]
    assert all(np.isnan(s) for _, _, s in ctl.placements)


def test_degenerate_confidence_still_places():
    ctl = _controller()
    name, pred_hw, conf_hw = ctl._choose_hardware(_obs(), 32, 100.0, 0.0)
    assert name in ctl.hardware_pool
    assert conf_hw == 0.0


# -------------------------------------------------- engines honor placement
class _PinnedPolicy:
    """Scale to 3 replicas immediately, pinning new ones to gpu-l4."""

    def control(self, obs):
        return Action(n_replicas=3, batch_cap=16, hardware="gpu-l4")


@pytest.fixture(scope="module")
def tpu_setup():
    return ServingSetup(cfg=get_config("llama3.1-8b"), hw=TPU_V5E, chips=4)


def test_action_hardware_creates_pinned_replicas(tpu_setup):
    tr = make_trace(TraceConfig(arrival="poisson", rate=4.0,
                                horizon_s=20.0, seed=31))
    for engine in ("heap", "fleet"):
        cfg = SimConfig(setup=tpu_setup, batch_cap=16, n_replicas=1,
                        max_replicas=3)
        res = simulate(tr, cfg, policy=_PinnedPolicy(), engine=engine)
        # the seed replica keeps the slot default; scale-ups are pinned
        assert res.replica_hw[0] == "tpu-v5e"
        created = {rid: hw for rid, hw in res.replica_hw.items() if rid > 0}
        assert created and set(created.values()) == {"gpu-l4"}


# ------------------------------------------------ heterogeneous data path
@pytest.fixture(scope="module")
def hetero_result(tpu_setup):
    l4 = ServingSetup(cfg=get_config("llama3.1-8b"),
                      hw=profile("gpu-l4"), chips=4)
    tr = make_trace(TraceConfig(arrival="poisson", rate=5.0,
                                horizon_s=40.0, seed=23))
    cfg = SimConfig(setup=tpu_setup, batch_cap=32, n_replicas=2,
                    replica_setups=(tpu_setup, l4))
    return simulate(tr, cfg, engine="heap"), tpu_setup, l4


def test_adapter_rejects_heterogeneous_result(hetero_result):
    res, tpu, l4 = hetero_result
    assert set(res.replica_hw.values()) == {"tpu-v5e", "gpu-l4"}
    with pytest.raises(ValueError, match="heterogeneous fleet"):
        windows_to_dataset(res, tpu, "llama3.1-8b")


def test_adapter_rejects_wrong_hardware_label(tpu_setup):
    l4 = ServingSetup(cfg=get_config("llama3.1-8b"),
                      hw=profile("gpu-l4"), chips=4)
    tr = make_trace(TraceConfig(arrival="poisson", rate=4.0,
                                horizon_s=30.0, seed=27))
    res = simulate(tr, SimConfig(setup=l4, batch_cap=32, n_replicas=2),
                   engine="heap")
    with pytest.raises(ValueError, match="wrong hardware"):
        windows_to_dataset(res, tpu_setup, "llama3.1-8b")


def test_windows_split_by_hardware(hetero_result):
    res, tpu, l4 = hetero_result
    out = windows_to_datasets_by_hardware(
        res, {"tpu-v5e": tpu, "gpu-l4": l4}, "llama3.1-8b")
    assert set(out) <= {"tpu-v5e", "gpu-l4"} and out
    for hw, ds in out.items():
        assert (ds["acc"] == hw).all()
        assert (ds["thpt"] > 0).all()
        want = feature_row(hw)
        for k, v in want.items():
            np.testing.assert_allclose(ds[k].astype(float), v)
    # every attributed row's hardware features differ across tiers
    if len(out) == 2:
        assert not np.isclose(out["tpu-v5e"]["hw_flops"][0],
                              out["gpu-l4"]["hw_flops"][0])
    with pytest.raises(KeyError, match="no ServingSetup"):
        windows_to_datasets_by_hardware(res, {"tpu-v5e": tpu},
                                        "llama3.1-8b")
