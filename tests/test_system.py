"""End-to-end behaviour tests for the full system: serving engine, ALA on
real measured data, capacity planning, trainer fault tolerance."""
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.common import SMOKE_TRAIN
from repro.configs.shapes import ShapeSpec
from repro.core.ala import ALA, ALAConfig
from repro.core.annealing import SAConfig, median_ape
from repro.inference.engine import ServingEngine
from repro.inference.scheduler import BatchingQueue, CapacityPlanner, Request
from repro.models.transformer import Model
from repro.training.train_loop import TrainConfig, Trainer
from repro.training.optimizer import AdamWConfig


@pytest.fixture(scope="module")
def tiny_engine():
    cfg = get_smoke_config("llama3.2-3b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    return ServingEngine(model, params)


def test_engine_generates_tokens(tiny_engine):
    prompts = np.random.default_rng(0).integers(0, 255, (2, 16),
                                                dtype=np.int32)
    res = tiny_engine.generate(prompts, max_new_tokens=8)
    assert res.tokens.shape == (2, 8)
    assert res.tokens_per_s > 0
    assert (res.tokens >= 0).all() and \
        (res.tokens < tiny_engine.model.cfg.vocab_size).all()


def test_engine_throughput_rows(tiny_engine):
    rows = tiny_engine.measure_throughput(ii=16, oo=4, bb=2, reps=2)
    assert len(rows) == 2
    assert all(r["thpt"] > 0 for r in rows)


def test_ala_on_real_measured_data(tiny_engine):
    """The full paper loop on genuinely measured (CPU) throughput."""
    rows = []
    for bb in (1, 2, 4, 8):
        for ii, oo in ((8, 4), (16, 4)):
            rows.extend(tiny_engine.measure_throughput(ii, oo, bb, reps=2))
    ii = np.array([r["ii"] for r in rows], float)
    oo = np.array([r["oo"] for r in rows], float)
    bb = np.array([r["bb"] for r in rows], float)
    th = np.array([r["thpt"] for r in rows], float)
    ala = ALA().fit(ii, oo, bb, th)
    err = ala.score(ii, oo, bb, th)
    assert err < 35.0, f"in-sample median APE {err}%"


def test_capacity_planner_monotone():
    from repro.core.expmodel import exp_model
    bbs = np.array([1, 2, 4, 8, 16, 32, 64, 128], float)
    rows_ii, rows_oo, rows_bb, rows_t = [], [], [], []
    for ii in (128.0, 512.0):
        for oo in (128.0, 256.0):
            y = exp_model(bbs, 900.0, 0.05, 1000.0 + ii / 10)
            rows_ii += [ii] * len(bbs)
            rows_oo += [oo] * len(bbs)
            rows_bb += bbs.tolist()
            rows_t += y.tolist()
    ala = ALA().fit(np.array(rows_ii), np.array(rows_oo),
                    np.array(rows_bb), np.array(rows_t))
    planner = CapacityPlanner(ala, candidate_bb=(1, 2, 4, 8, 16, 32, 64,
                                                 128))
    lo = planner.plan_batch_size(128, 128, target_thpt=300.0)
    hi = planner.plan_batch_size(128, 128, target_thpt=900.0)
    assert lo.bb <= hi.bb
    assert hi.predicted_thpt >= 900.0 * 0.5
    # unattainable target scales out
    huge = planner.plan_batch_size(128, 128, target_thpt=50_000.0)
    assert huge.replicas > 1


def test_batching_queue_groups_by_bucket():
    from repro.core.expmodel import exp_model
    bbs = np.array([1, 2, 4, 8], float)
    y = exp_model(bbs, 90.0, 0.3, 100.0)
    ala = ALA().fit(np.full(4, 128.0), np.full(4, 128.0), bbs, y)
    planner = CapacityPlanner(ala, candidate_bb=(1, 2, 4))
    q = BatchingQueue(planner, target_thpt=60.0)
    for i in range(10):
        q.submit(Request(rid=i, ii=100, oo=120))
    batches = q.ready_batches()
    assert batches, "expected at least one ready batch"
    key, reqs = batches[0]
    assert key == (128, 128)
    plan = q.plans[key]
    assert len(reqs) == plan.bb


def test_trainer_checkpoint_restart(tmp_path):
    """Fault-tolerance drill: train 6 steps, 'crash', resume from ckpt —
    final params must equal an uninterrupted 12-step run."""
    cfg = get_smoke_config("qwen3-0.6b")
    shape = ShapeSpec("t", seq_len=16, global_batch=2, kind="train")
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=12)

    def make(dirname, total):
        t = Trainer(Model(cfg), shape, None,
                    TrainConfig(total_steps=total, ckpt_every=6,
                                ckpt_dir=str(tmp_path / dirname),
                                log_every=100, opt=opt))
        return t

    # uninterrupted run
    t_full = make("full", 12)
    p_full, _ = t_full.run(seed=3)

    # interrupted run: 6 steps, then a fresh Trainer resumes to 12
    t_a = make("resume", 6)
    t_a.run(seed=3)
    t_b = make("resume", 12)
    p_res, _ = t_b.run(seed=3)

    flat_full = jax.tree_util.tree_leaves(p_full)
    flat_res = jax.tree_util.tree_leaves(p_res)
    for a, b in zip(flat_full, flat_res):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-5)


def test_trainer_loss_decreases(tmp_path):
    cfg = get_smoke_config("llama3.2-3b")
    shape = ShapeSpec("t", seq_len=32, global_batch=4, kind="train")
    t = Trainer(Model(cfg), shape, None,
                TrainConfig(total_steps=30, ckpt_every=1000,
                            ckpt_dir=str(tmp_path / "ck"), log_every=1000,
                            opt=AdamWConfig(lr=3e-3, warmup_steps=5,
                                            total_steps=30)))
    t.run(seed=0)
    first = np.mean([h["loss"] for h in t.history[:5]])
    last = np.mean([h["loss"] for h in t.history[-5:]])
    assert last < first - 0.1, (first, last)
