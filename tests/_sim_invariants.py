"""Shared invariant checks for serving-simulator results.

Both engines (the event-heap reference and the vectorized fleet engine)
must satisfy these regardless of workload, faults, or policy — the
engine-specific suites call ``assert_sim_invariants`` on every result
they produce so a regression in either engine trips the same net.
"""
import numpy as np


def assert_sim_invariants(result, trace=None):
    """Engine-independent sanity of one ``SimResult``.

    * request conservation: completed + shed == admitted, no overlap;
    * per-request timeline ordering: arrival <= first token <= done,
      and positive token counts;
    * shed bookkeeping: shed requests carry a reason and a shed time,
      completed ones carry neither;
    * metric cross-consistency: ``slo_attainment`` at +inf equals the
      completed-with-first-token fraction, percentiles are monotone in
      q, and goodput/replica-seconds are non-negative and finite;
    * step stream: durations non-negative, batch sizes positive, step
      end times within the simulated span.
    """
    result.check_conservation()
    acc = result.accounting()
    assert acc["completed"] + acc["shed"] == acc["admitted"]
    if trace is not None:
        assert acc["admitted"] == len(trace)

    n_first = 0
    for r in result.records:
        assert r.ii > 0 and r.oo > 0
        if r.first_token_s is not None:
            n_first += 1
            assert r.first_token_s >= r.arrival_s
        if r.done_s is not None:
            assert not r.shed
            assert r.shed_reason == "" and r.shed_s is None
            assert r.first_token_s is not None
            assert r.done_s >= r.first_token_s
        if r.shed:
            assert r.done_s is None
            assert r.shed_reason in ("oversized", "retry_budget",
                                     "deadline", "unserved")
            assert r.shed_s is not None and r.shed_s >= r.arrival_s
        assert r.retries >= 0

    # attainment at an arbitrarily large finite SLO counts exactly the
    # requests that got a first token and were not shed (shed / no-first
    # requests carry an infinite TTFT)
    n = acc["admitted"]
    if n:
        att_huge = result.slo_attainment(1e12)
        served = sum(1 for r in result.records
                     if r.first_token_s is not None and not r.shed)
        assert att_huge == served / n
        ps = [result.ttft_percentile(q) for q in (10.0, 50.0, 90.0, 99.0)]
        assert all(b >= a or (np.isinf(a) and np.isinf(b))
                   for a, b in zip(ps, ps[1:]))

    assert result.replica_seconds >= 0.0
    assert 0.0 <= result.availability <= 1.0
    assert np.isfinite(result.goodput_tok_s) and result.goodput_tok_s >= 0
    assert result.sim_end_s >= result.t_start

    for s in result.steps:
        assert s.duration_s >= 0.0
        assert s.bb > 0
        assert s.kind in ("prefill", "decode")
        assert s.t_end <= result.sim_end_s + 1e-9


def assert_per_tenant_consistent(result, slo_map=None):
    """Per-tenant splits must re-aggregate to the fleet totals."""
    per = result.per_tenant(slo_map=slo_map)
    acc = result.accounting()
    assert sum(d["n_requests"] for d in per.values()) == acc["admitted"]
    assert sum(d["n_completed"] for d in per.values()) == acc["completed"]
    assert sum(d["n_shed"] for d in per.values()) == acc["shed"]
    shares = [d["goodput_share"] for d in per.values()]
    if acc["completed"]:
        assert abs(sum(shares) - 1.0) < 1e-9
    for d in per.values():
        assert 0.0 <= d["goodput_share"] <= 1.0 + 1e-12
        if np.isfinite(d["ttft_slo_s"]):
            assert 0.0 <= d["attainment"] <= 1.0
    meta = result.meta_metrics(slo_map=slo_map)
    assert meta["n_requests"] == acc["admitted"]
    assert meta["n_shed"] == acc["shed"]
    assert 0.0 <= meta["jain_fairness"] <= 1.0 + 1e-12
    if slo_map:
        # fleet attainment is the request-weighted tenant average
        num = sum(d["attainment"] * d["n_requests"] for d in per.values()
                  if np.isfinite(d["attainment"]))
        den = sum(d["n_requests"] for d in per.values()
                  if np.isfinite(d["attainment"]))
        if den:
            assert abs(meta["fleet_attainment"] - num / den) < 1e-9
