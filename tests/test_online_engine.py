"""Online incremental-refit engine + the stale-state bugfixes it rests on.

Covers the four PR bugfixes (registry refit-after-append stale combos,
``Dataset.concat`` schema validation, ``Dataset.from_rows`` row-index
errors, window-boundary step attribution) and the ``OnlineALA`` engine:
from-scratch parity of the incremental serving path, SA warm starts,
additive bank extension, drift signals, and the autoscaler's mid-run
recalibration hook.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import uncertainty
from repro.core.ala import ALA, ALAConfig
from repro.core.annealing import SAConfig, median_ape, merge_logs
from repro.core.database import (build_exponential_database,
                                 update_exponential_database)
from repro.core.dataset import Dataset
from repro.core.online import OnlineALA, OnlineConfig
from repro.core.registry import ModelRegistry
from repro.serving.adapter import _window_overlaps, summarize_windows
from repro.serving.autoscaler import ALAAutoscaler
from repro.serving.simulator import (Observation, RequestRecord, SimResult,
                                     StepRecord)

KEY_COLS = dict(acc="tpu-v5e", acc_count=4, back="sim-trace", prec="bf16",
                mode="serve")


def _rows(model, n, seed, scale=1.0, iis=(128, 256, 512, 1024)):
    r = np.random.default_rng(seed)
    ii = r.choice(iis, n)
    oo = r.choice([64, 128, 256], n)
    bb = r.choice([1, 2, 4, 8, 16, 32, 64], n)
    thpt = (scale * 5000 * (1 - np.exp(-0.05 * bb)) * (512 / ii) ** 0.3
            * r.lognormal(0, 0.03, n))
    return [dict(model=model, **KEY_COLS, ii=int(a), oo=int(b), bb=int(c),
                 thpt=float(t))
            for a, b, c, t in zip(ii, oo, bb, thpt)]


def _ds(model, n, seed, **kw):
    return Dataset.from_rows(_rows(model, n, seed, **kw))


def _wl(n, seed, iis=(128., 256, 512, 1024)):
    r = np.random.default_rng(seed)
    ii = r.choice(iis, n)
    oo = r.choice([64., 128, 256], n)
    bb = r.choice([1., 2, 4, 8, 16, 32, 64], n)
    t = (5000 * (1 - np.exp(-0.05 * bb)) * (512 / ii) ** 0.3
         * r.lognormal(0, 0.03, n))
    return ii, oo, bb, t


def _small_cfg(warm_iters=3, **kw):
    sa = SAConfig(n_iters=4, n_chains=2, seed=0,
                  gbt_kw=dict(n_estimators=15, learning_rate=0.2,
                              max_depth=3))
    return OnlineConfig(sa=sa, warm_iters=warm_iters,
                        gbt_kw=dict(sa.gbt_kw), **kw)


# ------------------------------------------------------- registry bugfixes
def test_registry_full_fit_drops_stale_combos():
    """Refitting on a dataset missing a combination must not keep the old
    combination's model (or its stale ala) silently serving."""
    both = _ds("m-a", 30, 1).concat(_ds("m-b", 30, 2))
    reg = ModelRegistry().fit(both, n_estimators=10)
    assert len(reg.combos) == 2
    reg.combos[next(iter(reg.combos))] = dataclasses.replace(
        next(iter(reg.combos.values())), ala=object())   # fake stale ala
    only_a = _ds("m-a", 30, 3)
    reg.fit(only_a, n_estimators=10)
    assert len(reg.combos) == 1
    assert next(iter(reg.combos))[0] == "m-a"
    assert next(iter(reg.combos.values())).ala is None


def test_registry_refit_updates_only_targets():
    both = _ds("m-a", 30, 1).concat(_ds("m-b", 30, 2))
    reg = ModelRegistry().fit(both, n_estimators=10)
    combo_a = next(c for c in reg.combos if c[0] == "m-a")
    combo_b = next(c for c in reg.combos if c[0] == "m-b")
    reg.attach_ala(combo_b, object())
    kept = reg.combos[combo_b]
    grown = _ds("m-a", 45, 4)
    reg.refit(grown, combos=[combo_a], n_estimators=10)
    assert reg.combos[combo_b] is kept           # untouched, ala intact
    assert reg.combos[combo_a].ala is None       # refit drops stale ala
    pred = reg.predict(both)
    assert np.isfinite(pred).all() and (pred > 0).all()


def test_registry_refit_rejects_unknown_combo_and_key_mismatch():
    reg = ModelRegistry().fit(_ds("m-a", 30, 1), n_estimators=10)
    with pytest.raises(ValueError, match="no rows"):
        reg.refit(_ds("m-a", 10, 2), combos=[("m-zzz",) * 6])
    missing_keys = Dataset({k: _ds("m-a", 10, 3)[k]
                            for k in ("ii", "oo", "bb", "thpt", "model")})
    with pytest.raises(ValueError, match="key columns"):
        reg.refit(missing_keys)


def test_registry_update_combo_matches_full_fit():
    """Append-only incremental combo update == from-scratch fit, bit-near."""
    d0, d1 = _ds("m-a", 40, 1), _ds("m-a", 12, 2, iis=(64, 256))
    full = d0.concat(d1)
    reg = ModelRegistry().fit(d0, n_estimators=10)
    combo = next(iter(reg.combos))
    reg.update_combo(combo, full.workload, n_delta=len(d1), n_estimators=10)
    scratch = ModelRegistry().fit(full, n_estimators=10)
    np.testing.assert_allclose(reg.predict(full), scratch.predict(full),
                               atol=1e-6)


# -------------------------------------------------------- dataset bugfixes
def test_concat_schema_mismatch_names_columns():
    a = Dataset({"ii": np.arange(3), "oo": np.arange(3)})
    b = Dataset({"ii": np.arange(2)})
    with pytest.raises(ValueError, match=r"\['oo'\] missing from other"):
        a.concat(b)
    with pytest.raises(ValueError, match=r"\['oo'\] only in other"):
        b.concat(a)
    c = Dataset({"ii": np.arange(2), "oo": np.arange(2),
                 "thpt": np.ones(2)})
    with pytest.raises(ValueError, match="thpt"):
        a.concat(c)


def test_concat_dtype_promotion_deterministic():
    num = Dataset({"acc_count": np.array([4, 8]), "x": np.array([1, 2])})
    txt = Dataset({"acc_count": np.array(["4", "16"]),
                   "x": np.array([3.5, 4.5])})
    out = num.concat(txt)
    assert out["acc_count"].dtype.kind == "U"
    assert list(out["acc_count"]) == ["4", "8", "4", "16"]
    assert out["x"].dtype.kind == "f"            # numeric promotes normally
    np.testing.assert_allclose(out["x"], [1.0, 2.0, 3.5, 4.5])
    # symmetric: str side first gives the same column dtypes
    assert txt.concat(num)["acc_count"].dtype.kind == "U"


def test_from_rows_reports_offending_row_and_key():
    rows = [dict(ii=1, oo=2), dict(ii=3, oo=4), dict(ii=5)]
    with pytest.raises(ValueError, match=r"row 2.*missing keys \['oo'\]"):
        Dataset.from_rows(rows)
    rows = [dict(ii=1), dict(ii=2, extra=9)]
    with pytest.raises(ValueError, match=r"row 1 .*unexpected keys"
                                         r" \['extra'\]"):
        Dataset.from_rows(rows)
    with pytest.raises(ValueError):
        Dataset.from_rows([])


# ------------------------------------------------ adapter window attribution
def test_window_overlap_fractions_sum_to_one():
    for (t0, t1) in ((0.0, 1.0), (4.0, 7.0), (2.5, 12.5), (9.9, 10.0),
                     (3.0, 3.0)):
        fr = list(_window_overlaps(t0, t1, 5.0, 3))
        assert sum(f for _, f in fr) == pytest.approx(1.0)
        assert all(0 <= w < 3 for w, _ in fr)


def test_boundary_straddling_step_split_by_overlap():
    """A 2 s step ending 1 s after a window boundary must credit half its
    time/tokens to each side, not all of it to the t_end window."""
    recs = [RequestRecord(rid=0, ii=8, oo=4, arrival_s=0.1,
                          first_token_s=1.0, done_s=3.0),
            RequestRecord(rid=1, ii=8, oo=4, arrival_s=0.2,
                          first_token_s=6.0, done_s=8.0)]
    steps = [StepRecord(t_end=6.0, replica=0, kind="decode", bb=4,
                        duration_s=2.0, tokens_out=8),
             StepRecord(t_end=3.0, replica=0, kind="decode", bb=2,
                        duration_s=1.0, tokens_out=2),
             StepRecord(t_end=8.0, replica=0, kind="decode", bb=2,
                        duration_s=1.0, tokens_out=2)]
    res = SimResult(records=recs, steps=steps, sim_end_s=10.0, n_events=5,
                    replica_seconds=10.0, controls=[])
    wins = summarize_windows(res, window_s=5.0, min_completions=1)
    assert len(wins) == 2
    # per window: 1 s own step + 1 s (half) of the straddler -> 2 s busy,
    # 2 + 4 tokens -> thpt 3.0 both sides; old t_end crediting gave
    # 1.0 vs 4.67
    assert wins[0].thpt == pytest.approx(3.0)
    assert wins[1].thpt == pytest.approx(3.0)
    # duration-weighted bb: (2*1 + 4*1) / 2 = 3.0 in both windows
    assert wins[0].bb == pytest.approx(3.0)
    assert wins[1].bb == pytest.approx(3.0)


def test_window_totals_conserved():
    """Overlap splitting conserves each step's duration and tokens, for
    random spans including ones longer than a whole window."""
    rng = np.random.default_rng(0)
    n_win, window_s = 7, 3.0
    busy = np.zeros(n_win)
    toks = np.zeros(n_win)
    total_busy = total_toks = 0.0
    t = 0.0
    for _ in range(60):
        d = float(rng.uniform(0.05, 4.5))      # some spans > window_s
        t = min(t + d, n_win * window_s)
        fr = list(_window_overlaps(t - d, t, window_s, n_win))
        assert sum(f for _, f in fr) == pytest.approx(1.0)
        for w, f in fr:
            busy[w] += f * d
            toks[w] += f * 2
        total_busy += d
        total_toks += 2
    assert busy.sum() == pytest.approx(total_busy)
    assert toks.sum() == pytest.approx(total_toks)


# ----------------------------------------------------- incremental database
def test_update_exponential_database_parity():
    old = _wl(60, 1)
    delta = _wl(15, 2, iis=(64., 256, 2048))     # new and existing groups
    full = tuple(np.concatenate([a, b]) for a, b in zip(old, delta))
    db0 = build_exponential_database(*old)
    inc = update_exponential_database(db0, *full, n_delta=15)
    ref = build_exponential_database(*full)
    assert set(inc.params) == set(ref.params)
    for k in ref.params:
        np.testing.assert_array_equal(inc.params[k], ref.params[k])
    np.testing.assert_array_equal(inc.training, ref.training)


def test_update_exponential_database_single_group_delta():
    old = _wl(60, 1)
    d_ii = np.full(3, 256.0)
    delta = (d_ii, np.full(3, 64.0), np.array([2.0, 8.0, 32.0]),
             np.array([900.0, 2400.0, 4100.0]))
    full = tuple(np.concatenate([a, b]) for a, b in zip(old, delta))
    inc = update_exponential_database(build_exponential_database(*old),
                                      *full, n_delta=3)
    ref = build_exponential_database(*full)
    for k in ref.params:
        np.testing.assert_array_equal(inc.params[k], ref.params[k])


# ------------------------------------------------------- ALA refit + bank
@pytest.fixture(scope="module")
def warm_ala():
    tr, te = _wl(70, 1), _wl(25, 2)
    ala = ALA(ALAConfig(sa=SAConfig(n_iters=4, n_chains=2, seed=0,
                                    gbt_kw=dict(n_estimators=15,
                                                learning_rate=0.2,
                                                max_depth=3)),
                        gbt_kw=dict(n_estimators=15, learning_rate=0.15,
                                    max_depth=3)))
    ala.fit(*tr)
    ala.explore(te)
    ala.fit_error()
    ala.bank()
    return ala, tr, te


def test_ala_refit_warm_starts_from_previous_best(warm_ala):
    ala, tr, te = warm_ala
    prev_best = dict(ala.sa_log.best_subset)
    n0 = len(ala.sa_log.subsets)
    delta = _wl(20, 3)
    full = tuple(np.concatenate([a, b]) for a, b in zip(tr, delta))
    log = ala.refit(full, te, n_iters=3, n_chains=2)
    assert len(log.subsets) > n0
    # chain 0 of the new run starts from the previous best subset
    assert log.subsets[n0] == prev_best
    e, c = ala.estimate(te)
    assert np.isfinite(e) and 0.0 <= c <= 1.0


def test_ala_refit_extends_bank_incrementally(warm_ala):
    ala, _, te = warm_ala
    tr_now = ala._train
    bank0 = ala.bank()
    delta = _wl(10, 7)
    full = tuple(np.concatenate([a, b]) for a, b in zip(tr_now, delta))
    ala.refit(full, te, n_iters=2, n_chains=2)
    bank1 = ala.bank()
    # incremental extension == from-scratch rebuild under pinned edges
    ref = uncertainty.build_subset_bank(full, ala.sa_log,
                                        inner_edges=bank0.inner_edges)
    np.testing.assert_array_equal(bank1.hist, ref.hist)
    np.testing.assert_array_equal(bank1.masks, ref.masks)
    np.testing.assert_array_equal(bank1.valid, ref.valid)
    np.testing.assert_array_equal(bank1.inner_edges, bank0.inner_edges)


def test_extend_bank_trailing_window():
    tr, te = _wl(50, 1), _wl(20, 2)
    ala = ALA(ALAConfig(sa=SAConfig(n_iters=6, n_chains=1, seed=0,
                                    gbt_kw=dict(n_estimators=10,
                                                learning_rate=0.3,
                                                max_depth=2))))
    ala.fit(*tr)
    log = ala.explore(te)
    bank = uncertainty.build_subset_bank(tr, log, max_subsets=5)
    assert bank.n_subsets == 5
    delta = _wl(8, 3)
    full = tuple(np.concatenate([a, b]) for a, b in zip(tr, delta))
    merged = merge_logs(log, log)
    out = uncertainty.extend_bank(bank, full, 8, log.subsets,
                                  merged.universes, max_subsets=5)
    assert out.n_subsets == 5                     # window still applies
    ref = uncertainty.build_subset_bank(full, merged, max_subsets=5,
                                        inner_edges=bank.inner_edges)
    np.testing.assert_array_equal(out.hist, ref.hist)


def test_merge_logs_union_universes_and_fresh_best():
    tr, te = _wl(40, 1), _wl(15, 2)
    cfg = SAConfig(n_iters=3, n_chains=1, seed=0,
                   gbt_kw=dict(n_estimators=10, learning_rate=0.3,
                               max_depth=2))
    ala = ALA(ALAConfig(sa=cfg))
    ala.fit(*tr)
    log_a = ala.explore(te)
    tr2 = tuple(np.concatenate([a, b]) for a, b in zip(tr, _wl(10, 9,
                iis=(64., 4096))))
    ala2 = ALA(ALAConfig(sa=cfg))
    ala2.fit(*tr2)
    log_b = ala2.explore(te)
    merged = merge_logs(log_a, log_b)
    assert len(merged.subsets) == len(log_a.subsets) + len(log_b.subsets)
    assert merged.best_subset == log_b.best_subset
    for dim in ("ii", "oo", "bb"):
        assert set(log_a.universes[dim]) <= set(merged.universes[dim])
        assert set(log_b.universes[dim]) <= set(merged.universes[dim])


# ------------------------------------------------------------- OnlineALA
def test_online_parity_and_selective_refit():
    eng = OnlineALA(_small_cfg())
    eng.ingest(_ds("m-a", 40, 1).concat(_ds("m-b", 40, 2)),
               n_estimators=10)
    combo_a = next(c for c in eng.combos if c[0] == "m-a")
    combo_b = next(c for c in eng.combos if c[0] == "m-b")
    ala_b = eng.ala_for(combo_b)
    rep = eng.ingest(_ds("m-a", 20, 3), n_estimators=10)
    assert rep.changed == [combo_a] and rep.refit == [combo_a]
    assert eng.ala_for(combo_b) is ala_b          # untouched combo kept
    # serving-path parity with a from-scratch registry on the same rows
    full = eng.full_data()
    scratch = ModelRegistry().fit(full, n_estimators=10)
    np.testing.assert_allclose(eng.predict(full), scratch.predict(full),
                               atol=1e-6)
    # uncertainty path serves finite estimates for both combos
    err, d, conf = eng.estimate(full, backend="numpy")
    assert np.isfinite(err).all() and (conf > 0).all()


def test_online_drift_detection_and_policy():
    eng = OnlineALA(_small_cfg(refit="drift", drift_err_ratio=2.0))
    eng.ingest(_ds("m-a", 50, 1), n_estimators=10)
    combo = eng.combos[0]
    # same-distribution delta: no drift, no refit under the drift policy
    rep = eng.ingest(_ds("m-a", 15, 2), n_estimators=10)
    assert not rep.drift[combo].drifted
    assert rep.refit == [] and rep.skipped == [combo]
    # regime shift: residual growth must trigger a refit
    rep2 = eng.ingest(_ds("m-a", 15, 3, scale=0.25), n_estimators=10)
    assert rep2.drift[combo].drifted
    assert rep2.drift[combo].reason in ("residual_growth",
                                        "confidence_collapse")
    assert rep2.refit == [combo]


def test_online_drift_policy_refits_skipped_epoch_rows():
    """Epochs skipped under refit="drift" still accumulate rows; the
    next refit must treat them all as delta, not as fitted prefix —
    otherwise groups touched only by skipped epochs stay stale."""
    eng = OnlineALA(_small_cfg(refit="drift", drift_err_ratio=2.0))
    eng.ingest(_ds("m-a", 50, 1), n_estimators=10)
    combo = eng.combos[0]
    skipped = eng.ingest(_ds("m-a", 12, 2, iis=(64, 128)), n_estimators=10)
    assert skipped.refit == []                    # no drift -> no refit
    forced = eng.ingest(_ds("m-a", 12, 3, scale=0.25), n_estimators=10)
    assert forced.refit == [combo]
    full = eng.full_data()
    scratch = ModelRegistry().fit(full, n_estimators=10)
    np.testing.assert_allclose(eng.predict(full), scratch.predict(full),
                               atol=1e-6)


def test_online_request_refit_forces_recalibration():
    eng = OnlineALA(_small_cfg(refit="drift"))
    eng.ingest(_ds("m-a", 50, 1), n_estimators=10)
    combo = eng.combos[0]
    eng.request_refit(combo)
    rep = eng.ingest(_ds("m-a", 12, 2), n_estimators=10)
    assert rep.refit == [combo]                   # forced despite no drift
    # a forced combo refits even when the next ingest carries no rows
    # for it (the promise the autoscaler's recalibration log relies on)
    gen = eng.generation_of(combo)
    eng.request_refit(combo)
    rep2 = eng.ingest(_ds("m-b", 30, 3), n_estimators=10)
    assert combo in rep2.refit and combo not in rep2.changed
    assert eng.generation_of(combo) == gen + 1


def test_online_min_rows_skips_uncertainty_not_predict():
    eng = OnlineALA(_small_cfg(min_rows=64))
    rep = eng.ingest(_ds("m-a", 20, 1), n_estimators=10)
    combo = eng.combos[0]
    assert rep.refit == [] and eng.ala_for(combo) is None
    probe = _ds("m-a", 10, 2)
    assert np.isfinite(eng.predict(probe)).all()  # Alg 4/5 still serves
    err, d, conf = eng.estimate(probe, backend="numpy")
    assert np.isnan(err).all() and (conf == 0.0).all()   # sentinel


def test_online_key_mismatch_raises():
    eng = OnlineALA(_small_cfg())
    eng.ingest(_ds("m-a", 20, 1), n_estimators=10)
    bad = Dataset({k: _ds("m-a", 5, 2)[k]
                   for k in ("model", "ii", "oo", "bb", "thpt")})
    with pytest.raises(ValueError, match="key columns"):
        eng.ingest(bad)


# ----------------------------------------------- robust-ingestion gate
def test_gate_quarantines_with_reasons():
    eng = OnlineALA(_small_cfg(gate=True))
    eng.ingest(_ds("m-a", 40, 1), n_estimators=10)
    combo = eng.combos[0]
    clean = _rows("m-a", 6, 2)
    nan_row = dict(clean[0], thpt=float("nan"))
    dup_row = dict(clean[1])                      # exact copy in same delta
    poison = dict(clean[2], thpt=clean[2]["thpt"] * 50.0)
    delta = Dataset.from_rows(clean + [nan_row, dup_row, poison],
                              require_finite=None)
    rep = eng.ingest(delta, n_estimators=10)
    assert rep.n_quarantined >= 3
    by_reason = {}
    for q in eng.quarantine:
        by_reason.setdefault(q.reason, []).append(q.row)
    assert set(by_reason) <= {"nonfinite", "duplicate", "outlier"}
    assert any(not np.isfinite(r["thpt"]) for r in by_reason["nonfinite"])
    assert any(r["thpt"] == dup_row["thpt"]
               for r in by_reason["duplicate"])
    assert any(r["thpt"] == poison["thpt"]       # 50x scale poison caught
               for r in by_reason["outlier"])
    assert np.isfinite(eng.predict(_ds("m-a", 10, 3))).all()


def test_gate_quarantine_parity_with_prefiltered_stream():
    """Ingesting a fault-corrupted window stream through the gate must
    land on exactly the state a perfect pre-filter would produce: the
    gate is a deterministic function of the (identical) registry state,
    so predictions and refit generations match bit-for-bit."""
    from repro.serving.faults import FaultConfig, injector

    base = _ds("m-a", 40, 1)
    delta = _rows("m-a", 30, 2)
    corrupted, rep = injector(FaultConfig(
        seed=6, drop_p=0.1, dup_p=0.15, poison_nan_p=0.15)).corrupt_rows(
            delta)
    assert rep.n_dropped and rep.n_duplicated and rep.n_poisoned
    eng_a = OnlineALA(_small_cfg(gate=True))
    eng_a.ingest(base, n_estimators=10)
    rep_a = eng_a.ingest(Dataset.from_rows(corrupted, require_finite=None),
                         n_estimators=10)
    eng_b = OnlineALA(_small_cfg(gate=True))
    eng_b.ingest(base, n_estimators=10)
    eng_b.ingest(Dataset.from_rows(rep.clean_rows), n_estimators=10)
    assert rep_a.n_quarantined >= rep.n_poisoned + rep.n_duplicated
    combo = eng_a.combos[0]
    assert eng_a.generation_of(combo) == eng_b.generation_of(combo)
    probe = _ds("m-a", 20, 5)
    np.testing.assert_array_equal(eng_a.predict(probe),
                                  eng_b.predict(probe))
    ea, da, ca = eng_a.estimate(probe, backend="numpy")
    eb, db, cb = eng_b.estimate(probe, backend="numpy")
    np.testing.assert_array_equal(ea, eb)
    np.testing.assert_array_equal(ca, cb)


def test_nonfinite_rows_filtered_even_without_gate():
    """The NaN/inf filter is not optional: an ungated engine must still
    refuse non-finite telemetry (one NaN poisons every downstream fit)."""
    eng = OnlineALA(_small_cfg())                 # gate defaults off
    eng.ingest(_ds("m-a", 40, 1), n_estimators=10)
    clean = _rows("m-a", 8, 2)
    bad = [dict(clean[0], thpt=float("nan")),
           dict(clean[1], thpt=float("inf")),
           dict(clean[2], thpt=-10.0)]           # non-positive: unusable
    rep = eng.ingest(Dataset.from_rows(clean + bad, require_finite=None),
                     n_estimators=10)
    assert rep.n_quarantined == 3
    assert all(q.reason == "nonfinite" for q in eng.quarantine)
    assert np.isfinite(eng.predict(_ds("m-a", 10, 3))).all()


# ----------------------------------------------- autoscaler recalibration
def _obs(now, measured, batch_cap=64):
    return Observation(now=now, window_s=2.0, n_arrivals=10, mean_ii=256.0,
                       mean_oo=128.0, arrival_rate=5.0, queue_len=0,
                       n_running=4, n_active_replicas=1,
                       batch_cap=batch_cap, decode_tokens=2000, busy_s=2.0,
                       measured_tok_s=measured)


def test_autoscaler_requests_recalibration_on_residual_growth():
    eng = OnlineALA(_small_cfg(refit="drift"))
    eng.ingest(_ds("m-a", 50, 1), n_estimators=10)
    combo = eng.combos[0]
    pol = ALAAutoscaler(ala=eng.ala_for(combo), online=eng, combo=combo,
                        drift_window=3, drift_ape_threshold=40.0)
    pred = float(pol.ala.predict([256.0], [128.0], [64.0])[0])
    for i in range(4):
        pol.control(_obs(2.0 * (i + 1), measured=pred * 3.0))
    assert pol.recalibrations, "sustained residual must trigger a request"
    rep = eng.ingest(_ds("m-a", 12, 2), n_estimators=10)
    assert rep.refit == [combo]                   # consumed by the engine
    # after the refit the autoscaler rebinds to the fresh ALA on its
    # next tick (mid-run recalibration reaches the control loop)
    fresh = eng.ala_for(combo)
    pol.control(_obs(20.0, measured=pred))
    assert pol.ala is fresh


def test_goodput_uses_elapsed_span_not_absolute_clock():
    """An epochal replay starting at t_start must not count the
    pre-epoch offset as serving time."""
    rec = RequestRecord(rid=0, ii=8, oo=100, arrival_s=61.0,
                        first_token_s=62.0, done_s=70.0)
    base = dict(records=[rec], steps=[], n_events=1, replica_seconds=20.0,
                controls=[])
    offset = SimResult(sim_end_s=80.0, t_start=60.0, **base)
    zero = SimResult(sim_end_s=20.0, **base)
    assert offset.goodput_tok_s == pytest.approx(zero.goodput_tok_s)
    assert offset.goodput_tok_s == pytest.approx(100 / 20.0)


def test_autoscaler_without_online_keeps_legacy_behavior():
    eng = OnlineALA(_small_cfg())
    eng.ingest(_ds("m-a", 50, 1), n_estimators=10)
    pol = ALAAutoscaler(ala=eng.ala_for(eng.combos[0]))
    act = pol.control(_obs(2.0, measured=1000.0))
    assert act.n_replicas >= 1 and not pol.recalibrations
