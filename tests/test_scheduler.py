"""inference.scheduler coverage: planner math, derating, batching queue."""
import numpy as np
import pytest

from repro.core.ala import ALA
from repro.core.expmodel import exp_model
from repro.inference.scheduler import (BatchingQueue, CapacityPlanner,
                                       Request, derate_confidence)


@pytest.fixture(scope="module")
def ala():
    """ALA fit on clean synthetic exponential curves (no SA log, so the
    planner's confidence path short-circuits to 1.0)."""
    rows = []
    bbs = np.array([1, 2, 4, 8, 16, 32, 64, 128], float)
    for ii in (64.0, 128.0, 256.0, 512.0):
        for oo in (64.0, 128.0, 256.0):
            c = 2000.0 + 2.0 * oo - 0.5 * ii
            for bb, t in zip(bbs, exp_model(bbs, 0.9 * c, 0.1, c)):
                rows.append((ii, oo, bb, t))
    ii, oo, bb, th = map(np.asarray, zip(*rows))
    return ALA().fit(ii, oo, bb, th)


# -------------------------------------------------------------- derating
def test_derate_confidence_regions():
    assert derate_confidence(0.9) == 1.0
    assert derate_confidence(0.7) == 1.0
    assert derate_confidence(0.5) == 0.5           # proportional band
    assert derate_confidence(0.1) == 0.25          # clamped at min_derate
    assert derate_confidence(0.0) == 0.25          # degenerate sentinel
    assert derate_confidence(float("nan")) == 0.25
    assert derate_confidence(float("inf")) == 0.25
    assert derate_confidence(0.4, floor=0.5, min_derate=0.1) == 0.4


def test_zero_confidence_plan_is_finite(ala):
    """PR-3 degenerate sentinel (confidence=0.0) must not zero the plan
    or blow up the replica count (the old 1/c headroom divided by 0)."""
    planner = CapacityPlanner(ala, candidate_bb=(1, 4, 16, 64),
                              max_replicas=16)
    planner._confidence = lambda ii, oo, bbs: 0.0
    plan = planner.plan_batch_size(128, 128, target_thpt=10_000.0)
    assert plan.degenerate and plan.confidence == 0.0
    assert plan.derated_thpt > 0.0                 # min_derate kept it alive
    assert plan.derated_thpt == pytest.approx(
        plan.predicted_thpt * planner.min_derate)
    assert 1 <= plan.replicas <= 16                # clamped, not ~1e13


# -------------------------------------------------------- capacity planner
def test_plan_scales_bb_with_target(ala):
    planner = CapacityPlanner(ala, candidate_bb=(1, 2, 4, 8, 16, 32, 64))
    lo = planner.plan_batch_size(128, 128, target_thpt=500.0)
    hi = planner.plan_batch_size(128, 128, target_thpt=2000.0)
    assert lo.bb <= hi.bb
    assert lo.confidence == 1.0 and lo.replicas == 1


def test_replica_math_when_target_unreachable(ala):
    planner = CapacityPlanner(ala, candidate_bb=(1, 2, 4, 8, 16, 32, 64))
    plan = planner.plan_batch_size(128, 128, target_thpt=50_000.0)
    assert plan.replicas == int(np.ceil(50_000.0 / plan.derated_thpt))
    assert plan.replicas > 1
    assert plan.bb == 64                # scaled out at the max-thpt batch


def test_latency_slo_selects_batch(ala):
    planner = CapacityPlanner(ala, candidate_bb=(1, 2, 4, 8, 16, 32, 64))
    ok = planner.plan_batch_size(128, 128, max_token_latency_s=0.02)
    assert ok.bb == 1                   # smallest qualifying batch wins
    none = planner.plan_batch_size(128, 128, max_token_latency_s=1e-4)
    assert none.bb == 64                # nothing qualifies: max-thpt fallback


# ---------------------------------------------------------- batching queue
def test_bucket_rounds_up_to_pow2():
    assert BatchingQueue.bucket(100, 100) == (128, 128)
    assert BatchingQueue.bucket(128, 1) == (128, 1)
    assert BatchingQueue.bucket(129, 500) == (256, 512)


def test_queue_groups_homogeneous_batches(ala):
    planner = CapacityPlanner(ala, candidate_bb=(1, 2, 4))
    q = BatchingQueue(planner, target_thpt=1e9)    # unreachable -> bb=4
    rid = 0
    for _ in range(9):
        q.submit(Request(rid=rid, ii=100, oo=100)); rid += 1
    for _ in range(3):
        q.submit(Request(rid=rid, ii=300, oo=300)); rid += 1
    batches = q.ready_batches()
    keys = [k for k, _ in batches]
    assert keys.count((128, 128)) == 2             # 9 // 4 full batches
    assert all(len(b) == 4 for k, b in batches if k == (128, 128))
    assert (512, 512) not in keys                  # 3 < planned bb
    # every grouped request really belongs to its bucket
    for k, b in batches:
        assert all(BatchingQueue.bucket(r.ii, r.oo) == k for r in b)
    rest = q.flush()
    assert sum(len(b) for _, b in rest) == 12 - 8
    assert q.ready_batches() == [] and q.flush() == []
