"""repro-check: rule fixtures (positive / negative / suppressed per
rule), suppression auditing, the runtime tracers, the CLI surface, and
the self-check that the repo is clean at HEAD."""
import pathlib
import textwrap

import numpy as np
import pytest

from repro.staticcheck import (ALL_RULES, RULES_BY_NAME, check_paths,
                               check_source)
from repro.staticcheck.__main__ import main as cli_main

REPO = pathlib.Path(__file__).resolve().parents[1]


def names(findings, rule=None):
    return [f.rule for f in findings
            if rule is None or f.rule == rule]


def suppress_line(src: str, line: int, rule: str) -> str:
    lines = src.splitlines()
    lines[line - 1] += f"  # repro-check: disable={rule}"
    return "\n".join(lines) + "\n"


# One fixture triple per rule: (path, bad source, violation line,
# path+source that must be clean).  Paths matter — half the rules are
# scoped, and the negative case often exercises the scope boundary.
CASES = {
    "banned-solve": dict(
        path="src/repro/core/database.py",
        bad="import jax.numpy as jnp\nd = jnp.linalg.solve(A, r)\n",
        line=2,
        good=("src/repro/core/fit.py",           # the one exempt home
              "import numpy as np\nd = np.linalg.solve(A, r)\n")),
    "no-shim-import": dict(
        path="src/repro/serving/x.py",
        bad="from repro.perfmodel import tpu\n",
        line=1,
        good=("src/repro/serving/x.py",
              "from repro.perfmodel import hardware\n")),
    "unseeded-rng": dict(
        path="src/repro/core/x.py",
        bad="import numpy as np\nv = np.random.normal(0.0, 1.0)\n",
        line=2,
        good=("src/repro/core/x.py",
              "import numpy as np\nrng = np.random.default_rng(0)\n"
              "v = rng.normal(0.0, 1.0)\n")),
    "wallclock-in-sim": dict(
        path="src/repro/serving/x.py",
        bad="import time\nt0 = time.time()\n",
        line=2,
        good=("src/repro/serving/x.py",
              "import time\nt0 = time.perf_counter()\n")),
    "bench-provenance": dict(
        path="benchmarks/extra.py",
        bad="import json\n"
            "(RESULTS / 'BENCH_extra.json').write_text("
            "json.dumps(payload))\n",
        line=2,
        good=("benchmarks/extra.py",
              "import json\ndef _write_bench(filename, payload):\n"
              "    (RESULTS / filename).write_text("
              "json.dumps(payload))\n")),
    "float64-edges": dict(
        path="src/repro/obs/metrics.py",
        bad="import numpy as np\n"
            "def my_edges(lo, hi, n):\n"
            "    return np.linspace(lo, hi, n)\n",
        line=2,
        good=("src/repro/obs/metrics.py",
              "import numpy as np\n"
              "def my_edges(lo, hi, n):\n"
              "    return np.linspace(lo, hi, n).astype(np.float32)\n")),
    "jit-in-loop": dict(
        path="src/repro/core/x.py",
        bad="import jax\nfor s in shapes:\n"
            "    f = jax.jit(lambda x: x + s)\n",
        line=3,
        good=("src/repro/core/x.py",
              "import jax\ndef _make():\n"
              "    return jax.jit(lambda x: x)\n")),
    "mutable-default-config": dict(
        path="src/repro/serving/x.py",
        bad="import dataclasses\n@dataclasses.dataclass\nclass C:\n"
            "    xs: list = dataclasses.field(default=[1])\n",
        line=4,
        good=("src/repro/serving/x.py",
              "import dataclasses\n@dataclasses.dataclass\nclass C:\n"
              "    xs: tuple = (1,)\n"
              "    ys: list = dataclasses.field("
              "default_factory=list)\n")),
}


@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_positive(rule):
    c = CASES[rule]
    findings = check_source(c["bad"], c["path"])
    hits = [f for f in findings if f.rule == rule]
    assert hits, f"{rule} missed its seeded violation: {findings}"
    assert hits[0].line == c["line"]
    assert hits[0].path == c["path"]


@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_negative(rule):
    path, good = CASES[rule]["good"]
    assert not names(check_source(good, path), rule)


@pytest.mark.parametrize("rule", sorted(CASES))
def test_rule_suppressed(rule):
    c = CASES[rule]
    src = suppress_line(c["bad"], c["line"], rule)
    findings = check_source(src, c["path"])
    assert not names(findings, rule)
    # a *used* suppression must not be reported as unused
    assert not names(findings, "unused-suppression")


# ------------------------------------------------------- rule details
def test_banned_solve_catches_numpy_and_scipy_spellings():
    for mod in ("np", "numpy", "jnp", "jax.numpy", "scipy"):
        src = f"d = {mod}.linalg.solve(A, r)\n"
        assert names(check_source(src, "src/repro/core/online.py"),
                     "banned-solve")


def test_no_shim_import_all_spellings_and_scope():
    spellings = (
        "import repro.perfmodel.tpu\n",
        "from repro.perfmodel.tpu import TPU_V5E\n",
        "from repro.perfmodel import tpu\n",
        "import importlib\n"
        "m = importlib.import_module('repro.perfmodel.tpu')\n",
    )
    for src in spellings:
        assert names(check_source(src, "src/repro/core/x.py"),
                     "no-shim-import"), src
    # the shim itself and out-of-src callers (tests) are exempt
    assert not names(check_source(spellings[0],
                                  "src/repro/perfmodel/tpu.py"),
                     "no-shim-import")
    assert not names(check_source(spellings[0],
                                  "tests/test_hardware_transfer.py"),
                     "no-shim-import")


def test_unseeded_rng_spellings():
    bad = (
        "r = np.random.default_rng()\n",
        "r = np.random.default_rng(seed=None)\n",
        "import random\nrandom.seed(3)\n",
        "from random import choice\n",
        "from numpy.random import normal\n",
        "v = np.random.rand(4)\n",
    )
    for src in bad:
        assert names(check_source(src, "src/repro/core/x.py"),
                     "unseeded-rng"), src
    good = (
        "r = np.random.default_rng(0)\n",
        "r = np.random.default_rng(seed)\n",           # variable seed
        "r = np.random.default_rng([seed, idx])\n",    # spawn-key list
        "k = jax.random.split(key, 4)\n",
        "v = rng.random(4)\n",                         # generator draw
    )
    for src in good:
        assert not names(check_source(src, "src/repro/core/x.py"),
                         "unseeded-rng"), src
    # benchmarks and tests sit outside the seed-determinism scope
    assert not names(check_source(bad[0], "benchmarks/run.py"),
                     "unseeded-rng")


def test_wallclock_scope_and_spellings():
    for src in ("t = time.time()\n", "t = time.monotonic()\n",
                "t = datetime.now()\n",
                "t = datetime.datetime.now()\n",
                "from time import time\n"):
        assert names(check_source(src, "src/repro/perfmodel/x.py"),
                     "wallclock-in-sim"), src
    # launch/ measures real compile wall-clock; benchmarks stamp
    # provenance — both out of scope
    assert not names(check_source("t = time.time()\n",
                                  "src/repro/launch/dryrun.py"),
                     "wallclock-in-sim")
    assert not names(check_source("t = time.time()\n",
                                  "benchmarks/run.py"),
                     "wallclock-in-sim")


def test_bench_provenance_ignores_non_bench_dumps():
    src = "import json\npath.write_text(json.dumps(report))\n"
    assert not names(check_source(src, "benchmarks/run.py"),
                     "bench-provenance")


def test_jit_in_loop_decorator_and_shielding():
    deco = ("import jax\nfor s in shapes:\n"
            "    @jax.jit\n    def f(x):\n        return x\n")
    assert names(check_source(deco, "src/repro/core/x.py"),
                 "jit-in-loop")
    partial = ("import functools, jax\nwhile True:\n"
               "    f = functools.partial(jax.jit, "
               "static_argnames=('n',))(g)\n")
    assert names(check_source(partial, "src/repro/core/x.py"),
                 "jit-in-loop")
    # a def inside the loop shields jit calls in its body (they run
    # per call, not per iteration) ...
    shielded = ("import jax\nfor s in shapes:\n"
                "    def make(s=s):\n"
                "        return jax.jit(lambda x: x + s)\n")
    assert not names(check_source(shielded, "src/repro/core/x.py"),
                     "jit-in-loop")
    # ... and a loop *inside* a jitted function is the gbt idiom
    inner = ("import jax\n@jax.jit\ndef f(x):\n"
             "    for _ in range(3):\n        x = x + 1\n"
             "    return x\n")
    assert not names(check_source(inner, "src/repro/core/x.py"),
                     "jit-in-loop")


def test_mutable_default_catches_np_and_ctor_defaults():
    for default in ("np.zeros(3)", "dict()", "collections.deque()",
                    "{}", "[]"):
        src = ("import dataclasses\n@dataclasses.dataclass(frozen=True)\n"
               f"class C:\n    x: object = {default}\n")
        assert names(check_source(src, "src/repro/configs/x.py"),
                     "mutable-default-config"), default
    # non-dataclass classes keep their idioms
    plain = "class C:\n    registry = {}\n"
    assert not names(check_source(plain, "src/repro/configs/x.py"),
                     "mutable-default-config")


# ------------------------------------------------------ suppressions
def test_unused_suppression_detected():
    src = "x = 1  # repro-check: disable=banned-solve\n"
    findings = check_source(src, "src/repro/core/x.py")
    assert names(findings, "unused-suppression")


def test_unknown_rule_in_suppression_detected():
    src = "x = 1  # repro-check: disable=no-such-rule\n"
    findings = check_source(src, "src/repro/core/x.py")
    assert any("unknown rule" in f.message for f in findings)


def test_suppression_for_unselected_rule_tolerated():
    # --rule subset runs must not misread other rules' waivers
    src = ("import jax.numpy as jnp\n"
           "d = jnp.linalg.solve(A, r)"
           "  # repro-check: disable=banned-solve\n")
    only_shim = [RULES_BY_NAME["no-shim-import"]]
    assert not check_source(src, "src/repro/core/x.py", rules=only_shim)


def test_suppression_inside_string_is_content_not_waiver():
    src = 'doc = "# repro-check: disable=banned-solve"\n'
    assert not check_source(src, "src/repro/core/x.py")


def test_multi_rule_suppression_one_used_one_stale():
    src = ("import time\n"
           "t = time.time()"
           "  # repro-check: disable=wallclock-in-sim,banned-solve\n")
    findings = check_source(src, "src/repro/serving/x.py")
    assert not names(findings, "wallclock-in-sim")
    stale = names(findings, "unused-suppression")
    assert len(stale) == 1


def test_parse_error_is_a_finding():
    findings = check_source("def broken(:\n", "src/repro/core/x.py")
    assert names(findings, "parse-error")


# ------------------------------------------------------- self-check
def test_repo_clean_at_head():
    """The acceptance gate: repro-check over src/ and benchmarks/ (and
    the test tree) reports zero findings at HEAD."""
    res = check_paths([REPO / "src", REPO / "benchmarks",
                       REPO / "tests"], root=REPO)
    assert res.n_files > 80
    assert res.ok, "\n".join(f.format() for f in res.findings)


def test_every_rule_registered_and_documented():
    assert len(ALL_RULES) >= 8
    assert set(CASES) == {r.name for r in ALL_RULES}
    catalog = (REPO / "docs" / "static_analysis.md").read_text()
    for r in ALL_RULES:
        assert r.name and r.description and r.contract
        assert f"`{r.name}`" in catalog, \
            f"rule {r.name} missing from docs/static_analysis.md"


# -------------------------------------------------------------- CLI
def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for r in ALL_RULES:
        assert r.name in out


def test_cli_finds_and_formats(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\nt = time.time()\n")
    # outside any repo root the scoped rules don't apply -> clean
    assert cli_main([str(bad)]) == 0
    # inside a synthetic repo layout the finding fires, with the
    # github annotation format CI consumes
    root = tmp_path / "fake"
    target = root / "src" / "repro" / "serving"
    target.mkdir(parents=True)
    (root / ".git").mkdir()
    f = target / "bad.py"
    f.write_text("import time\nt = time.time()\n")
    capsys.readouterr()
    assert cli_main(["--format=github", str(f)]) == 1
    out = capsys.readouterr().out
    assert "::error file=" in out and "wallclock-in-sim" in out


def test_cli_bad_invocations(capsys):
    assert cli_main(["--rule", "no-such-rule", "src"]) == 2
    assert cli_main(["definitely/not/a/path"]) == 2
    capsys.readouterr()


# ----------------------------------------------------------- tracers
def test_assert_max_compiles_counts_and_gates():
    jax = pytest.importorskip("jax")
    jnp = jax.numpy
    from repro.staticcheck.tracers import (CompileBudgetExceeded,
                                           assert_max_compiles,
                                           count_compiles)
    f = jax.jit(lambda x: x * 2.0)
    x8 = jnp.ones(8)
    f(x8)                                   # warmup compile
    with count_compiles("steady") as rep:
        f(x8)                               # cache hit
    if not rep.available:                   # exotic build: counted no-op
        pytest.skip("jax.monitoring unavailable")
    assert rep.count == 0
    with assert_max_compiles(8, label="one new shape") as rep:
        f(jnp.ones(16))
    assert rep.count >= 1
    with pytest.raises(CompileBudgetExceeded, match="budget exceeded"):
        with assert_max_compiles(0, label="must not compile"):
            f(jnp.ones(32))


def test_nan_guard_names_offending_leaf():
    from repro.staticcheck.tracers import nan_guard

    @nan_guard
    def fit():
        return {"params": np.ones(3), "err": np.array([1.0, np.nan])}

    with pytest.raises(FloatingPointError, match=r"\['err'\]"):
        fit()


def test_nan_guard_inf_sentinel_allowed_by_default():
    from repro.staticcheck.tracers import nan_guard
    sentinel = nan_guard(lambda: (np.inf, 0.0))   # degenerate Alg 8
    assert sentinel() == (np.inf, 0.0)
    strict = nan_guard(lambda: (np.inf, 0.0), allow_inf=False)
    with pytest.raises(FloatingPointError):
        strict()


def test_nan_guard_passes_clean_output_through():
    from repro.staticcheck.tracers import nan_guard
    out = nan_guard(lambda: [np.arange(3), {"s": "text", "v": 1.5}])()
    assert out[1]["s"] == "text"
